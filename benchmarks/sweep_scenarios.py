"""Scenario-grid sweep over the topology engine (DESIGN.md §5).

Runs every gather scenario in the registry grid over protocol x knob:

  multi_ps_gather   n_ps in {1, 2, 4[, 8]}          (sharded-PS scaling)
  straggler_gather  slow_rate_mult in {0.5, 0.25[, 0.1]}
  cross_traffic     bg_load in {0.0, 0.5[, 0.8]}

Emits one row per (scenario, protocol, knob): mean/p99 gather BST, mean
delivered fraction, and LTP's speedup over the same cell's cubic run.
Transfer sizes are scaled (2 MB quick / 5 MB full per model) so the whole
grid finishes in seconds on CPU; trends — not absolute seconds — are the
output.

  PYTHONPATH=src python -m benchmarks.run --only scenario_sweep
  PYTHONPATH=src python -m benchmarks.sweep_scenarios          # standalone
"""
from __future__ import annotations

import numpy as np

from repro.config import NetConfig
from repro.net.scenarios import PROTOCOLS, run_scenario

from benchmarks.common import emit


def _cells(quick: bool):
    n_ps = [1, 2, 4] if quick else [1, 2, 4, 8]
    slow = [0.5, 0.25] if quick else [0.5, 0.25, 0.1]
    load = [0.0, 0.5] if quick else [0.0, 0.5, 0.8]
    for v in n_ps:
        yield "multi_ps_gather", {"n_ps": v}, f"n_ps={v}"
    for v in slow:
        yield "straggler_gather", {"slow_rate_mult": v}, f"slow_mult={v}"
    for v in load:
        yield "cross_traffic", {"bg_load": v}, f"bg_load={v}"


def run(quick: bool = True):
    rows = []
    iters = 4 if quick else 10
    size = 2e6 if quick else 5e6
    w = 8
    net = NetConfig(10, 1, 0.001, 4096)
    for scenario, kw, knob in _cells(quick):
        cell = {}
        for proto in PROTOCOLS:
            rs = run_scenario(scenario, proto, net, w=w, size_bytes=size,
                              iters=iters, seed=13, **kw)
            bst = np.array([r.bst_gather for r in rs])
            cell[proto] = bst.mean()
            rows.append({
                "scenario": scenario, "knob": knob, "protocol": proto,
                "bst_mean_ms": round(float(bst.mean()) * 1e3, 2),
                "bst_p99_ms": round(float(np.percentile(bst, 99)) * 1e3, 2),
                "delivered": round(float(np.mean([r.delivered.mean()
                                                  for r in rs])), 4),
            })
        for r in rows[-len(PROTOCOLS):]:
            r["ltp_speedup_vs_cubic"] = round(cell["cubic"] / cell["ltp"], 2)
    emit(rows, "sweep_scenarios")
    return rows


if __name__ == "__main__":
    run(quick=True)
