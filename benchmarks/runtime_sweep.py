"""Cluster-runtime sweep: aggregation policy x protocol x cluster size
under the lognormal straggler compute model (DESIGN.md §8).

Each cell runs the event-driven ``ClusterRuntime`` on the tiny papernet
over heterogeneous workers (lognormal jitter + occasional 5x straggler)
and reports simulated time per iteration, staleness, Early Close
activity, and blocked time. The grid is where the paper's barrier story
becomes measurable: bsp pays the per-iteration max over workers, while
async/ssp overlap the stragglers — their sim-time speedups over bsp on
the same seed are the acceptance metrics.

Gate metrics landing in ``BENCH_runtime.json`` (diffed and
floor-checked by ``benchmarks.check_regression`` in CI):

  runtime_async_vs_bsp_speedup / runtime_ssp_vs_bsp_speedup
      simulated-time ratio bsp/policy at the largest swept cluster
      (machine-independent: every stream is seeded);
  runtime_des_events_per_sec
      packet-level co-simulation throughput of the w=8 DES cell,
      measured warm (the cold run pays one-time jit compilation the
      step cache then amortizes across every later runtime — see
      runtime/step.py); the paired ``runtime_des_cold_events_per_sec``
      records the unwarmed figure;
  runtime_des64_events_per_sec
      the DES-at-scale cell: 64 workers, coalesced packet trains —
      the shape the event-engine/pooling/jit-cache fast path
      (DESIGN.md §9) exists to make routine;
  telemetry_overhead_ratio
      warm DES events/s with tracker off divided by the same cell with
      the JSONL tracker attached (both best-of-2) — the observability
      layer's measured cost, gated at <= 1.05 by ``check_regression``
      (DESIGN.md §12); ``runtime_des_jsonl_events_per_sec`` records the
      JSONL-arm absolute figure.

  PYTHONPATH=src python -m benchmarks.runtime_sweep --quick
  PYTHONPATH=src python -m benchmarks.run --only runtime_sweep
"""
from __future__ import annotations

import argparse
import gc
import os
import tempfile
import time

from repro.config import LTPConfig, NetConfig, ObservabilityConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.net import simcore
from repro.optim import make_optimizer
from repro.runtime import ClusterRuntime, LognormalStragglerCompute

from benchmarks.common import emit
from benchmarks.sweep_scenarios import write_bench

POLICIES = ("bsp", "async", "ssp")
PROTOCOLS = ("ltp", "cubic")
SSP_K = 2

#: the straggler model every cell shares — heavy enough that the barrier
#: penalty is unambiguous, seeded so the sweep is reproducible
COMPUTE_KW = dict(sigma=0.3, straggler_prob=0.15, straggler_mult=5.0)


def _cell(api, tc, net, w, policy, proto, steps, *, transport="analytic",
          seed=11, obs=None):
    data = SyntheticCIFAR(seed=3)
    kw = {"policy_kw": {"staleness": SSP_K}} if policy == "ssp" else {}
    compute = LognormalStragglerCompute(w, base=0.05, seed=seed,
                                        **COMPUTE_KW)
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(staleness_comp=0.5), net,
        n_workers=w, protocol=proto, policy=policy,
        compute_model=compute, compute_time=0.05, seed=seed,
        transport=transport, obs=obs, **kw)
    simcore.PERF.reset()
    t0 = time.time()
    rt.run(batches(data, tc.batch, steps), epoch_steps=max(1, steps // 2))
    wall = time.time() - t0
    s = rt.tel.summary()
    row = {
        "scenario": f"runtime_w{w}", "policy": policy, "protocol": proto,
        "transport": transport,
        "simtime_s": round(rt.sim_time, 4),
        "simtime_per_iter_ms": round(rt.sim_time / steps * 1e3, 2),
        "wall_s": round(wall, 2),
        "staleness_max": s["staleness_max"],
        "staleness_mean": s["staleness_mean"],
        "n_early_close": s["n_early_close"],
        "n_stale_drops": s["n_stale_drops"],
        "blocked_s": s["blocked_s"],
    }
    if transport == "des":
        row["coalesce"] = rt.net_des.coalesce
        row["events_per_sec"] = round(
            simcore.PERF.packets / max(wall, 1e-9))
    return row


def run(quick: bool = True):
    sizes = (8, 16) if quick else (8, 32, 64)
    steps = 8 if quick else 16
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    net = NetConfig(10, 1, 0.001, 4096)
    rows = []
    metrics = {"runtime_ssp_k": SSP_K}
    t_start = time.time()
    for w in sizes:
        tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
        cell = {}
        for policy in POLICIES:
            for proto in PROTOCOLS:
                row = _cell(api, tc, net, w, policy, proto, steps)
                cell[(policy, proto)] = row["simtime_s"]
                rows.append(row)
        for policy in ("async", "ssp"):
            for proto in PROTOCOLS:
                sp = round(cell[("bsp", proto)] / cell[(policy, proto)], 3)
                metrics[f"runtime_w{w}_{policy}_{proto}_vs_bsp"] = sp
    # acceptance metrics: largest swept cluster, both policies, ltp
    w_top = sizes[-1]
    metrics["runtime_async_vs_bsp_speedup"] = \
        metrics[f"runtime_w{w_top}_async_ltp_vs_bsp"]
    metrics["runtime_ssp_vs_bsp_speedup"] = \
        metrics[f"runtime_w{w_top}_ssp_ltp_vs_bsp"]
    # packet-level co-simulation cells: DES throughput under the gate.
    # The first (cold) run pays one-time jit compilation the grid above
    # didn't already cover plus flow-pool construction; the gated figure
    # is the best of two warm reruns — that's what every later runtime
    # in the process actually pays (runtime/step.py jit cache,
    # DESIGN.md §9), measured best-of like every kernel microbench.
    def des_cell(w, tc, steps):
        gc.collect()
        cold = _cell(api, tc, net, w, "bsp", "ltp", steps, transport="des")
        warm = []
        for _ in range(2):
            gc.collect()
            warm.append(_cell(api, tc, net, w, "bsp", "ltp", steps,
                              transport="des"))
        return cold, max(warm, key=lambda r: r["events_per_sec"])

    des_steps = max(2, steps // 4)
    tc = TrainConfig(batch=4 * sizes[0], lr=0.05, steps=des_steps)
    cold_row, des_row = des_cell(sizes[0], tc, des_steps)
    metrics["runtime_des_cold_events_per_sec"] = cold_row["events_per_sec"]
    rows.append(des_row)
    metrics["runtime_des_events_per_sec"] = des_row["events_per_sec"]
    # observability overhead (DESIGN.md §12): the same warm cell with the
    # JSONL tracker attached, best-of-2 like the tracker-off arm. The
    # ratio (off / jsonl) is the CI-gated ceiling — the backend buffers
    # O(1) appends and serializes only after the run, so the true cost
    # is a few percent and the 1.05 budget mostly absorbs runner jitter.
    obs_cfg = ObservabilityConfig(
        tracker="jsonl",
        path=os.path.join(tempfile.gettempdir(), "runtime_sweep_obs.jsonl"))
    jl = []
    for _ in range(2):
        gc.collect()
        jl.append(_cell(api, tc, net, sizes[0], "bsp", "ltp", des_steps,
                        transport="des", obs=obs_cfg))
    jsonl_row = max(jl, key=lambda r: r["events_per_sec"])
    jsonl_row["scenario"] = "runtime_des_jsonl"
    rows.append(jsonl_row)
    metrics["runtime_des_jsonl_events_per_sec"] = \
        jsonl_row["events_per_sec"]
    metrics["telemetry_overhead_ratio"] = round(
        des_row["events_per_sec"] / max(jsonl_row["events_per_sec"], 1), 4)
    # DES at scale: 64 workers, coalesced trains — the cell shape the
    # §9 fast path exists to make routine
    w64 = 64
    tc64 = TrainConfig(batch=4 * w64, lr=0.05, steps=2)
    cold64_row, des64_row = des_cell(w64, tc64, 2)
    des64_row["scenario"] = "runtime_des64"
    rows.append(des64_row)
    metrics["runtime_des64_cold_events_per_sec"] = \
        cold64_row["events_per_sec"]
    metrics["runtime_des64_events_per_sec"] = des64_row["events_per_sec"]
    metrics["runtime_des64_coalesce"] = des64_row["coalesce"]
    metrics["runtime_sweep_wall_s"] = round(time.time() - t_start, 3)
    write_bench(metrics, quick, "BENCH_runtime.json")
    emit(rows, "runtime_sweep")
    speed_a = metrics["runtime_async_vs_bsp_speedup"]
    speed_s = metrics["runtime_ssp_vs_bsp_speedup"]
    print(f"async vs bsp: {speed_a}x | ssp(k={SSP_K}) vs bsp: {speed_s}x "
          f"(sim-time, w={w_top}, lognormal stragglers)")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized grid (default: full)")
    args = ap.parse_args(argv)
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
