"""repro.checkpoint round-trip contract: pytree <-> npz with slash
paths, step restoration, and loud failures on archive/`like` skew — a
silent partial restore is how PS failover would corrupt a model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.models import build
from repro.optim import make_optimizer
from repro.config import TrainConfig


def _tree():
    return {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                   "b": np.zeros(3, dtype=np.float32)},
        "opt": {"mu": {"w": np.full((2, 3), 0.5, np.float32)},
                "count": np.asarray(7)},
    }


def test_round_trip_identity(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, _tree(), step=42)
    # `like` carries structure and dtypes only; its values must not leak
    zeros = {
        "params": {"w": np.zeros((2, 3), np.float32),
                   "b": np.ones(3, np.float32)},
        "opt": {"mu": {"w": np.zeros((2, 3), np.float32)},
                "count": np.asarray(0)},
    }
    tree, step = restore_checkpoint(path, zeros)
    assert step == 42
    ref = _tree()
    np.testing.assert_array_equal(tree["params"]["w"], ref["params"]["w"])
    np.testing.assert_array_equal(tree["params"]["b"], ref["params"]["b"])
    np.testing.assert_array_equal(tree["opt"]["mu"]["w"],
                                  ref["opt"]["mu"]["w"])
    assert int(tree["opt"]["count"]) == 7


def test_round_trip_real_model_and_opt_state(tmp_path):
    import jax
    cfg = get_config("papernet").replace(d_model=8, n_layers=2)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = make_optimizer(TrainConfig(batch=8, lr=0.1, steps=1))
    opt_state = opt.init(params)
    path = str(tmp_path / "model_ck")
    save_checkpoint(path, {"params": params, "opt_state": opt_state},
                    step=3)
    like = {"params": params, "opt_state": opt_state}
    tree, step = restore_checkpoint(path, like)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(tree["params"]),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype
    for a, b in zip(jax.tree_util.tree_leaves(tree["opt_state"]),
                    jax.tree_util.tree_leaves(opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_missing_paths_raise_keyerror_with_names(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"params": {"w": np.ones(2, np.float32)}})
    like = {"params": {"w": np.zeros(2, np.float32),
                       "b": np.zeros(3, np.float32)},
            "opt": np.zeros(1, np.float32)}
    with pytest.raises(KeyError, match=r"missing 2 path"):
        restore_checkpoint(path, like)
    try:
        restore_checkpoint(path, like)
    except KeyError as e:
        msg = str(e)
        assert "params/b" in msg and "opt" in msg


def test_extra_paths_strict_raises_lenient_passes(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": np.ones(2, np.float32),
                           "legacy": np.zeros(4, np.float32)}, step=9)
    like = {"w": np.zeros(2, np.float32)}
    with pytest.raises(ValueError, match=r"legacy.*strict=False"):
        restore_checkpoint(path, like)
    tree, step = restore_checkpoint(path, like, strict=False)
    assert step == 9
    np.testing.assert_array_equal(tree["w"], np.ones(2, np.float32))


def test_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": np.ones((2, 3), np.float32)})
    with pytest.raises(ValueError, match=r"'w' has shape \(2, 3\)"):
        restore_checkpoint(path, {"w": np.zeros((3, 2), np.float32)})


def test_dtype_follows_like(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": np.ones(4, np.float64)})
    tree, _ = restore_checkpoint(path, {"w": jnp.zeros(4, jnp.float32)})
    assert tree["w"].dtype == jnp.float32


def test_npz_suffix_is_optional(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": np.ones(2, np.float32)}, step=1)
    assert (tmp_path / "ck.npz").exists()
    t1, s1 = restore_checkpoint(path, {"w": np.zeros(2, np.float32)})
    t2, s2 = restore_checkpoint(path + ".npz",
                                {"w": np.zeros(2, np.float32)})
    assert s1 == s2 == 1
    np.testing.assert_array_equal(t1["w"], t2["w"])
