"""papernet — the paper's own workload family, adapted.

The paper trains ResNet-18/50/152 and VGG16 on CIFAR-10 over 8 workers + 1 PS.
``papernet`` is a ResNet-style mini CNN (3 stages x 2 basic blocks) on 32x32x3
inputs with 10 classes, used by the accuracy / TTA / Random-k-vs-Top-k
experiments (paper Figs 5, 12, 13). ``d_model`` is the stem width; stage
widths are (w, 2w, 4w).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="papernet",
    family="cnn",
    n_layers=6,              # 3 stages x 2 basic blocks
    d_model=32,              # stem width
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=10,                # classes
    pos_type="none",
    norm_type="ln",          # per-channel scale/offset (GroupNorm-ish, BN-free)
    dtype="float32",
    source="paper §V (ResNet/CIFAR-10 testbed workload)",
)

REDUCED = CONFIG.replace(name="papernet-reduced", n_layers=2, d_model=8)
