"""Packetization invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import packets as pk


def _tree(shapes):
    return {f"t{i}": jnp.arange(int(np.prod(s)), dtype=jnp.float32).reshape(s) + i
            for i, s in enumerate(shapes)}


def test_roundtrip_exact():
    tree = _tree([(7, 5), (13,), (2, 3, 4)])
    plan = pk.make_plan(tree, packet_floats=8)
    flat = pk.flatten(plan, tree)
    back = pk.unflatten(plan, flat)
    for k in tree:
        np.testing.assert_array_equal(tree[k], back[k])


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.tuples(st.integers(1, 9), st.integers(1, 9)), min_size=1, max_size=5),
    st.integers(2, 64),
)
def test_roundtrip_property(shapes, p):
    tree = _tree(shapes)
    plan = pk.make_plan(tree, packet_floats=p)
    back = pk.unflatten(plan, pk.flatten(plan, tree))
    for k in tree:
        np.testing.assert_array_equal(tree[k], back[k])


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.tuples(st.integers(1, 12), st.integers(1, 12)), min_size=1, max_size=4),
    st.integers(2, 50),
)
def test_padding_bubble_alignment(shapes, p):
    """No float straddles a packet boundary: zeroing any packet zeroes only
    whole float elements and leaves every other element bit-identical
    (paper §III-C, Fig 8)."""
    tree = _tree(shapes)
    plan = pk.make_plan(tree, packet_floats=p)
    flat = pk.flatten(plan, tree)
    kill = plan.n_packets // 2
    flat2 = flat.at[kill].set(0.0)
    back = pk.unflatten(plan, flat2)
    orig = pk.unflatten(plan, flat)
    changed = 0
    for k in tree:
        diff = np.asarray(back[k] != orig[k])
        eq_zero = np.asarray(back[k] == 0)
        assert np.all(~diff | eq_zero)   # every changed element became 0
        changed += diff.sum()
    assert changed <= plan.packet_floats


def test_critical_packets_cover_tensor_edges():
    tree = _tree([(17, 3), (5,), (101,)])
    plan = pk.make_plan(tree, packet_floats=16, critical_per_tensor=1)
    sizes = [51, 5, 101]
    offs = np.cumsum([0] + sizes)[:-1]
    for off, sz in zip(offs, sizes):
        assert plan.critical[off // 16]
        assert plan.critical[(off + sz - 1) // 16]


def test_delivery_mask_critical_always_on():
    tree = _tree([(64, 4)])
    plan = pk.make_plan(tree, packet_floats=8)
    m = pk.delivery_mask(plan, jax.random.PRNGKey(1), 0.0)
    assert np.all(np.asarray(m)[plan.critical] == 1.0)
    assert np.all(np.asarray(m)[~plan.critical] == 0.0)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 0.95), st.integers(0, 100))
def test_delivery_mask_rate(frac, seed):
    tree = _tree([(700, 4)])
    plan = pk.make_plan(tree, packet_floats=8, critical_per_tensor=1)
    m = np.asarray(pk.delivery_mask(plan, jax.random.PRNGKey(seed), frac))
    noncrit = m[~plan.critical]
    assert abs(noncrit.mean() - frac) < 0.12


def test_local_plan_shapes():
    from repro import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P
    sds = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    plan = pk.local_plan(sds, {"w": P(None, None)}, mesh, packet_floats=8)
    assert plan.n_floats == 64 * 32
