"""Network-layer chaos (DESIGN.md §14): link/switch fault injection,
self-healing LTP flows, and the closed-loop loss-budget controller.

Invariants this suite pins:

  * zero-fault parity — an armed-but-empty fabric-fault layer (empty
    LinkFaultSchedule, no controller) is bitwise identical to a
    fault-unaware runtime: same history, same telemetry stream
  * extended conservation — every grad_ready is applied, stale-dropped,
    torn, lost, or blackholed (flow_dead); nothing vanishes silently
  * blackhole liveness — a permanently partitioned rack's flows abort
    via RTO backoff within bounded sim time; the barrier never wedges
  * determinism — faulted runs replay bitwise from (seed, schedule),
    and drawn schedules never cut more racks than the configured ceiling
"""
import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig, NetFaultConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.net.simcore import Packet, Pipe, Sim
from repro.net.topology import rack_spine
from repro.optim import make_optimizer
from repro.runtime import (
    BudgetController,
    ClusterRuntime,
    FaultEvent,
    LinkFaultEvent,
    LinkFaultSchedule,
    NetFaultPlane,
    netfault_schedule_from_config,
)
from repro.net.netfaults import max_concurrent_cut

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

NET = NetConfig(10, 1, 0.001, 4096)
W = 4
STEPS = 6


@pytest.fixture(scope="module")
def api():
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    return build(cfg)


def _rt(api, policy="bsp", steps=STEPS, w=W, racks=2, n_ps=1, seed=0,
        **kw):
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
    return ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(), NET, n_workers=w,
        policy=policy, compute_time=0.05, seed=seed, transport="des",
        topology=rack_spine(racks, w // racks, n_ps=n_ps), **kw)


def _run(rt, steps=STEPS, w=W):
    return rt.run(batches(SyntheticCIFAR(seed=0), 4 * w, steps))


def _assert_conservation(rt):
    """grad_ready == applied + stale + torn + lost + flow_dead — the §10
    law extended with the fabric-fault sink (DESIGN.md §14)."""
    tel = rt.tel
    n_ready = len(tel.of("grad_ready"))
    applied = sum(e["n_grads"] for e in tel.of("apply"))
    n_stale = len(tel.of("stale_drop"))
    n_torn = len(tel.of("flow_torn"))
    n_lost = len(tel.of("ps_lost"))
    n_dead = len(tel.of("flow_dead"))
    assert n_ready == applied + n_stale + n_torn + n_lost + n_dead, (
        n_ready, applied, n_stale, n_torn, n_lost, n_dead)


# ---------------------------------------------------------------------------
# event / schedule units
# ---------------------------------------------------------------------------


def test_link_fault_event_validation_and_label():
    with pytest.raises(ValueError, match="unknown link fault kind"):
        LinkFaultEvent(0.1, "meteor")
    with pytest.raises(ValueError, match="must be >= 0"):
        LinkFaultEvent(-1.0, "link_down", "rack0/up")
    with pytest.raises(TypeError):
        LinkFaultSchedule([("not", "an", "event")])
    lbl = LinkFaultEvent(0.1, "link_flap", "rack1/up", period_s=0.02,
                         duty=0.5, duration_s=0.2).label()
    assert lbl.startswith("link_flap rack1/up @0.10s")
    assert "duty 0.50" in lbl
    lbl = LinkFaultEvent(0.5, "partition", "rack2", recover_s=0.1).label()
    assert "+0.10s recovery" in lbl


def test_node_fault_labels_name_the_right_unit():
    # satellite regression: ps_* / worker_* kinds must not both render
    # as "worker{target}"
    assert FaultEvent(0.5, "ps_fail", 1).label().startswith(
        "ps_fail ps1 @0.50s")
    assert FaultEvent(0.5, "worker_crash", 2).label().startswith(
        "worker_crash worker2 @0.50s")


def test_schedule_sorted_stable_deterministic():
    evs = [LinkFaultEvent(0.3, "link_down", "rack0/up"),
           LinkFaultEvent(0.1, "link_up", "rack1/up"),
           LinkFaultEvent(0.3, "heal", "rack0")]
    s = LinkFaultSchedule(evs)
    assert [e.t for e in s] == [0.1, 0.3, 0.3]
    assert [e.kind for e in s] == ["link_up", "link_down", "heal"]
    spec = rack_spine(4, 4, n_ps=2)
    a = LinkFaultSchedule.random(spec, 2.0, seed=5, flap_rate=3.0,
                                 partition_at=(0.5, 1.0))
    b = LinkFaultSchedule.random(spec, 2.0, seed=5, flap_rate=3.0,
                                 partition_at=(0.5, 1.0))
    assert a.events == b.events and len(a) > 0


def test_random_never_downs_trunks_or_partitions_ps_racks():
    spec = rack_spine(4, 4, n_ps=2)
    ps_homes = {spec.ps_rack(p) for p in range(spec.n_ps)}
    s = LinkFaultSchedule.random(spec, 5.0, seed=7, link_down_rate=4.0,
                                 flap_rate=4.0, degrade_rate=2.0,
                                 partition_at=(0.5, 1.5, 2.5),
                                 switch_crash_at=(1.0,))
    assert len(s) > 0
    for ev in s:
        if ev.kind in ("link_down", "link_flap"):
            assert "trunk" not in ev.target
        if ev.kind == "partition":
            r = int(ev.target[4:])
            assert r not in ps_homes


def test_max_concurrent_cut_replay():
    assert max_concurrent_cut([]) == 0
    # two overlapping auto-healed partitions on distinct racks
    evs = [LinkFaultEvent(0.1, "partition", "rack2", recover_s=0.5),
           LinkFaultEvent(0.3, "partition", "rack3", recover_s=0.5)]
    assert max_concurrent_cut(evs) == 2
    # sequential (no overlap)
    evs = [LinkFaultEvent(0.1, "partition", "rack2", recover_s=0.1),
           LinkFaultEvent(0.3, "partition", "rack3", recover_s=0.1)]
    assert max_concurrent_cut(evs) == 1
    # permanent cut closed by an explicit heal
    evs = [LinkFaultEvent(0.1, "switch_crash", "rack1"),
           LinkFaultEvent(0.2, "switch_recover", "rack1"),
           LinkFaultEvent(0.3, "partition", "rack2", recover_s=1.0)]
    assert max_concurrent_cut(evs) == 1
    # unhealed cut stays open to infinity
    evs = [LinkFaultEvent(0.1, "partition", "rack2"),
           LinkFaultEvent(5.0, "partition", "rack3", recover_s=0.1)]
    assert max_concurrent_cut(evs) == 2


def _cut_ceiling_holds(seed, max_cut):
    spec = rack_spine(4, 4, n_ps=1)
    s = LinkFaultSchedule.random(
        spec, 4.0, seed=seed,
        partition_at=tuple(np.linspace(0.1, 3.5, 9)),
        switch_crash_at=tuple(np.linspace(0.2, 3.6, 9)),
        partition_heal_s=0.8, switch_recover_s=0.8, max_cut=max_cut)
    ceiling = min(max_cut, spec.racks - 1)
    assert max_concurrent_cut(s.events) <= ceiling


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**20), max_cut=st.integers(0, 6))
    def test_drawn_schedules_respect_cut_ceiling(seed, max_cut):
        """Property (DESIGN.md §14): a drawn timeline never severs more
        racks concurrently than min(max_cut, racks - 1) — the fabric
        mirror of FaultSchedule.random's min_active thinning."""
        _cut_ceiling_holds(seed, max_cut)
else:
    @pytest.mark.parametrize("seed", range(12))
    @pytest.mark.parametrize("max_cut", [0, 1, 2, 4])
    def test_drawn_schedules_respect_cut_ceiling(seed, max_cut):
        _cut_ceiling_holds(seed, max_cut)


def test_netfault_schedule_from_config_wires_fields():
    spec = rack_spine(4, 4, n_ps=1)
    cfg = NetFaultConfig(flap_rate=3.0, partition_at=(0.5,),
                         partition_heal_s=0.2, seed=9)
    s = netfault_schedule_from_config(cfg, spec, 2.0)
    kinds = {e.kind for e in s}
    assert "link_flap" in kinds and "partition" in kinds
    part = [e for e in s if e.kind == "partition"][0]
    assert part.t == 0.5 and part.recover_s == 0.2


# ---------------------------------------------------------------------------
# pipe-level fault mechanics (generation fence, reroute, degrade)
# ---------------------------------------------------------------------------


def _pipe(sim, seed=0, loss=0.0):
    return Pipe(sim, 1e9, 1e-3, loss=loss, queue_pkts=64,
                rng=np.random.default_rng(seed))


def _pkt(seq=0):
    return Packet(flow=0, seq=seq, size=1500)


def test_downed_pipe_fences_in_flight_and_blackholes_new_sends():
    sim = Sim()
    p = _pipe(sim)
    p.faultable = True
    got = []
    assert p.send(_pkt(), got.append)
    sim.after(1e-4, lambda: p.set_up(False))     # down while in flight
    sim.run()
    assert got == [] and p.n_dropped_down == 1   # fenced at arrival
    # new sends on a downed pipe with no backup: swallowed silently
    assert p.send(_pkt(1), got.append)
    sim.run()
    assert got == [] and p.n_dropped_down == 2


def test_downed_pipe_reroutes_via_backup():
    sim = Sim()
    p, bk = _pipe(sim, 0), _pipe(sim, 1)
    p.faultable = bk.faultable = True
    p.backup = bk
    p.set_up(False)
    got = []
    p.send(_pkt(), got.append)
    sim.run()
    assert len(got) == 1 and p.n_rerouted == 1
    assert bk.bytes_delivered > 0
    # partition: backup down too -> blackhole
    bk.set_up(False)
    p.send(_pkt(1), got.append)
    sim.run()
    assert len(got) == 1 and p.n_dropped_down == 1


def test_degrade_cuts_rate_and_restores():
    sim = Sim()
    p = _pipe(sim)
    base_rate, base_loss = p.rate, p.loss
    p.set_degraded(rate_factor=0.25, extra_loss=0.1)
    assert p.rate == pytest.approx(base_rate * 0.25)
    assert p.loss == pytest.approx(base_loss + 0.1)
    p.clear_degraded()
    assert p.rate == base_rate and p.loss == base_loss


def test_plane_installs_lazily_and_builds_backups():
    sim = Sim()
    spec = rack_spine(2, 2, n_ps=1)
    from repro.net.scenarios import _build_topology
    topo, _ = _build_topology(sim, NET, 4, spec,
                              np.random.default_rng(0))
    plane = NetFaultPlane(sim, topo, spec, seed=0)
    assert not plane.installed
    assert all(not p.faultable for p in topo.pipes.values())
    plane.dispatch(LinkFaultEvent(0.0, "link_down", "rack1/up",
                                  recover_s=0.01))
    assert plane.installed
    up = topo.pipes["rack1/up"]
    assert up.backup is not None and not up.up and up.backup.up
    assert plane.n_reroutes == 1      # the cut found a live backup
    sim.run()
    assert up.up                      # auto-recovery fired


# ---------------------------------------------------------------------------
# acceptance: zero-fault parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["bsp", "async"])
def test_zero_netfault_run_is_record_identical(api, policy):
    """Empty LinkFaultSchedule + no controller must be a structural
    no-op: pipes stay unfaulted, senders keep unhealed timing, and both
    the history and the telemetry stream match bitwise."""
    base = _rt(api, policy=policy)
    h0 = _run(base)
    rt = _rt(api, policy=policy, net_faults=LinkFaultSchedule([]))
    h1 = _run(rt)
    assert h0 == h1
    assert base.tel.events == rt.tel.events
    assert rt.netfault_plane is None
    assert all(not p.faultable for p in rt.net_des.topo.pipes.values())
    import jax
    for x, y in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(rt.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# acceptance: 16-worker chaos (flaps + switch crash + partition)
# ---------------------------------------------------------------------------


def _chaos_schedule():
    return LinkFaultSchedule([
        LinkFaultEvent(0.05, "link_flap", "rack2/up", period_s=0.02,
                       duty=0.5, duration_s=0.12),
        LinkFaultEvent(0.10, "switch_crash", "rack1", recover_s=0.06),
        LinkFaultEvent(0.20, "partition", "rack3", recover_s=0.15),
        LinkFaultEvent(0.35, "link_degrade", "ps0/trunk",
                       rate_factor=0.5, extra_loss=0.02, recover_s=0.1),
    ])


@pytest.mark.parametrize("policy", ["bsp", "async"])
def test_chaos16_completes_conserves_and_replays(api, policy,
                                                 chaos_forensics):
    def go():
        rt = chaos_forensics(_rt(
            api, policy=policy, w=16, racks=4, n_ps=2, steps=4,
            net_faults=_chaos_schedule(), seed=3,
            budget=BudgetController(interval_s=0.03)))
        h = _run(rt, steps=4, w=16)
        return rt, h

    rt, h = go()
    assert len(h) > 0
    _assert_conservation(rt)
    for r in h:
        assert np.isfinite(r["loss"])
    if policy == "bsp":
        assert [r["step"] for r in h] == list(range(4))
    s = rt.tel.summary()
    assert s["n_netfaults"] == len(_chaos_schedule())
    assert s["n_reroutes"] + s["n_blackholes"] > 0
    # bitwise replay from the same (seed, schedule)
    rt2, h2 = go()
    assert h == h2
    assert rt.tel.events == rt2.tel.events


# ---------------------------------------------------------------------------
# acceptance: blackhole liveness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["bsp", "async"])
def test_permanent_partition_aborts_flows_not_the_run(api, policy,
                                                      chaos_forensics):
    """A rack partitioned forever (uplink + backup both down, never
    healed): its members' flows must abort via RTO backoff + blackhole
    detection — bounded sim time, flow_dead telemetry, no gather
    deadlock — while the surviving racks finish training."""
    sched = LinkFaultSchedule([LinkFaultEvent(0.08, "partition", "rack1")])
    rt = chaos_forensics(_rt(api, policy=policy, net_faults=sched, seed=3))
    h = _run(rt)                          # completing at all IS the pin
    assert len(h) > 0
    _assert_conservation(rt)
    dead = rt.tel.of("flow_dead")
    assert dead, "no flow_dead despite a permanent partition"
    # abort latency: blackhole detection is 6 consecutive backed-off
    # watchdog RTOs. Worst case is a flow that never saw an ACK (rtprop
    # unestimated -> 0.2s fallback base): 0.2*(1+2+4+8+16+16) = 9.4s.
    # Pinned at 12s of the cut so estimator drift can't flake the suite.
    assert min(e["t"] for e in dead) < 0.08 + 12.0
    assert rt.tel.summary()["n_flow_dead"] == len(dead)
    assert rt.net_des.flow_stats()["n_flow_dead"] > 0


# ---------------------------------------------------------------------------
# budget controller
# ---------------------------------------------------------------------------


def test_budget_controller_widens_under_distress_and_respects_floor(api):
    rt = _rt(api, policy="bsp", w=16, racks=4, n_ps=2, steps=4, seed=3,
             net_faults=_chaos_schedule(),
             budget=BudgetController(floor=0.7, step=0.1,
                                     interval_s=0.02))
    _run(rt, steps=4, w=16)
    moves = rt.tel.of("budget")
    assert moves, "chaos run produced no controller moves"
    assert any(m["direction"] == "widen" for m in moves)
    base = LTPConfig().data_pct_threshold
    for m in moves:
        assert 0.7 - 1e-9 <= m["pct"] <= base + 1e-9
    # actuation reached the transport
    assert all(0.7 - 1e-9 <= v <= base + 1e-9
               for v in rt.net_des.pct_eff)


def test_budget_controller_idle_on_clean_run(api):
    """No distress, thresholds already at the ceiling: the controller
    must not move (and the run must match the controller-free twin)."""
    base = _rt(api, policy="bsp")
    h0 = _run(base)
    rt = _rt(api, policy="bsp", budget=BudgetController(interval_s=0.05))
    h1 = _run(rt)
    assert rt.tel.of("budget") == []
    assert [r["loss"] for r in h0] == [r["loss"] for r in h1]


def test_budget_controller_requires_des(api):
    tc = TrainConfig(batch=4 * W, lr=0.05, steps=2)
    with pytest.raises(ValueError, match="transport='des'"):
        ClusterRuntime(api, make_optimizer(tc), tc, LTPConfig(), NET,
                       n_workers=W, transport="analytic",
                       budget=BudgetController())


def test_netfaults_require_des(api):
    tc = TrainConfig(batch=4 * W, lr=0.05, steps=2)
    with pytest.raises(ValueError, match="transport='des'"):
        ClusterRuntime(api, make_optimizer(tc), tc, LTPConfig(), NET,
                       n_workers=W, transport="analytic",
                       net_faults=LinkFaultSchedule([]))
