"""Metrics registry: counters / gauges / histograms (DESIGN.md §12).

The registry is the home for numbers that are *not* discrete runtime
events — cumulative protocol counters (retransmits, ACK trains,
generation-fence drops), instantaneous state (trunk queue depth), and
sampled distributions (queue-depth histograms). The §9 hot-path
discipline applies: instruments are pre-bound by their owner (an
attribute holding the ``Counter``; never a name lookup per event), a
``Counter.inc`` is one integer add, and anything that walks topology
state is sampled on the runtime's ``Sim.every`` wall grid, never per
packet/event.

``Histogram`` keeps a bounded reservoir (Vitter's Algorithm R, seeded
— same stream of observations, same reservoir) so quantiles over
millions of samples cost O(reservoir) memory and the sampling itself
stays O(1) amortized.

``MetricsRegistry.snapshot()`` flattens everything into plain floats —
the dict ``Tracker.log_summary`` ships at end of run.
"""
from __future__ import annotations

import random
from typing import Dict, List, Mapping, Optional

import numpy as np


class Counter:
    """Monotone cumulative count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0

    # replint: hotpath
    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0

    # replint: hotpath
    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Reservoir-sampled distribution (Algorithm R, seeded).

    ``observe`` is O(1): the first ``reservoir`` observations fill the
    buffer; afterwards observation ``i`` replaces a uniform slot with
    probability ``reservoir / i``. Count/sum/min/max are exact; the
    quantiles come from the reservoir.
    """

    __slots__ = ("name", "reservoir", "samples", "count", "total",
                 "vmin", "vmax", "_rng")

    def __init__(self, name: str, reservoir: int = 1024,
                 seed: int = 0) -> None:
        if reservoir < 1:
            raise ValueError("reservoir must be >= 1")
        self.name = name
        self.reservoir = reservoir
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        # stdlib RNG: ~3x cheaper than a numpy Generator for scalar draws
        self._rng = random.Random(seed)

    # replint: hotpath
    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if len(self.samples) < self.reservoir:
            self.samples.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.reservoir:
                self.samples[j] = v

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(self.samples, q))

    def snapshot(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.total / self.count,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Named instrument registry. ``counter``/``gauge``/``histogram``
    are get-or-create (same name -> same instrument), so independent
    subsystems can contribute to shared totals; ``absorb`` folds an
    external stats dict (``AggSwitch.stats()``, ``PERF.snapshot()``,
    transport flow stats) into counters/gauges in one call."""

    def __init__(self, reservoir: int = 1024, seed: int = 0) -> None:
        self._reservoir = reservoir
        self._seed = seed
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  reservoir: Optional[int] = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(
                name, reservoir or self._reservoir, seed=self._seed)
        return h

    def absorb(self, prefix: str, stats: Mapping[str, float],
               as_gauges: bool = False) -> None:
        """Fold a ``{name: number}`` stats dict in under ``prefix/``.
        Counters are *set* to the given cumulative value (the sources —
        pipe/sender/switch counters — are already cumulative); pass
        ``as_gauges=True`` for instantaneous values."""
        for k, v in stats.items():
            if not isinstance(v, (int, float)):
                continue
            if as_gauges:
                self.gauge(f"{prefix}/{k}").set(float(v))
            else:
                self.counter(f"{prefix}/{k}").value = int(v)

    def snapshot(self) -> Dict[str, float]:
        """Flatten every instrument to ``name -> float`` (histograms
        expand to ``name/count|mean|min|max|p50|p99``)."""
        out: Dict[str, float] = {}
        for name, c in self.counters.items():
            out[name] = c.value
        for name, g in self.gauges.items():
            out[name] = g.value
        for name, h in self.histograms.items():
            for k, v in h.snapshot().items():
                out[f"{name}/{k}"] = v
        return out
