"""Simulation scenarios mirroring the paper's evaluation setups, plus the
multi-PS / heterogeneous-worker extensions (DESIGN.md §5).

Paper scenarios:
  p2p_transfer     point-to-point goodput under loss        (Fig 4)
  incast_gather    W-to-1 gather; FCT tail / BST            (Fig 3, 14)
  train_iterations gather+broadcast loop -> BST + delivered fractions
                   (consumed by the training coupling; Fig 12/13)
  fairness_share   two flows on one bottleneck              (Fig 15)

Topology-engine scenarios (beyond the paper's single shared bottleneck):
  multi_ps_gather  sharded gather: n_ps parameter-server shards, one pipe
                   group (trunk) per PS; every worker sends 1/n_ps of the
                   model to each shard. n_ps=1 IS incast_gather.
  straggler_gather heterogeneous per-worker access links (rate/delay/loss
                   multipliers) feeding the shared trunk — bandwidth
                   stragglers, not just host-jitter start delays.
  cross_traffic    incast under open-loop background load on the trunk(s).

All gather-style scenarios run through one engine (``_run_gather``) driven
by a ``GatherSpec``; every scenario is registered in ``SCENARIOS`` and
runnable via ``run_scenario(name, protocol, net, **kw)``.

All scenarios use scaled transfer sizes (document the scale where used) —
event counts stay ~O(1e5-1e6) so full sweeps run in seconds on CPU.
Iterations carry warm CC state across rounds (persistent connections, as
real PS frameworks keep sockets open between batches).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.config import LTPConfig, NetConfig
from repro.net import senders as snd
from repro.net.aggtree import AggIngress, AggSwitch
from repro.net.ltp_receiver import (
    LTPFlowReceiver,
    ShardedGatherReceiver,
)
from repro.net.simcore import (
    CrossTrafficSource,
    Packet,
    Pipe,
    Sim,
    Topology,
)
from repro.net.topology import (       # noqa: F401  (GatherSpec re-exported)
    GatherSpec,
    as_topology,
    rack_spine,
)

PROTOCOLS = ("ltp", "bbr", "cubic", "reno")

# ----------------------------------------------------------------------------
# scenario registry
# ----------------------------------------------------------------------------

#: name -> callable(protocol, net, **kwargs). The sweep runner and the
#: training coupling both dispatch through this table.
SCENARIOS: Dict[str, Callable] = {}


def register_scenario(name: str):
    def deco(fn):
        SCENARIOS[name] = fn
        return fn
    return deco


def run_scenario(name: str, protocol: str, net: NetConfig, **kwargs):
    """Dispatch a registered scenario by name."""
    try:
        fn = SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; registered: {sorted(SCENARIOS)}"
        ) from None
    return fn(protocol, net, **kwargs)


def list_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


def _warm(sender, state: Optional[dict]):
    if not state:
        return
    if isinstance(sender, snd.LTPSender) or isinstance(sender, snd.BBRSender):
        est = sender.est
        est.rtprop = state.get("rtprop", est.rtprop)
        if state.get("btlbw", 0) > 0:
            est._bw_samples.append((sender.sim.now, state["btlbw"]))
            sender.startup = False
    else:
        # idle restart: slow-start back toward the previous operating point
        # (RFC 2861 style — cwnd resets, ssthresh remembers)
        sender.ssthresh = state.get("ssthresh", sender.ssthresh)
        sender.srtt = state.get("srtt", sender.srtt)


def _save_warm(sender) -> dict:
    if isinstance(sender, (snd.LTPSender, snd.BBRSender)):
        return {"rtprop": sender.est.rtprop, "btlbw": sender.est.btlbw}
    return {
        "ssthresh": max(sender.cwnd, sender.ssthresh)
        if math.isfinite(sender.ssthresh) else sender.cwnd,
        "srtt": sender.srtt,
    }


def _npkts(size_bytes: float, protocol: str) -> int:
    payload = snd.LTP_PAYLOAD if protocol == "ltp" else snd.MSS
    return max(1, int(math.ceil(size_bytes / payload)))


# ----------------------------------------------------------------------------
# p2p
# ----------------------------------------------------------------------------


@register_scenario("p2p_transfer")
def p2p_transfer(protocol: str, net: NetConfig, size_bytes: float,
                 seed: int = 0, warm: Optional[dict] = None) -> Dict:
    """One flow over one lossy link. Returns fct/goodput/utilization."""
    sim = Sim()
    rng = np.random.default_rng(seed)
    bw = net.bandwidth_gbps * 1e9
    fwd = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
               net.queue_pkts, rng)
    back = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
                10_000, rng)
    n = _npkts(size_bytes, protocol)
    done = {}

    def on_done(s):
        done["t"] = sim.now

    if protocol == "ltp":
        sender = snd.LTPSender(sim, fwd, None, n, rng=rng, on_done=on_done)
        recv = LTPFlowReceiver(sim, lambda p: back.send(p, sender.on_ack), 0)
        sender.deliver = lambda p: recv.on_data(p, lambda: None)
    else:
        sender = snd.make_sender(protocol, sim, fwd, None, n, rng=rng,
                                 on_done=on_done)
        recv = snd.TcpReceiver(sim, lambda p: back.send(p, sender.on_ack), 0)
        sender.deliver = recv.on_data
    _warm(sender, warm)
    sender.start()
    sim.run(until=3600.0)
    fct = done.get("t", sim.now) - 0.0
    goodput = size_bytes * 8.0 / max(fct, 1e-12)
    return {
        "fct": fct,
        "goodput_bps": goodput,
        "utilization": goodput / bw,
        "warm": _save_warm(sender),
    }


def utilization_cached(protocol: str, net: NetConfig, size_bytes: float = 4e6,
                       _cache={}) -> float:
    """Steady-state (warm-connection) p2p utilization at this transfer size."""
    key = (protocol, net.bandwidth_gbps, net.rtprop_ms, net.loss_rate,
           round(math.log2(max(size_bytes, 1e5))))
    if key not in _cache:
        warm = p2p_transfer(protocol, net, size_bytes)["warm"]
        _cache[key] = p2p_transfer(protocol, net, size_bytes, seed=1,
                                   warm=warm)["utilization"]
    return _cache[key]


# ----------------------------------------------------------------------------
# the gather engine (single-PS incast is the n_ps=1 special case)
# ----------------------------------------------------------------------------


# GatherSpec lives in repro.net.topology (DESIGN.md §11) and is
# re-imported above so `from repro.net.scenarios import GatherSpec`
# keeps working for existing call sites.


@dataclasses.dataclass
class GatherResult:
    bst_gather: float
    fcts: np.ndarray              # (W,) per-flow 100%-or-close time
    delivered: np.ndarray         # (W,) fraction delivered at close
    full_times: np.ndarray        # (W,) time to 100% (inf if early-closed)
    criticals_ok: bool
    per_ps_full: Optional[np.ndarray] = None   # (n_ps, W) per-shard 100% times
    packets_received: int = 0                  # payload packets at receiver(s)
    packets_expected: int = 0                  # n_ps * W * pkts-per-shard
    trunk_stats: Optional[Dict] = None         # Topology.stats() of the trunks
    # (n_ps, W, n) bool per-(shard, worker, packet) delivery state at close —
    # the exact mask shape the kernel-backed sync consumes (DESIGN.md §7).
    # All-True for reliable protocols.
    masks: Optional[np.ndarray] = None
    # summed AggSwitch.stats() over all (shard, rack) ToR aggregation
    # points (None when the topology has no in-network aggregation).
    agg_stats: Optional[Dict] = None


def _build_topology(sim: Sim, net: NetConfig, w: int, spec: GatherSpec,
                    rng: np.random.Generator, coalesce: int = 1,
                    ) -> Tuple[Topology, List[CrossTrafficSource]]:
    """PS trunks (one pipe group per shard) + optional worker access links
    + optional cross-traffic sources. Forward routes come from
    ``_fwd_path``; ack/return paths are built per flow by the caller.

    Hierarchical specs (DESIGN.md §11) add one oversubscribed uplink pipe
    per rack and — with ``inetwork_agg`` — one ``AggSwitch`` per
    (shard, rack) at the ToR, attached as ``topo.aggs``. Delay model:
    worker→spine-PS one-way = rtprop (uplink + trunk hop, half each);
    a shard homed in the worker's own rack skips the uplink hop.
    """
    bw = net.bandwidth_gbps * 1e9
    topo = Topology(sim)
    half_rtt = net.rtprop_ms * 1e-3 / 2
    for p in range(spec.n_ps):
        topo.add_pipe(f"ps{p}/trunk",
                      Pipe(sim, bw, half_rtt, net.loss_rate,
                           net.queue_pkts, rng),
                      group=f"ps{p}")
    if spec.heterogeneous:
        for f in range(w):
            rate, delay, loss = spec.access_params(f, net)
            topo.add_pipe(f"w{f}/up",
                          Pipe(sim, rate, delay, loss, net.queue_pkts, rng),
                          group="access")
    topo.aggs = {}
    if spec.hierarchical:
        spec.validate_workers(w, "gather")
        for r in range(spec.racks):
            topo.add_pipe(f"rack{r}/up",
                          Pipe(sim, spec.uplink_bps(net), half_rtt,
                               net.loss_rate, net.queue_pkts, rng),
                          group="uplink")
        if spec.inetwork_agg:
            hold_s = (spec.agg_hold_ms or 0.25 * net.rtprop_ms) * 1e-3
            for p in range(spec.n_ps):
                for r in range(spec.racks):
                    if spec.ps_rack(p) == r:
                        upstream = topo.route(f"ps{p}/trunk")
                    else:
                        upstream = topo.route(f"rack{r}/up", f"ps{p}/trunk")
                    topo.aggs[(p, r)] = AggSwitch(
                        sim, upstream, spec.rack_members(r), hold_s)
    sources: List[CrossTrafficSource] = []
    if spec.cross_traffic_load > 0:
        for p in range(spec.n_ps):
            src = CrossTrafficSource(
                sim, topo.pipes[f"ps{p}/trunk"], spec.cross_traffic_load,
                rng=rng, on_mean=spec.cross_on_ms * 1e-3,
                off_mean=spec.cross_off_ms * 1e-3, train_len=coalesce)
            sources.append(src)
            src.start()
    return topo, sources


def _fwd_path(topo: Topology, spec: GatherSpec, p: int, f: int,
              protocol: str = "ltp"):
    """Worker f's forward path to PS shard p.

    On rack fabrics with in-network aggregation, LTP flows enter through
    an ``AggIngress`` at their ToR (order-preserving protocols never
    aggregate — the switch cannot merge in-order byte streams, so they
    route over the raw uplink instead)."""
    if spec.hierarchical:
        r = spec.rack_of(f)
        access = topo.pipes[f"w{f}/up"] if spec.heterogeneous else None
        if spec.inetwork_agg and protocol == "ltp":
            return AggIngress(topo.aggs[(p, r)], f, access=access)
        names = [f"w{f}/up"] if spec.heterogeneous else []
        if spec.ps_rack(p) != r:
            names.append(f"rack{r}/up")
        names.append(f"ps{p}/trunk")
        return topo.route(*names)
    if spec.heterogeneous:
        return topo.route(f"w{f}/up", f"ps{p}/trunk")
    return topo.pipes[f"ps{p}/trunk"]


def _agg_stats(topo: Topology) -> Optional[Dict]:
    aggs = getattr(topo, "aggs", None)
    if not aggs:
        return None
    total: Dict[str, float] = {}
    for sw in aggs.values():
        for k, v in sw.stats().items():
            total[k] = total.get(k, 0) + v
    total["n_switches"] = len(aggs)
    return total


def _run_gather(protocol: str, net: NetConfig, w: int, size_bytes: float,
                rng: np.random.Generator,
                warm: Optional[List[List[Optional[dict]]]],
                lt: np.ndarray, deadline: np.ndarray, pct_thresh: float,
                critical_frac: float = 0.01,
                start_delays: Optional[np.ndarray] = None,
                spec: Optional[GatherSpec] = None,
                coalesce: int = 1,
                ) -> Tuple[GatherResult, List[List[dict]]]:
    """One gather round over the topology in ``spec``.

    Returns (result, warm_states[n_ps][w]). ``size_bytes`` is the FULL
    model size; each of the n_ps shards carries size_bytes/n_ps.
    ``lt``/``deadline`` are per-shard (n_ps,) thresholds.

    ``start_delays``: per-worker start offsets modelling host-side
    stragglers (GC pauses, CPU contention, slow gradient production) —
    the source of the paper's Fig-3 "starved flows" beyond pure protocol
    dynamics. A worker's delay applies to all of its shard flows.

    ``coalesce`` > 1 turns on the packet-train engine (DESIGN.md §7):
    senders emit trains of up to ``coalesce`` packets per heap event, the
    receivers acknowledge per train, and cross-traffic bursts inject in
    chunks — ~coalesce x fewer events for the same simulated traffic.
    ``coalesce=1`` is the per-packet reference path. BBR ignores it (its
    pacing clock is inherently per-packet).
    """
    spec = as_topology(spec or GatherSpec())
    n_ps = spec.n_ps
    coalesce = max(1, int(coalesce))
    sim = Sim()
    bw = net.bandwidth_gbps * 1e9
    topo, sources = _build_topology(sim, net, w, spec, rng, coalesce)
    n = _npkts(size_bytes / n_ps, protocol)   # packets per shard flow
    senders: Dict[Tuple[int, int], object] = {}
    half_rtt = net.rtprop_ms * 1e-3 / 2

    def stop_sources():
        for src in sources:
            src.stop()

    # safeguard: background load dies out well past the slowest deadline so
    # a pathological round cannot spin the event loop for simulated hours
    if sources:
        d_max = (float(np.max(start_delays)) if start_delays is not None
                 else 0.0)
        sim.at(d_max + 10.0 * float(np.max(deadline)) + 1e-3, stop_sources)

    if protocol == "ltp":
        crit = np.zeros(n, bool)
        ncrit = max(2, int(critical_frac * n))
        crit[: ncrit // 2] = True
        crit[-(ncrit - ncrit // 2):] = True
        stops: Dict[Tuple[int, int], Callable[[], None]] = {}

        def send_stop(p, f):
            stops[(p, f)]()

        sharded = ShardedGatherReceiver(
            sim, n_ps, list(range(w)), [float(x) for x in lt],
            [float(x) for x in deadline], pct_thresh, send_stop)
        n_done = [0]

        def flow_stopped():
            n_done[0] += 1
            if n_done[0] >= n_ps * w:
                stop_sources()

        for p in range(n_ps):
            shard = sharded.shard(p)
            for f in range(w):
                back = Pipe(sim, bw, half_rtt, net.loss_rate, 10_000, rng)
                s = snd.LTPSender(sim, _fwd_path(topo, spec, p, f, "ltp"),
                                  shard.on_data, n, critical=crit,
                                  flow=f, rng=rng,
                                  on_done=lambda s: flow_stopped(),
                                  train_len=coalesce)
                shard.attach_ack(f, lambda pkt, s=s, back=back:
                                 back.send(pkt, s.on_ack))
                if coalesce > 1:
                    s.deliver_train = shard.on_data_train
                    shard.attach_ack_train(
                        f, lambda acks, s=s, back=back:
                        back.send_train(acks, s.on_ack_train))
                stops[(p, f)] = (lambda s=s, back=back: back.send(
                    Packet(s.flow, -2, 41, kind="stop"), s.on_ack))
                _warm(s, warm[p][f] if warm else None)
                senders[(p, f)] = s
        for (p, f), s in senders.items():
            d = float(start_delays[f]) if start_delays is not None else 0.0
            sim.at(d, s.start)
        sim.run(until=3600.0)
        res = GatherResult(
            bst_gather=sharded.bst_gather(),
            fcts=np.minimum(sharded.full_times(), sharded.bst_gather()),
            delivered=sharded.delivered_fracs(),
            full_times=sharded.full_times(),
            criticals_ok=sharded.criticals_done,
            per_ps_full=sharded.per_shard_full_times(),
            packets_received=sharded.payload_packets_received(),
            packets_expected=n_ps * w * n,
            trunk_stats=topo.stats(),
            masks=sharded.delivery_masks(),
            agg_stats=_agg_stats(topo),
        )
        return res, [[_save_warm(senders[(p, f)]) for f in range(w)]
                     for p in range(n_ps)]

    # order-preserving protocols: reliable, BST = max FCT
    fcts = np.full((n_ps, w), np.inf)
    receivers = []
    n_done = [0]
    for p in range(n_ps):
        for f in range(w):
            back = Pipe(sim, bw, half_rtt, net.loss_rate, 10_000, rng)

            def on_done(s, p=p, f=f):
                fcts[p, f] = sim.now
                n_done[0] += 1
                if n_done[0] >= n_ps * w:
                    stop_sources()

            s = snd.make_sender(protocol, sim,
                                _fwd_path(topo, spec, p, f, protocol),
                                None, n, flow=f, rng=rng, on_done=on_done,
                                train_len=coalesce)
            r = snd.TcpReceiver(
                sim, lambda pkt, s=s, back=back: back.send(pkt, s.on_ack), f)
            s.deliver = r.on_data
            if coalesce > 1:
                s.deliver_train = r.on_data_train
                r.send_ack_train = (lambda acks, s=s, back=back:
                                    back.send_train(acks, s.on_ack_train))
            # registration so the receiver knows flow length
            _warm(s, warm[p][f] if warm else None)
            senders[(p, f)] = s
            receivers.append(r)
    for r in receivers:
        r.n_total = n
    for (p, f), s in senders.items():
        d = float(start_delays[f]) if start_delays is not None else 0.0
        sim.at(d, s.start)
    sim.run(until=3600.0)
    fin = np.where(np.isfinite(fcts), fcts, sim.now)
    per_worker = fin.max(axis=0)
    res = GatherResult(
        bst_gather=float(per_worker.max()),
        fcts=per_worker,
        delivered=np.ones(w),
        full_times=fcts.max(axis=0),
        criticals_ok=True,
        per_ps_full=fcts,
        packets_received=sum(len(r.received) for r in receivers),
        packets_expected=n_ps * w * n,
        trunk_stats=topo.stats(),
        masks=np.ones((n_ps, w, n), bool),   # reliable: everything lands
    )
    return res, [[_save_warm(senders[(p, f)]) for f in range(w)]
                 for p in range(n_ps)]


def _iterate_gather(protocol: str, net: NetConfig, w: int, size_bytes: float,
                    iters: int, ltp: Optional[LTPConfig], seed: int,
                    straggler_prob: float, straggler_scale: float,
                    spec: Optional[GatherSpec] = None,
                    coalesce: int = 1) -> List[GatherResult]:
    """Repeated gather rounds with per-(shard, link) Early Close adaptation.

    Host-jitter stragglers: with prob ``straggler_prob`` a worker starts
    its flows late by Exp(straggler_scale * ECT) (the paper's Fig-3
    "starved flows"). Bandwidth stragglers come from ``spec``.
    """
    ltp = ltp or LTPConfig()
    spec = spec or GatherSpec()
    n_ps = spec.n_ps
    rng = np.random.default_rng(seed)
    shard_bytes = size_bytes / n_ps
    rt = net.rtprop_ms * 1e-3
    bw_share = net.bandwidth_gbps * 1e9 / 8.0 / w
    ect = rt + shard_bytes / bw_share
    # per-(shard, link) LT init: the paper's formula with each link's own
    # attainable share (slow access links start with larger thresholds)
    lt = np.empty((n_ps, w))
    for f in range(w):
        share = spec.worker_share_bps(f, w, net) / 8.0   # bytes/s
        lt[:, f] = ltp.lt_init_rtprop_mult * rt + shard_bytes / share
    results: List[GatherResult] = []
    warm: Optional[List[List[Optional[dict]]]] = None
    best_full = np.full((n_ps, w), np.inf)
    iters_per_epoch = max(1, iters // 3)
    for i in range(iters):
        delays = np.where(
            rng.random(w) < straggler_prob,
            rng.exponential(straggler_scale * ect, w),
            0.0,
        )
        deadline = lt.max(axis=1) + ltp.deadline_c_ms * 1e-3   # (n_ps,)
        res, warm = _run_gather(protocol, net, w, size_bytes, rng, warm,
                                lt.max(axis=1), deadline,
                                ltp.data_pct_threshold,
                                start_delays=delays, spec=spec,
                                coalesce=coalesce)
        results.append(res)
        pfull = res.per_ps_full if res.per_ps_full is not None else \
            res.full_times[None, :]
        ok = np.isfinite(pfull)
        best_full[ok] = np.minimum(best_full[ok], pfull[ok])
        if (i + 1) % iters_per_epoch == 0:   # epoch boundary: update LT
            upd = np.isfinite(best_full)
            lt[upd] = best_full[upd]
            if not upd.all():
                # some link never reached 100% (early-closed every round):
                # re-apply the paper's ECT formula with the *measured*
                # per-link BtlBw (repro extension, cf. paper §VI-B)
                for p, f in zip(*np.nonzero(~upd)):
                    btlbw = (warm[p][f] or {}).get("btlbw", 0.0) / 8.0
                    if btlbw > 0:
                        lt[p, f] = (ltp.lt_init_rtprop_mult * rt
                                    + shard_bytes / btlbw)
            best_full[:] = np.inf
    return results


# ----------------------------------------------------------------------------
# registered gather scenarios
# ----------------------------------------------------------------------------


@register_scenario("incast_gather")
def incast_gather(protocol: str, net: NetConfig, w: int, size_bytes: float,
                  iters: int = 10, ltp: Optional[LTPConfig] = None,
                  seed: int = 0, straggler_prob: float = 0.15,
                  straggler_scale: float = 0.6,
                  coalesce: int = 1) -> List[GatherResult]:
    """The paper's W-to-1 incast gather with Early Close adaptation —
    the n_ps=1 homogeneous case of the gather engine."""
    return _iterate_gather(protocol, net, w, size_bytes, iters, ltp, seed,
                           straggler_prob, straggler_scale, GatherSpec(),
                           coalesce=coalesce)


@register_scenario("multi_ps_gather")
def multi_ps_gather(protocol: str, net: NetConfig, w: int, size_bytes: float,
                    n_ps: int = 2, iters: int = 10,
                    ltp: Optional[LTPConfig] = None, seed: int = 0,
                    straggler_prob: float = 0.15,
                    straggler_scale: float = 0.6,
                    coalesce: int = 1) -> List[GatherResult]:
    """Sharded gather over n_ps parameter-server shards (DESIGN.md §5).

    The model splits evenly: each worker sends size/n_ps to every shard,
    each shard sits behind its own trunk (pipe group) and runs its own
    Early Close state. By construction n_ps=1 is ``incast_gather``.
    """
    return _iterate_gather(protocol, net, w, size_bytes, iters, ltp, seed,
                           straggler_prob, straggler_scale,
                           GatherSpec(n_ps=n_ps), coalesce=coalesce)


@register_scenario("straggler_gather")
def straggler_gather(protocol: str, net: NetConfig, w: int, size_bytes: float,
                     iters: int = 6, ltp: Optional[LTPConfig] = None,
                     seed: int = 0, n_slow: int = 0,
                     slow_rate_mult: float = 0.25,
                     slow_delay_ms: float = 0.0,
                     n_ps: int = 1, coalesce: int = 1) -> List[GatherResult]:
    """Bandwidth stragglers: the last ``n_slow`` workers (default w//4,
    at least 1) attach through access links at ``slow_rate_mult`` x the
    trunk rate (+ optional extra delay). Early-Close LT thresholds adapt
    per link, so LTP closes around the stragglers while order-preserving
    protocols wait for their last byte.
    """
    n_slow = n_slow or max(1, w // 4)
    mult = np.ones(w)
    mult[w - n_slow:] = slow_rate_mult
    delay = np.zeros(w)
    delay[w - n_slow:] = slow_delay_ms
    spec = GatherSpec(n_ps=n_ps, worker_rate_mult=mult,
                      worker_delay_ms=delay if slow_delay_ms else None)
    return _iterate_gather(protocol, net, w, size_bytes, iters, ltp, seed,
                           0.0, 0.0, spec, coalesce=coalesce)


@register_scenario("topology_gather")
def topology_gather(protocol: str, net: NetConfig, w: int, size_bytes: float,
                    topology: Optional[GatherSpec] = None, iters: int = 4,
                    ltp: Optional[LTPConfig] = None, seed: int = 0,
                    straggler_prob: float = 0.0, straggler_scale: float = 0.0,
                    coalesce: int = 1) -> List[GatherResult]:
    """Gather over an arbitrary ``repro.net.topology`` builder result —
    the generic topology-first entry point (DESIGN.md §11): flat,
    multi-PS, and rack/spine (with or without in-network aggregation)
    all run through the one engine."""
    spec = as_topology(topology) if topology is not None else None
    return _iterate_gather(protocol, net, w, size_bytes, iters, ltp, seed,
                           straggler_prob, straggler_scale, spec,
                           coalesce=coalesce)


@register_scenario("rack_spine_gather")
def rack_spine_gather(protocol: str, net: NetConfig, size_bytes: float,
                      racks: int = 4, workers_per_rack: int = 8,
                      oversub: float = 4.0, n_ps: int = 1, agg: bool = True,
                      agg_hold_ms: float = 0.0, iters: int = 4,
                      ltp: Optional[LTPConfig] = None, seed: int = 0,
                      straggler_prob: float = 0.0,
                      straggler_scale: float = 0.0, coalesce: int = 1,
                      w: Optional[int] = None) -> List[GatherResult]:
    """Rack/spine gather sugar over ``topology_gather``: ToR-attached
    workers, oversubscribed uplinks, optional in-network aggregation
    (DESIGN.md §11). ``w`` is implied by the rack grid."""
    spec = rack_spine(racks, workers_per_rack, oversub=oversub, n_ps=n_ps,
                      agg=agg, agg_hold_ms=agg_hold_ms)
    if w is not None:
        spec.validate_workers(w, "rack_spine_gather")
    return _iterate_gather(protocol, net, spec.n_workers, size_bytes, iters,
                           ltp, seed, straggler_prob, straggler_scale, spec,
                           coalesce=coalesce)


@register_scenario("cross_traffic")
def cross_traffic(protocol: str, net: NetConfig, w: int, size_bytes: float,
                  iters: int = 6, ltp: Optional[LTPConfig] = None,
                  seed: int = 0, bg_load: float = 0.5,
                  on_ms: float = 5.0, off_ms: float = 5.0,
                  n_ps: int = 1, coalesce: int = 1) -> List[GatherResult]:
    """Incast gather competing with open-loop background traffic on the
    trunk(s): other tenants' flows crossing the same ToR egress. The
    background load is never ACKed or retransmitted (pure interference);
    ``bg_load`` is the offered fraction of line rate during ON bursts.
    """
    spec = GatherSpec(n_ps=n_ps, cross_traffic_load=bg_load,
                      cross_on_ms=on_ms, cross_off_ms=off_ms)
    return _iterate_gather(protocol, net, w, size_bytes, iters, ltp, seed,
                           0.0, 0.0, spec, coalesce=coalesce)


# ----------------------------------------------------------------------------
# full training-iteration loop (gather + broadcast)
# ----------------------------------------------------------------------------


@register_scenario("train_iterations")
def train_iterations(protocol: str, net: NetConfig, w: int, model_bytes: float,
                     iters: int = 10, ltp: Optional[LTPConfig] = None,
                     seed: int = 0, scale: float = 1.0,
                     scenario: str = "incast_gather", n_ps: int = 1,
                     **scenario_kw) -> Dict:
    """Gather (simulated, possibly Early-Closed) + broadcast (reliable,
    one-to-many — modeled via measured p2p utilization since it has no
    incast contention). ``scale`` < 1 simulates a scaled-down model size
    and rescales times back up (documented wherever used).

    ``scenario`` picks any registered gather scenario for the gathering
    leg (``multi_ps_gather``, ``straggler_gather``, ``cross_traffic``);
    extra kwargs pass through. ``n_ps`` governs BOTH legs: it is
    forwarded to scenarios that shard (so gather and broadcast always
    agree), and with n_ps shards the broadcast parallelizes — each PS
    broadcasts its 1/n_ps of the model over its own trunk.
    """
    import inspect
    size = model_bytes * scale
    fn = SCENARIOS[scenario]
    if scenario_kw.get("topology") is not None:
        # topology-first path (DESIGN.md §11): the Topology carries the
        # shard count for both legs
        if n_ps != 1 and n_ps != scenario_kw["topology"].n_ps:
            raise ValueError(
                f"n_ps={n_ps} contradicts topology.n_ps="
                f"{scenario_kw['topology'].n_ps}; drop the n_ps kwarg")
        n_ps = scenario_kw["topology"].n_ps
    elif "n_ps" in inspect.signature(fn).parameters:
        scenario_kw.setdefault("n_ps", n_ps)
        n_ps = int(scenario_kw["n_ps"])
    elif n_ps != 1:
        raise ValueError(
            f"scenario {scenario!r} does not take n_ps; use "
            f"scenario='multi_ps_gather' (or another sharding scenario) "
            f"for n_ps={n_ps}")
    gs = run_scenario(scenario, protocol, net, w=w, size_bytes=size,
                      iters=iters, ltp=ltp, seed=seed, **scenario_kw)
    util = utilization_cached(protocol, net, size_bytes=max(4e6, w * size))
    bcast = (net.rtprop_ms * 1e-3
             + w * size / n_ps
             / (net.bandwidth_gbps * 1e9 / 8.0 * max(util, 1e-3)))
    bst = np.array([g.bst_gather + bcast for g in gs]) / scale
    delivered = np.stack([g.delivered for g in gs])
    # (iters, W, n_ps * n) bool: each worker's full-model packet stream is
    # the concatenation of its per-shard streams — the delivery masks the
    # kernel-backed sync consumes (PSTrainer(mask_trace=...), DESIGN.md §7)
    masks = None
    if all(g.masks is not None for g in gs):
        masks = np.stack([np.concatenate(list(g.masks), axis=1) for g in gs])
    return {
        "bst": bst,
        "bst_gather": np.array([g.bst_gather for g in gs]) / scale,
        "bst_broadcast": bcast / scale,
        "delivered": delivered,
        "fct_all": np.concatenate([g.fcts for g in gs]) / scale,
        "delivery_masks": masks,
    }


# ----------------------------------------------------------------------------
# fairness
# ----------------------------------------------------------------------------


def fairness_share(proto_a: str, proto_b: str, net: NetConfig,
                   duration: float = 2.0, seed: int = 0) -> Tuple[float, float]:
    """Two long flows share the bottleneck; returns (bytes_a, bytes_b)
    normalized shares over ``duration``."""
    sim = Sim()
    rng = np.random.default_rng(seed)
    bw = net.bandwidth_gbps * 1e9
    bottleneck = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
                      net.queue_pkts, rng)
    delivered = {0: 0, 1: 0}
    sender_objs = []
    for f, proto in enumerate((proto_a, proto_b)):
        n = 10_000_000  # effectively infinite
        back = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate, 10_000, rng)
        if proto == "ltp":
            s = snd.LTPSender(sim, bottleneck, None, n, rng=rng, flow=f)
            r = LTPFlowReceiver(sim, lambda p, s=s, back=back: back.send(p, s.on_ack), f)
            def deliver(p, r=r, f=f):
                if p.kind == "data":
                    delivered[f] += p.size
                r.on_data(p, lambda: None)
            s.deliver = deliver
        else:
            s = snd.make_sender(proto, sim, bottleneck, None, n, flow=f,
                                rng=rng)
            r = snd.TcpReceiver(sim, lambda p, s=s, back=back: back.send(p, s.on_ack), f)
            def deliver(p, r=r, f=f):
                if p.kind == "data":
                    delivered[f] += p.size
                r.on_data(p)
            s.deliver = deliver
        sender_objs.append(s)
    for s in sender_objs:
        s.start()
    sim.run(until=duration)
    tot = delivered[0] + delivered[1]
    if tot == 0:
        return 0.5, 0.5
    return delivered[0] / tot, delivered[1] / tot


# registry adapter: the competing protocol rides in as a kwarg
SCENARIOS["fairness_share"] = (
    lambda protocol, net, proto_b="cubic", **kw:
        fairness_share(protocol, proto_b, net, **kw))
