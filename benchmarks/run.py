"""Benchmark harness — one module per paper table/figure + the roofline.

  python -m benchmarks.run            # quick mode (CI-sized)
  python -m benchmarks.run --full     # paper-sized sweeps
  python -m benchmarks.run --only fig4_loss_tolerance

Output: CSV-ish lines `<figure>,<k>=<v>,...` on stdout and JSON blobs in
benchmarks/results/.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (
    fault_sweep,
    fig3_incast_fct,
    fig4_loss_tolerance,
    fig5_randomk_topk,
    fig12_throughput,
    fig13_tta,
    fig15_fairness,
    kernel_bench,
    roofline,
    runtime_sweep,
    sweep_scenarios,
)

MODULES = {
    "fig3_14_incast_fct_bst": fig3_incast_fct,
    "fig4_loss_tolerance": fig4_loss_tolerance,
    "fig5_randomk_topk": fig5_randomk_topk,
    "fig12_throughput": fig12_throughput,
    "fig13_tta": fig13_tta,
    "fig15_fairness": fig15_fairness,
    "roofline": roofline,
    "scenario_sweep": sweep_scenarios,
    "kernel_bench": kernel_bench,
    "runtime_sweep": runtime_sweep,
    "fault_sweep": fault_sweep,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    names = [args.only] if args.only else list(MODULES)
    for name in names:
        t0 = time.time()
        print(f"### {name} (quick={not args.full})", flush=True)
        MODULES[name].run(quick=not args.full)
        print(f"### {name} done in {time.time()-t0:.1f}s", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
