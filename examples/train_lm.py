"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
LTP-synced gradients (deliverable b).

The model is the smollm-360m family at ~100M scale; data is the synthetic
bigram corpus (loss floor = chain entropy, so the curve shows real
learning). Gradient sync uses the Early-Close controller + packet masks;
checkpoints are written at the end.

  PYTHONPATH=src python examples/train_lm.py --steps 300 [--tiny]
"""
import argparse
import time

import jax
import numpy as np

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.checkpoint import save_checkpoint
from repro.data import SyntheticLM
from repro.models import build
from repro.optim import make_optimizer
from repro.train import PSTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="2-layer model for a fast demo run")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    base = get_config("smollm_360m")
    if args.tiny:
        cfg = base.replace(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                           head_dim=32, d_ff=256, vocab=512)
    else:
        # ~100M params: 12 layers of d_model 768
        cfg = base.replace(n_layers=12, d_model=768, n_heads=12, n_kv=4,
                           head_dim=64, d_ff=2048, vocab=8192)
    cfg = cfg.replace(dtype="float32")
    api = build(cfg)
    n_params = sum(
        int(np.prod(x.shape)) for x in jax.tree.leaves(
            jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0))))
    )
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    lm = SyntheticLM(vocab=cfg.vocab, seed=0)
    print(f"bigram entropy floor: {lm.entropy_floor:.3f} nats "
          f"(init loss ~ {np.log(cfg.vocab):.3f})")

    tc = TrainConfig(batch=args.batch, seq=args.seq, lr=3e-4,
                     optimizer="adamw", steps=args.steps)
    net = NetConfig(10, 1, 0.001, 4096)
    tr = PSTrainer(api, make_optimizer(tc), tc, LTPConfig(), net,
                   n_workers=args.workers, protocol="ltp",
                   compute_time=0.05, seed=0)

    def gen():
        for step in range(args.steps):
            yield lm.train_batch(args.batch, args.seq, step)

    t0 = time.time()
    tr.run(gen(), epoch_steps=100, log_every=10)
    print(f"wall {time.time()-t0:.0f}s, simulated {tr.sim_time:.0f}s, "
          f"final loss {tr.history[-1]['loss']:.4f} "
          f"(floor {lm.entropy_floor:.3f})")
    save_checkpoint(args.ckpt, tr.params, step=tr.step_idx)
    print(f"checkpoint -> {args.ckpt}.npz")


if __name__ == "__main__":
    main()
