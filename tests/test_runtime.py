"""Cluster runtime (DESIGN.md §8): bsp/legacy equivalence, async & SSP
aggregation under stragglers, compute models, staleness-weighted
reduction, DES co-simulation, and truncation safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.core import ltp_sync as ls
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.optim import make_optimizer
from repro.runtime import (
    ClusterRuntime,
    DeterministicCompute,
    LognormalStragglerCompute,
    TraceCompute,
    make_compute_model,
    make_policy,
)
from repro.runtime.policies import AsyncPolicy, BSPPolicy, PendingGrad, SSPPolicy
from repro.train import PSTrainer

W = 4
STEPS = 5
NET = NetConfig(10, 1, 0.001, 4096)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    tc = TrainConfig(batch=32, lr=0.05, steps=STEPS)
    return api, tc


def _data():
    return batches(SyntheticCIFAR(seed=0), 32, STEPS)


def _trainer(api, tc, engine, protocol="ltp", **kw):
    return PSTrainer(api, make_optimizer(tc), tc, LTPConfig(), NET,
                     n_workers=W, protocol=protocol, compute_time=0.05,
                     seed=0, engine=engine, **kw)


def _runtime(api, tc, policy, protocol="ltp", transport="analytic",
             ltp=None, **kw):
    return ClusterRuntime(api, make_optimizer(tc), tc, ltp or LTPConfig(),
                          NET, n_workers=W, protocol=protocol,
                          policy=policy, compute_time=0.05, seed=0,
                          transport=transport, **kw)


# ---------------------------------------------------------------------------
# acceptance: bsp under the runtime == legacy lockstep PSTrainer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", ["ltp", "cubic"])
def test_bsp_matches_legacy_lockstep(setup, protocol):
    """Same seed, same masks -> per-iteration records and final params
    match the legacy loop to float tolerance (they are bitwise-identical
    in practice: same fused step, same RNG streams)."""
    api, tc = setup
    legacy = _trainer(api, tc, "lockstep", protocol)
    h1 = legacy.run(_data(), epoch_steps=3)
    rt = _trainer(api, tc, "runtime", protocol)
    assert rt.engine == "runtime" and rt._rt is not None
    h2 = rt.run(_data(), epoch_steps=3)
    assert len(h1) == len(h2) == STEPS
    for a, b in zip(h1, h2):
        assert a["step"] == b["step"]
        for k in ("loss", "bst", "delivered", "sim_time"):
            np.testing.assert_allclose(a[k], b[k], rtol=1e-6, atol=1e-9)
    for x, y in zip(jax.tree_util.tree_leaves(legacy.params),
                    jax.tree_util.tree_leaves(rt.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-8)


def test_trace_inputs_fall_back_to_lockstep(setup):
    api, tc = setup
    tr = _trainer(api, tc, "runtime", bst_trace=np.array([0.01, 0.02]))
    assert tr.engine == "lockstep" and tr._rt is None
    h = tr.run(_data())
    assert [r["bst"] for r in h[:2]] == [0.01, 0.02]


# ---------------------------------------------------------------------------
# acceptance: async / ssp reduce sim time vs bsp under lognormal stragglers
# ---------------------------------------------------------------------------


def test_async_and_ssp_beat_bsp_under_stragglers(setup):
    api, tc = setup
    compute = LognormalStragglerCompute(W, base=0.05, sigma=0.3,
                                        straggler_prob=0.25,
                                        straggler_mult=5.0, seed=7)
    times = {}
    for policy in ("bsp", "async", "ssp"):
        kw = {"policy_kw": {"staleness": 2}} if policy == "ssp" else {}
        rt = _runtime(api, tc, policy, compute_model=compute, **kw)
        rt.run(_data(), epoch_steps=3)
        times[policy] = rt.sim_time
        assert all(np.isfinite(r["loss"]) for r in rt.history)
        if policy == "bsp":
            assert len(rt.history) == STEPS
            assert rt.tel.summary()["blocked_s"] > 0   # barrier waits
        else:
            # apply-on-arrival: one record per admitted batch, covering
            # every non-dropped worker-iteration gradient
            applied = sum(r["n_grads"] for r in rt.history)
            assert applied == W * STEPS - rt.tel.summary()["n_stale_drops"]
    assert times["async"] < times["bsp"]
    assert times["ssp"] < times["bsp"]


def test_ssp_staleness_bound_and_drops(setup):
    api, tc = setup
    compute = LognormalStragglerCompute(W, base=0.05, sigma=0.4,
                                        straggler_prob=0.4,
                                        straggler_mult=6.0, seed=3)
    k = 1
    rt = _runtime(api, tc, "ssp", compute_model=compute,
                  policy_kw={"staleness": k},
                  ltp=LTPConfig(staleness_comp=0.5))
    rt.run(_data())
    s = rt.tel.summary()
    # admitted gradients never exceed the bound; over-stale ones are
    # counted out, not silently folded in
    assert s["staleness_max"] <= k
    for e in rt.tel.of("stale_drop"):
        assert e["staleness"] > k


def test_async_staleness_recorded_and_weighted(setup):
    api, tc = setup
    compute = LognormalStragglerCompute(W, base=0.05, sigma=0.3,
                                        straggler_prob=0.3,
                                        straggler_mult=5.0, seed=11)
    rt = _runtime(api, tc, "async", compute_model=compute)
    rt.run(_data())
    stale = [e["staleness"] for e in rt.tel.of("grad_arrived")]
    assert max(stale) >= 1          # stragglers really produce staleness
    assert rt.tel.summary()["n_applies"] == len(rt.history)


# ---------------------------------------------------------------------------
# policies (pure unit)
# ---------------------------------------------------------------------------


def _grad(worker, it, staleness=0):
    return PendingGrad(worker=worker, iteration=it, t_ready=0.0,
                       staleness=staleness, payload={"frac": 1.0})


def test_bsp_policy_barrier():
    p = make_policy("bsp")
    p.bind(3)
    assert p.may_start(0, 0) and not p.may_start(0, 1)
    p.on_arrival(_grad(0, 0))
    p.on_arrival(_grad(2, 0))
    assert p.ready() == [] and p.pending_count() == 2
    p.on_arrival(_grad(1, 0))
    batch = p.ready()
    assert [g.worker for g in batch] == [0, 1, 2]
    p.on_applied(batch)
    assert p.committed == 1 and p.may_start(0, 1)


def test_ssp_policy_bound_ordering_and_drops():
    p = make_policy("ssp", staleness=0, staleness_comp=0.5)
    p.bind(2)
    assert isinstance(p, SSPPolicy)
    assert p.may_start(0, 0)
    p.on_start(0, 0)
    # worker 1 has not started iteration 0 yet -> worker 0 is gated
    assert not p.may_start(0, 1)
    p.on_start(1, 0)
    assert p.may_start(0, 1)
    p.on_arrival(_grad(0, 1, staleness=0))
    p.on_arrival(_grad(1, 0, staleness=0))
    p.on_arrival(_grad(1, 0, staleness=1))       # over the bound
    batch = p.ready()
    # MLFabric-style admission ordering: oldest iteration first
    assert [(g.worker, g.iteration) for g in batch] == [(1, 0), (0, 1)]
    assert len(p.drained_stale()) == 1 and p.drained_stale() == []
    # staleness-damped weights (LTPConfig.staleness_comp wiring)
    p2 = make_policy("ssp", staleness=2, staleness_comp=0.5)
    p2.bind(2)
    w = p2.weights([_grad(0, 0, staleness=1), _grad(1, 1, staleness=0)])
    np.testing.assert_allclose(w, [1 / 1.5, 1.0])
    # staleness_comp=0 -> uniform (classic SSP reduction)
    assert make_policy("ssp", staleness=2).weights([_grad(0, 0, 1)]) is None


def test_async_policy_never_blocks():
    p = make_policy("async", damping=1.0)
    p.bind(2)
    assert isinstance(p, AsyncPolicy)
    assert p.may_start(0, 99)
    p.on_arrival(_grad(0, 5, staleness=3))
    batch = p.ready()
    assert len(batch) == 1 and p.ready() == []
    np.testing.assert_allclose(p.weights(batch), [0.25])


def test_make_policy_rejects_unknown():
    with pytest.raises(ValueError, match="unknown aggregation policy"):
        make_policy("2pc")
    bsp = BSPPolicy()
    assert make_policy(bsp) is bsp


# ---------------------------------------------------------------------------
# compute models
# ---------------------------------------------------------------------------


def test_compute_models():
    det = DeterministicCompute(3, base=0.1, mults=[1.0, 2.0, 4.0])
    assert det.sample(2, 9) == pytest.approx(0.4)
    ln1 = LognormalStragglerCompute(3, base=0.05, seed=5)
    ln2 = LognormalStragglerCompute(3, base=0.05, seed=5)
    draws = [ln1.sample(w, i) for w in range(3) for i in range(4)]
    assert draws == [ln2.sample(w, i) for w in range(3) for i in range(4)]
    assert len(set(draws)) == len(draws)          # per-(w, i) independence
    tr = TraceCompute(2, trace=[[0.1, 0.2], [0.3, 0.4]])
    assert tr.sample(1, 0) == 0.2
    assert tr.sample(0, 3) == 0.3                 # tiled modulo len(trace)
    bc = TraceCompute(2, trace=[0.1, 0.2])        # 1-D broadcasts
    assert bc.sample(1, 1) == 0.2
    m = make_compute_model(None, 4, base=0.07)
    assert isinstance(m, DeterministicCompute) and m.sample(0, 0) == 0.07
    with pytest.raises(ValueError, match="unknown compute model"):
        make_compute_model("gamma", 4)
    with pytest.raises(ValueError):
        TraceCompute(3, trace=[[0.1, 0.2]])


# ---------------------------------------------------------------------------
# staleness-weighted reduction (core/ltp_sync + config wiring)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp", ["paper", "count", "expected"])
def test_reduce_packet_stream_worker_weights(comp):
    rng = np.random.default_rng(0)
    pkts = jnp.asarray(rng.normal(size=(3, 6, 16)).astype(np.float32))
    masks = jnp.asarray((rng.random((3, 6)) < 0.7).astype(np.float32))
    wts = jnp.asarray([1.0, 0.5, 0.25])
    ltp = LTPConfig(compensation=comp)
    got = ls.reduce_packet_stream(pkts, masks, ltp, 3, expected_frac=0.7,
                                  worker_weights=wts, backend="python")
    # a weight scales the worker's gradient exactly
    ref = ls.reduce_packet_stream(pkts * wts[:, None, None], masks, ltp, 3,
                                  expected_frac=0.7, backend="python")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-7)
    ker = ls.reduce_packet_stream(pkts, masks, ltp, 3, expected_frac=0.7,
                                  worker_weights=wts, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ker),
                               rtol=1e-5, atol=1e-6)
    ones = ls.reduce_packet_stream(pkts, masks, ltp, 3, expected_frac=0.7,
                                   worker_weights=jnp.ones(3),
                                   backend="python")
    base = ls.reduce_packet_stream(pkts, masks, ltp, 3, expected_frac=0.7,
                                   backend="python")
    np.testing.assert_allclose(np.asarray(ones), np.asarray(base))


def test_staleness_weights_formula():
    w = ls.staleness_weights([0.0, 1.0, 4.0], 0.5)
    np.testing.assert_allclose(w, [1.0, 1 / 1.5, 1 / 3.0])
    np.testing.assert_allclose(ls.staleness_weights([0.0, 3.0], 0.0),
                               [1.0, 1.0])


def test_staleness_comp_wires_into_async_policy(setup):
    """LTPConfig.staleness_comp governs async damping unless the policy
    instance overrides it explicitly."""
    api, tc = setup
    rt = _runtime(api, tc, "async", ltp=LTPConfig(staleness_comp=0.7))
    assert rt.policy.damping == 0.7
    rt0 = _runtime(api, tc, "async")          # staleness_comp defaults to 0
    assert rt0.policy.damping == 0.0
    assert rt0.policy.weights([_grad(0, 0, staleness=3)]) is None
    over = _runtime(api, tc, AsyncPolicy(damping=1.0),
                    ltp=LTPConfig(staleness_comp=0.7))
    assert over.policy.damping == 1.0


# ---------------------------------------------------------------------------
# DES co-simulation
# ---------------------------------------------------------------------------


def test_des_bsp_cosim(setup):
    api, tc = setup
    rt = _runtime(api, tc, "bsp", transport="des")
    h = rt.run(_data(), epoch_steps=3)
    assert len(h) == STEPS and not rt.sim.truncated
    assert all(0.0 < r["delivered"] <= 1.0 for r in h)
    # the trunk-queue sampler (Sim.every + Topology.queue_depths) ran
    net_samples = [e for e in rt.tel.of("queue") if "net_depth" in e]
    assert net_samples and max(e["net_depth"] for e in net_samples) > 0


def test_des_async_cosim(setup):
    api, tc = setup
    compute = DeterministicCompute(W, base=0.05,
                                   mults=[1.0, 1.0, 1.0, 3.0])
    rt = _runtime(api, tc, "async", transport="des", compute_model=compute)
    h = rt.run(_data())
    assert sum(r["n_grads"] for r in h) == W * STEPS
    assert not rt.sim.truncated
    # per-flow Early Close fired and produced partial deliveries
    assert rt.tel.of("early_close")
    assert any(r["delivered"] < 1.0 for r in h)


def test_runtime_truncation_raises(setup):
    api, tc = setup
    rt = _runtime(api, tc, "bsp", transport="des")
    with pytest.warns(RuntimeWarning, match="max_events"):
        with pytest.raises(RuntimeError, match="truncated"):
            rt.run(_data(), max_events=50)


def test_runtime_rejects_unknown_transport(setup):
    api, tc = setup
    with pytest.raises(ValueError, match="unknown transport"):
        _runtime(api, tc, "bsp", transport="carrier-pigeon")
