"""Whisper-small — encoder-decoder audio transformer [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor frontend is STUBBED per the
assignment: ``input_specs`` provides precomputed frame embeddings of shape
(batch, encoder_frames, d_model). This config describes the transformer
backbone (encoder stack + decoder stack with cross-attention).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,             # decoder layers
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv=12,                 # MHA (kv == heads)
    head_dim=64,
    d_ff=3072,
    vocab=51865,             # padded to 51968 (vocab_padded) for TP
    encoder_frames=1500,
    block_pattern=("A",),
    norm_type="ln",
    mlp_type="gelu",
    pos_type="learned",
    source="arXiv:2212.04356",
)

REDUCED = CONFIG.replace(
    name="whisper-small-reduced",
    n_layers=2,
    encoder_layers=2,
    d_model=192,
    n_heads=6,
    n_kv=6,
    head_dim=32,
    d_ff=512,
    vocab=512,
    encoder_frames=64,
)
