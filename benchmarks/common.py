"""Shared helpers for the per-figure benchmark modules."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "dryrun_results")


def emit(rows: List[Dict[str, Any]], name: str) -> List[Dict[str, Any]]:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    for r in rows:
        fields = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{fields}", flush=True)
    return rows


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
