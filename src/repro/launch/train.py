"""Training launcher.

Two modes:

* host (default): the paper's PS training loop on this host — W vmapped
  workers, LTP transport (or a TCP baseline), synthetic data, checkpoints.

      PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
          --reduced --steps 100 --protocol ltp --loss-rate 0.001

* sharded: the pod-scale LTP `shard_map` train step on whatever devices
  this process has (a real TPU slice, or host devices via XLA_FLAGS) —
  the same code path the dry-run lowers at 256/512 chips.

      XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.train --mode sharded \
          --arch smollm_360m --reduced --steps 10 --n-data 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config, get_reduced
from repro.data import SyntheticLM
from repro.models import build
from repro.optim import make_optimizer
from repro.train import PSTrainer
from repro.train.trainer import init_state, make_ltp_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["host", "sharded"], default="host")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--protocol", default="ltp",
                    choices=["ltp", "bbr", "cubic", "reno"])
    ap.add_argument("--loss-rate", type=float, default=0.001)
    ap.add_argument("--compensation", default="paper",
                    choices=["paper", "count", "expected"])
    ap.add_argument("--n-data", type=int, default=0,
                    help="sharded mode: data-axis size (0 = all devices)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)

    cfg = (get_reduced if args.reduced else get_config)(args.arch)
    cfg = cfg.replace(dtype="float32")
    api = build(cfg)
    tc = TrainConfig(batch=args.batch, seq=args.seq, lr=args.lr,
                     optimizer="adamw", steps=args.steps)
    opt = make_optimizer(tc)
    lm = SyntheticLM(vocab=cfg.vocab, seed=0)
    ltp = LTPConfig(compensation=args.compensation)

    if args.mode == "host":
        net = NetConfig(10, 1, args.loss_rate, 4096)
        tr = PSTrainer(api, opt, tc, ltp, net, n_workers=args.workers,
                       protocol=args.protocol, compute_time=0.05, seed=0)
        gen = (lm.train_batch(args.batch, args.seq, s)
               for s in range(args.steps))
        tr.run(gen, epoch_steps=max(1, args.steps // 3), log_every=10)
        print(f"final loss {tr.history[-1]['loss']:.4f} | "
              f"throughput {tr.throughput(args.batch):.1f} seq/s (simulated)")
        if args.ckpt:
            save_checkpoint(args.ckpt, tr.params, tr.step_idx)
        return 0

    # sharded mode
    n_data = args.n_data or jax.device_count()
    from repro import compat
    mesh = compat.make_mesh((n_data, jax.device_count() // n_data),
                            ("data", "model"))
    print(f"mesh: {dict(mesh.shape)}; LTP workers = data axis ({n_data})")
    batch_specs = {"tokens": P("data"), "labels": P("data")}
    step = make_ltp_train_step(api, opt, mesh, ltp, ("data",), batch_specs)
    state = init_state(api, opt, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    frac = jnp.ones((n_data,))
    with compat.set_mesh(mesh):
        for s in range(args.steps):
            b = lm.train_batch(args.batch, args.seq, s)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            key, sub = jax.random.split(key)
            # a simple loss-rate-driven delivered fraction per step
            frac = jnp.clip(1.0 - args.loss_rate * 10
                            + 0.0 * frac, 0.5, 1.0) * jnp.ones((n_data,))
            state, m = step(state, b, frac, sub, jnp.float32(args.lr))
            if s % 10 == 0:
                print(f"step {s:4d} loss {float(m['loss']):.4f} "
                      f"delivered {float(m['delivered_frac']):.3f}",
                      flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, state.params, args.steps)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
