"""Topology-first construction surface (DESIGN.md §11).

One builder module owns every cluster shape the simulator can run:

  ``flat(...)``        the paper's topology: W workers behind one shared
                       trunk per PS shard (``n_ps=1`` is the single-PS
                       incast).
  ``multi_ps(n)``      flat, sharded over n parameter servers — one
                       trunk (pipe group) per shard.
  ``rack_spine(...)``  two-tier DC fabric: ``racks`` racks of
                       ``workers_per_rack`` workers behind ToR switches,
                       oversubscribed uplinks to a spine (``oversub``),
                       PS shard placement as a tunable (``ps_racks``),
                       and optional in-network aggregation at the ToRs
                       (``repro.net.aggtree``, DESIGN.md §11).

Builders return a ``Topology`` — a declarative description accepted by
every scenario, runtime, and benchmark entry point (``topology=``).
``Topology`` extends ``GatherSpec``, so everything that composed with
specs (heterogeneous access links, cross traffic) composes with racks,
and every internal plumb that typed against ``GatherSpec`` accepts a
``Topology`` unchanged.

The scattered construction surface this module replaces —
``PSTrainer(n_ps=)``, ``ClusterRuntime(n_ps=, spec=)``,
``DESTransport(n_ps=, spec=)`` — survives as thin aliases emitting
``APIDeprecationWarning`` (promoted to an error under pytest so the old
spelling cannot creep back in-tree).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Tuple

import numpy as np

from repro.config import NetConfig


class APIDeprecationWarning(DeprecationWarning):
    """A deprecated construction kwarg was used (DESIGN.md §11).

    A subclass so the test run can promote exactly OUR deprecations to
    errors without tripping over third-party ``DeprecationWarning``s.
    """


def warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} (repro.net.topology builders)",
        APIDeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class GatherSpec:
    """Topology description for one gather scenario (DESIGN.md §5).

    The default spec is the paper's setup: one PS behind one shared
    bottleneck, homogeneous workers, no background load. Every field
    composes with every other. ``Topology`` (below) extends this with
    the rack/spine tier; build instances through ``flat``/``multi_ps``/
    ``rack_spine`` rather than by hand.
    """

    n_ps: int = 1
    # per-worker access-link heterogeneity; None -> workers attach to the
    # trunk directly (no extra hop), exactly the paper topology.
    worker_rate_mult: Optional[np.ndarray] = None   # (W,) x trunk rate
    worker_delay_ms: Optional[np.ndarray] = None    # (W,) extra one-way ms
    worker_loss: Optional[np.ndarray] = None        # (W,) access loss prob
    # open-loop background load per PS trunk, as a fraction of line rate
    # offered during ON bursts (see CrossTrafficSource).
    cross_traffic_load: float = 0.0
    cross_on_ms: float = 5.0
    cross_off_ms: float = 5.0

    @property
    def heterogeneous(self) -> bool:
        return (self.worker_rate_mult is not None
                or self.worker_delay_ms is not None
                or self.worker_loss is not None)

    @property
    def hierarchical(self) -> bool:
        return False    # overridden by Topology

    def access_params(self, f: int, net: NetConfig) -> Tuple[float, float, float]:
        """(rate_bps, one-way delay s, loss) of worker f's access link."""
        bw = net.bandwidth_gbps * 1e9
        rate = bw * (self.worker_rate_mult[f]
                     if self.worker_rate_mult is not None else 1.0)
        delay = (self.worker_delay_ms[f] * 1e-3
                 if self.worker_delay_ms is not None else 0.0)
        loss = (float(self.worker_loss[f])
                if self.worker_loss is not None else 0.0)
        return rate, delay, loss

    def worker_share_bps(self, f: int, w: int, net: NetConfig) -> float:
        """Worker f's attainable per-shard rate: min(trunk fair share,
        its access-link share across the n_ps concurrent shard flows)."""
        bw = net.bandwidth_gbps * 1e9
        share = bw / w
        if self.worker_rate_mult is not None:
            share = min(share, bw * self.worker_rate_mult[f] / self.n_ps)
        return share


@dataclasses.dataclass
class Topology(GatherSpec):
    """Declarative cluster topology (builder result, DESIGN.md §11).

    ``racks == 0`` (the default) is the flat paper topology — a
    ``Topology`` then behaves exactly like the ``GatherSpec`` it
    extends. With ``racks > 0`` the gather becomes multi-hop: worker →
    ToR → (oversubscribed uplink) → spine → PS trunk, with shard ``p``
    optionally homed inside rack ``ps_racks[p]`` (its rack-mates skip
    the uplink and its oversubscription).

    ``inetwork_agg`` places an ``AggSwitch`` per (shard, rack) at the
    ToR: same-(shard, seq) packets from rack members are combined into
    one upstream wire packet (MLFabric-style partial reduction in the
    network), flushed in seq order — see ``repro.net.aggtree``.
    """

    racks: int = 0
    workers_per_rack: int = 0
    oversub: float = 1.0            # rack uplink = wpr x bw / oversub
    ps_racks: Optional[Tuple[int, ...]] = None  # shard p homed in rack
    #                                             ps_racks[p]; None = spine
    inetwork_agg: bool = False
    agg_hold_ms: float = 0.0        # ToR flush hold; 0 -> 0.25 x rtprop
    name: str = "flat"

    @property
    def hierarchical(self) -> bool:
        return self.racks > 0

    @property
    def n_workers(self) -> Optional[int]:
        """Worker count implied by the rack grid (None when flat)."""
        if not self.hierarchical:
            return None
        return self.racks * self.workers_per_rack

    def rack_of(self, f: int) -> int:
        return f // self.workers_per_rack

    def rack_members(self, r: int) -> List[int]:
        w0 = r * self.workers_per_rack
        return list(range(w0, w0 + self.workers_per_rack))

    def ps_rack(self, p: int) -> Optional[int]:
        """Rack housing shard p's server (None = attached at the spine)."""
        if self.ps_racks is None:
            return None
        return self.ps_racks[p]

    def uplink_bps(self, net: NetConfig) -> float:
        """ToR→spine uplink rate: the rack's aggregate injection rate
        derated by the oversubscription ratio."""
        return self.workers_per_rack * net.bandwidth_gbps * 1e9 / self.oversub

    def validate_workers(self, w: int, owner: str = "topology") -> None:
        if self.hierarchical and w != self.n_workers:
            raise ValueError(
                f"{owner}: n_workers={w} does not match the rack grid "
                f"{self.racks} x {self.workers_per_rack} = {self.n_workers}")

    def worker_share_bps(self, f: int, w: int, net: NetConfig) -> float:
        """Attainable per-shard rate on the rack fabric — feeds the
        Early-Close LT init formula (paper §III), so slow uplinks start
        with honest thresholds instead of flat-trunk optimism."""
        share = super().worker_share_bps(f, w, net)
        if not self.hierarchical:
            return share
        bw = net.bandwidth_gbps * 1e9
        up = self.uplink_bps(net)
        if self.inetwork_agg:
            # the worker's packets ride its rack's ONE merged flow per
            # shard: uplink split over n_ps merged flows, trunk over racks
            return min(up / self.n_ps, bw / max(self.racks, 1))
        # per-worker flow: trunk shared by all W, uplink shared by the
        # rack's wpr workers x n_ps concurrent shard flows each
        return min(share, up / (self.workers_per_rack * self.n_ps))


# ----------------------------------------------------------------------------
# builders — the public construction surface
# ----------------------------------------------------------------------------


def flat(n_ps: int = 1, **kw: Any) -> Topology:
    """The paper's topology: workers behind one shared trunk per PS
    shard. Extra ``GatherSpec`` fields (heterogeneous access links,
    cross traffic) pass through as keywords."""
    if n_ps < 1:
        raise ValueError(f"n_ps must be >= 1, got {n_ps}")
    return Topology(n_ps=n_ps, name="flat" if n_ps == 1 else f"flat_ps{n_ps}",
                    **kw)


def multi_ps(n_ps: int, **kw: Any) -> Topology:
    """Flat sharded gather: n_ps parameter servers, one trunk each."""
    return flat(n_ps=n_ps, **kw)


def rack_spine(racks: int, workers_per_rack: int, *, oversub: float = 4.0,
               n_ps: int = 1, ps_racks: Optional[Tuple[int, ...]] = None,
               agg: bool = True, agg_hold_ms: float = 0.0,
               **kw: Any) -> Topology:
    """Two-tier rack/spine fabric (DESIGN.md §11).

    ``oversub`` is the ToR uplink oversubscription ratio (uplink rate =
    workers_per_rack x link rate / oversub; 1.0 = non-blocking).
    ``ps_racks`` homes shard p inside rack ps_racks[p] — its rack-mates
    reach it without paying the uplink; None attaches every PS at the
    spine. ``agg=True`` enables in-network aggregation at the ToRs for
    LTP flows (order-aware partial reduction, ``repro.net.aggtree``).
    """
    if racks < 1 or workers_per_rack < 1:
        raise ValueError(
            f"rack grid must be positive, got {racks} x {workers_per_rack}")
    if oversub <= 0:
        raise ValueError(f"oversub must be > 0, got {oversub}")
    if n_ps < 1:
        raise ValueError(f"n_ps must be >= 1, got {n_ps}")
    if ps_racks is not None:
        ps_racks = tuple(int(r) for r in ps_racks)
        if len(ps_racks) != n_ps:
            raise ValueError(
                f"ps_racks must name a rack per shard: got {len(ps_racks)} "
                f"entries for n_ps={n_ps}")
        bad = [r for r in ps_racks if not 0 <= r < racks]
        if bad:
            raise ValueError(f"ps_racks out of range [0, {racks}): {bad}")
    return Topology(
        n_ps=n_ps, racks=racks, workers_per_rack=workers_per_rack,
        oversub=float(oversub), ps_racks=ps_racks, inetwork_agg=bool(agg),
        agg_hold_ms=float(agg_hold_ms),
        name=f"rack{racks}x{workers_per_rack}"
             f"{'_agg' if agg else ''}_os{oversub:g}", **kw)


# ----------------------------------------------------------------------------
# coercion + deprecation shims
# ----------------------------------------------------------------------------


def as_topology(spec: GatherSpec) -> Topology:
    """Coerce any ``GatherSpec`` to a ``Topology`` (identity when it
    already is one) so the runtime can rely on the extended surface."""
    if isinstance(spec, Topology):
        return spec
    fields = {f.name: getattr(spec, f.name)
              for f in dataclasses.fields(GatherSpec)}
    return Topology(**fields)


def resolve_topology(topology: Optional[GatherSpec], *,
                     n_ps: Optional[int] = None,
                     spec: Optional[GatherSpec] = None,
                     owner: str = "caller") -> Topology:
    """One resolution rule for every entry point: the new ``topology=``
    kwarg wins; the deprecated ``n_ps=`` / ``spec=`` aliases still work
    but emit ``APIDeprecationWarning``; nothing given -> single-PS flat.
    """
    if topology is not None:
        if spec is not None or n_ps is not None:
            raise ValueError(
                f"{owner}: pass either topology= or the deprecated "
                f"n_ps=/spec= aliases, not both")
        return as_topology(topology)
    if spec is not None:
        warn_deprecated(f"{owner}(spec=...)", f"{owner}(topology=...)")
        if n_ps is not None and n_ps != spec.n_ps:
            raise ValueError(
                f"{owner}: spec.n_ps={spec.n_ps} contradicts n_ps={n_ps}")
        return as_topology(spec)
    if n_ps is not None:
        warn_deprecated(f"{owner}(n_ps=...)",
                        f"{owner}(topology=multi_ps({n_ps}))")
        return multi_ps(n_ps)
    return flat()
