"""Perf-regression gate over the BENCH_*.json records (CI perf-smoke).

Compares freshly generated records against the committed baselines:

* ``*_wall_s``        — FAIL when current > ``--max-ratio`` x baseline
                        (default 2.0: the CI budget for runner jitter);
* ``*_events_per_sec`` / ``*_gbps`` / ``*_speedup``
                      — FAIL when current < baseline / ``--max-ratio``
                        (throughput ratchets: the committed acceptance
                        metrics must not silently collapse);
* absolute events/sec floors (``FLOORS``)
                      — FAIL when current < floor x ``--floor-scale``.
                        Unlike the relative rules these do not drift
                        with whatever baseline was last committed: the
                        runtime-DES fast path (DESIGN.md §9) is gated
                        at a minimum absolute throughput, so a sequence
                        of small "within budget" regressions can never
                        ratchet the baseline back down to the pre-§9
                        event engine;
* metric present in the baseline but missing from the current record
                      — FAIL (a benchmark quietly dropped).

New metrics in the current record are allowed (they become baseline on
the next commit of the JSONs).

Wall-clocks are machine-dependent: the 2x budget is what absorbs the
authoring-machine-vs-CI-runner gap, and a host mismatch between the two
records is printed as a warning so a tripped gate is easy to triage.
Every failure line prints the per-metric delta (absolute and relative)
so the run page is diagnosable without re-running anything.

  python -m benchmarks.check_regression \
      --baseline-dir /tmp/bench-baseline --current-dir . \
      BENCH_netsim.json BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

DEFAULT_FILES = ("BENCH_netsim.json", "BENCH_kernels.json",
                 "BENCH_runtime.json", "BENCH_faults.json",
                 "BENCH_netfaults.json")

#: metric-name suffix -> direction ("up" = bigger is better)
RULES: Tuple[Tuple[str, str], ...] = (
    ("_wall_s", "down"),
    ("_events_per_sec", "up"),
    ("_gbps", "up"),
    ("_speedup", "up"),
)

#: absolute events/sec floors — set at roughly HALF the value measured
#: on the 2-core authoring container (BENCH_*.json), so a healthy CI
#: runner clears them with margin while a return to the pre-§9 runtime
#: (per-packet events, per-runtime recompiles, O(pipes) telemetry —
#: ~300-500 ev/s on the same container) trips them immediately.
FLOORS: Dict[str, float] = {
    "runtime_des_events_per_sec": 2500.0,
    "runtime_des64_events_per_sec": 1200.0,
    "grid64_ltp_ps1_events_per_sec": 25_000.0,
    "grid64_ltp_ps4_events_per_sec": 25_000.0,
    "grid64_cubic_ps1_events_per_sec": 25_000.0,
    "grid64_cubic_ps4_events_per_sec": 25_000.0,
    "grid64_ref_coalesced_events_per_sec": 25_000.0,
    "grid64_ref_per_packet_events_per_sec": 4000.0,
    # the 512-worker rack/spine in-network-aggregation cell (DESIGN.md
    # §11): the calendar-queue engine must keep DC-scale gathers in CI
    "rack512_ltp_agg_events_per_sec": 12_000.0,
}

#: absolute wall-clock ceilings (seconds) — FAIL when current > ceiling.
#: Coarser than the relative ``_wall_s`` budget: these mark cells whose
#: very feasibility is the acceptance criterion (the 512-worker DES
#: gather must complete "in minutes", ISSUE 7 / ROADMAP), so a runaway
#: run fails even if some slow baseline was once committed. Set ~3x the
#: authoring-container measurement to absorb runner jitter.
WALL_CEILINGS: Dict[str, float] = {
    "rack512_wall_s": 300.0,
}

#: absolute quality ceilings — FAIL when current > ceiling. Unlike wall
#: clocks these are seeded, machine-independent metrics, so no runner
#: budget applies: the des16 fault acceptance (DESIGN.md §10 — two
#: worker crashes plus a PS failover must cost < 10% of final loss
#: relative to the fault-free twin) is gated at its spec value, not at
#: whatever baseline was last committed.
CEILINGS: Dict[str, float] = {
    "fault_des16_final_loss_ratio": 1.10,
    # observability layer (DESIGN.md §12): warm DES events/s with the
    # tracker off divided by the same cell with the JSONL tracker
    # attached (both best-of-2, runtime_sweep). The backend is a
    # buffered O(1) append per event, so the honest cost is a couple
    # percent — 1.05 is the spec budget (ISSUE 8) incl. runner jitter.
    "telemetry_overhead_ratio": 1.05,
    # network-layer chaos acceptance (DESIGN.md §14): the des16 fabric
    # scenario (flap storm + switch crash + partition + rack brownout)
    # with the budget controller on must cost < 10% of final loss vs
    # the fault-free twin, and commits must be back at pre-fault
    # cadence within 2 sim-seconds of the first injected fault. Both
    # are seeded, machine-independent sim metrics — spec values, not
    # drifting baselines.
    "netfault_final_loss_ratio": 1.10,
    "netfault_recovery_s": 2.0,
}


def replint_gate() -> List[str]:
    """The invariant linter (DESIGN.md §13) must report zero findings on
    ``src/`` — a perf record produced from a tree with un-pragma'd
    determinism/hygiene violations is not trustworthy as a baseline.
    Skips (empty) when the repo layout is not importable here."""
    try:
        from repro.devtools.replint import lint_paths
    except ImportError:
        return []
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    if not os.path.isdir(src):
        return []
    findings, _n = lint_paths([src])
    return [f"replint: {f.render()}" for f in findings]


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _metrics(doc: dict) -> Dict[str, float]:
    return {k: v for k, v in doc.get("metrics", {}).items()
            if isinstance(v, (int, float))}


def compare(current: Dict[str, float], baseline: Dict[str, float],
            max_ratio: float, floor_scale: float = 1.0) -> List[str]:
    """Returns a list of human-readable failure lines (empty = pass).

    Failure lines carry the per-metric delta (current - baseline, and
    the ratio) so a tripped gate is diagnosable from the log alone.
    """
    failures = []
    for key, base in sorted(baseline.items()):
        direction = next((d for suf, d in RULES if key.endswith(suf)), None)
        gated = direction is not None and base != 0
        if not gated and key not in CEILINGS:
            continue
        if key not in current:
            failures.append(f"{key}: missing from current record "
                            f"(baseline {base})")
            continue
        cur = current[key]
        ratio = cur / base if base else float("nan")
        ok = (not gated) or (ratio <= max_ratio if direction == "down"
                             else ratio >= 1.0 / max_ratio)
        floor = FLOORS.get(key)
        floor_ok = floor is None or cur >= floor * floor_scale
        ceiling = CEILINGS.get(key)
        ceiling_ok = ceiling is None or cur <= ceiling
        wall_cap = WALL_CEILINGS.get(key)
        wall_ok = wall_cap is None or cur <= wall_cap
        mark = ("ok" if ok and floor_ok and ceiling_ok and wall_ok
                else "REGRESSION")
        print(f"  {key:45s} base={base:<12g} cur={cur:<12g} "
              f"x{ratio:.2f} [{mark}]")
        if not ok:
            failures.append(
                f"{key}: {cur:g} vs baseline {base:g} "
                f"(delta {cur - base:+g}, x{ratio:.2f}, "
                f"budget x{max_ratio:g} {direction})")
        if not floor_ok:
            failures.append(
                f"{key}: {cur:g} below absolute floor "
                f"{floor * floor_scale:g} "
                f"(delta {cur - floor * floor_scale:+g}; the §9 runtime "
                f"fast path must not silently ratchet away)")
        if not ceiling_ok:
            failures.append(
                f"{key}: {cur:g} above absolute ceiling {ceiling:g} "
                f"(delta {cur - ceiling:+g}; the §10 fault-tolerance "
                f"acceptance must not silently degrade)")
        if not wall_ok:
            failures.append(
                f"{key}: {cur:g}s above absolute wall-clock ceiling "
                f"{wall_cap:g}s (the cell's feasibility is the "
                f"acceptance criterion — a runaway run is a failure)")
    # floors/ceilings also apply to metrics with no baseline entry yet
    for key, floor in sorted(FLOORS.items()):
        if key in baseline or key not in current:
            continue
        cur = current[key]
        if cur < floor * floor_scale:
            failures.append(
                f"{key}: {cur:g} below absolute floor "
                f"{floor * floor_scale:g} (no baseline; delta "
                f"{cur - floor * floor_scale:+g})")
    for key, ceiling in sorted(CEILINGS.items()):
        if key in baseline or key not in current:
            continue
        cur = current[key]
        if cur > ceiling:
            failures.append(
                f"{key}: {cur:g} above absolute ceiling {ceiling:g} "
                f"(no baseline; delta {cur - ceiling:+g})")
    for key, wall_cap in sorted(WALL_CEILINGS.items()):
        if key in baseline or key not in current:
            continue
        cur = current[key]
        if cur > wall_cap:
            failures.append(
                f"{key}: {cur:g}s above absolute wall-clock ceiling "
                f"{wall_cap:g}s (no baseline)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=None,
                    help=f"record names (default: {', '.join(DEFAULT_FILES)})")
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed baseline JSONs")
    ap.add_argument("--current-dir", default=".",
                    help="directory holding the fresh JSONs (default: .)")
    ap.add_argument("--max-ratio", type=float, default=2.0)
    ap.add_argument("--floor-scale", type=float, default=1.0,
                    help="multiplier on the absolute events/sec floors "
                         "(derate for known-slow runners)")
    args = ap.parse_args(argv)
    files = args.files or list(DEFAULT_FILES)
    # committed roots only: the gate reads BENCH_*.json record names,
    # never paths — intermediates (benchmarks/results/*.json and other
    # gitignored artifacts) cannot be smuggled in as a baseline
    bad = [n for n in files
           if os.path.basename(n) != n
           or not (n.startswith("BENCH_") and n.endswith(".json"))]
    if bad:
        print(f"refusing non-root record names {bad}: the gate compares "
              f"committed BENCH_*.json roots only", file=sys.stderr)
        return 2
    all_failures = replint_gate()
    for name in files:
        base_path = os.path.join(args.baseline_dir, name)
        cur_path = os.path.join(args.current_dir, name)
        if not os.path.exists(base_path):
            print(f"{name}: no baseline at {base_path} — skipping "
                  f"(commit one to arm the gate)")
            continue
        if not os.path.exists(cur_path):
            all_failures.append(f"{name}: current record missing at "
                                f"{cur_path}")
            continue
        base_doc, cur_doc = _load(base_path), _load(cur_path)
        if base_doc.get("host") != cur_doc.get("host"):
            print(f"{name}: WARNING host mismatch "
                  f"(baseline {base_doc.get('host')} vs "
                  f"current {cur_doc.get('host')}) — wall-clock ratios "
                  f"compare different machines")
        print(f"{name}:")
        all_failures += compare(_metrics(cur_doc), _metrics(base_doc),
                                args.max_ratio, args.floor_scale)
    if all_failures:
        print("\nperf regression gate FAILED:", file=sys.stderr)
        for f in all_failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nperf regression gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
