"""Mixture-of-Experts: top-k routing with sort-based capacity dispatch.

Dispatch is scatter/gather (static shapes, token dropping at capacity),
NOT one-hot einsum — the GShard-style dispatch einsum costs
O(T * E * C * d) MXU FLOPs, which for the deepseek config (E=160) would
dwarf the expert matmuls themselves and wreck the roofline's
MODEL_FLOPS / HLO_FLOPS ratio. Expert FLOPs here are ~6 * N_active * D.

Sharding: the (E, C, d) dispatch buffer is constrained expert-parallel
('model') when E divides the axis (deepseek 160/16); for few-big-expert
configs (mixtral E=8) experts are tensor-parallel inside (d_ff sharded)
and the buffer stays expert-replicated. The baseline relies on GSPMD to
lower the data-dependent gather/scatter; replacing it with an explicit
shard_map all-to-all is a §Perf hillclimb.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import Params, dense_init, split_keys
from repro.models.sharding import ShardCtx, NULL_CTX


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def moe_ff(cfg: ModelConfig) -> int:
    return cfg.moe_d_ff or cfg.d_ff


def capacity(cfg: ModelConfig, n_tokens: int, factor: float = 1.25) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * factor)
    return max(8, _round_up(c, 8))


def moe_params(key, cfg: ModelConfig, dtype) -> Params:
    d, e, ff = cfg.d_model, cfg.n_experts, moe_ff(cfg)
    ks = split_keys(key, 5)
    p = {
        "moe_gate": dense_init(ks[0], d, e, jnp.float32),
        "experts_gate": (jax.random.normal(ks[1], (e, d, ff)) * 0.02).astype(dtype),
        "experts_up": (jax.random.normal(ks[2], (e, d, ff)) * 0.02).astype(dtype),
        "experts_down": (jax.random.normal(ks[3], (e, ff, d)) * 0.02).astype(dtype),
    }
    if cfg.n_shared_experts > 0:
        sff = cfg.n_shared_experts * ff
        k1, k2, k3 = split_keys(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, d, sff, dtype),
            "w_up": dense_init(k2, d, sff, dtype),
            "w_down": dense_init(k3, sff, d, dtype),
        }
    return p


def router(cfg: ModelConfig, p: Params, xf):
    """xf: (T, d) -> (weights (T,k), ids (T,k), aux_loss scalar)."""
    logits = (xf.astype(jnp.float32) @ p["moe_gate"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    e = cfg.n_experts
    density = jnp.mean(
        jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0
    )
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * e
    return weights, ids, aux


def _route_group(cfg: ModelConfig, p: Params, xf, cap: int):
    """Routing + dispatch scatter for ONE group (vmapped over groups).

    Returns (buf (e, cap, d), s_ids, pos_c, s_tok, s_w, aux)."""
    tg, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    weights, ids, aux = router(cfg, p, xf)
    a = tg * k
    flat_ids = ids.reshape(a)
    flat_w = weights.reshape(a)
    tok_idx = jnp.arange(a) // k

    order = jnp.argsort(flat_ids)  # stable
    s_ids = flat_ids[order]
    s_tok = tok_idx[order]
    s_w = flat_w[order]

    counts = jnp.bincount(flat_ids, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(a) - starts[s_ids]
    pos_c = jnp.where(pos < cap, pos, cap)  # cap -> OOB -> dropped

    buf = jnp.zeros((e, cap, d), xf.dtype)
    buf = buf.at[s_ids, pos_c].set(xf[s_tok], mode="drop")
    return buf, s_ids, pos_c, s_tok, s_w, aux


def _combine_group(out_buf, s_ids, pos_c, s_tok, s_w, tg: int):
    """Combine gather + weighted scatter-add for ONE group."""
    d = out_buf.shape[-1]
    y_assign = out_buf.at[s_ids, pos_c].get(mode="fill", fill_value=0)
    y = jnp.zeros((tg, d), jnp.float32)
    y = y.at[s_tok].add(
        (y_assign * s_w[:, None].astype(out_buf.dtype)).astype(jnp.float32))
    return y


def apply_moe(
    cfg: ModelConfig,
    p: Params,
    x,
    *,
    capacity_factor: float = 1.25,
    ctx: ShardCtx = NULL_CTX,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss).

    GShard-style GROUPED dispatch: tokens are split into one group per
    data-parallel shard and routed within the group (token dropping at
    per-group capacity). The group axis is dp-sharded, so dispatch
    scatter/combine gather are shard-local; only the expert einsums touch
    the model axis. (The ungrouped global-sort variant made the
    partitioner replicate expert compute / all-reduce capacity buffers —
    visible in the ``benchmarks/roofline.py`` HLO walk.)
    """
    b, s, d = x.shape
    e, ff = cfg.n_experts, moe_ff(cfg)
    t = b * s
    xf = x.reshape(t, d)

    ndp = 1
    if ctx.mesh is not None:
        for ax in ctx.dp:
            ndp *= ctx.mesh.shape[ax]
    g_count = ndp if (ndp > 1 and t % ndp == 0) else 1
    tg = t // g_count
    cap = capacity(cfg, tg, capacity_factor)
    dp = ctx.dp or None
    ep = e % max(ctx.nm, 1) == 0

    xg = ctx.constrain(xf.reshape(g_count, tg, d), dp, None, None)
    buf, s_ids, pos_c, s_tok, s_w, aux = jax.vmap(
        lambda xx: _route_group(cfg, p, xx, cap)
    )(xg)
    # expert einsums at top level with explicit shardings: the group axis
    # stays on dp, experts on 'model' (expert-parallel) or d_ff on 'model'
    # (few big experts)
    espec = "model" if ep else None
    fspec = None if ep else "model"
    buf = ctx.constrain(buf, dp, espec, None, None)
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["experts_gate"]))
    h = g * jnp.einsum("gecd,edf->gecf", buf, p["experts_up"])
    h = ctx.constrain(h, dp, espec, None, fspec)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["experts_down"])
    out_buf = ctx.constrain(out_buf, dp, espec, None, None)
    y = jax.vmap(lambda ob, si, pc, st, sw: _combine_group(ob, si, pc, st, sw, tg))(
        out_buf, s_ids, pos_c, s_tok, s_w
    )
    y = ctx.constrain(y, dp, None, None)
    y = y.reshape(t, d).astype(x.dtype)
    aux = jnp.mean(aux)

    if cfg.n_shared_experts > 0:
        sp = p["shared"]
        sg = jax.nn.silu(xf @ sp["w_gate"])
        y = y + (sg * (xf @ sp["w_up"])) @ sp["w_down"]
    y = ctx.constrain(y, dp, "model")
    return y.reshape(b, s, d), aux
