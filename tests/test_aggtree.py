"""In-network aggregation at ToR switches (DESIGN.md §11).

Four layers of pinning, smallest to largest:

* the ``AggSwitch`` unit: MLFabric order-aware flush (a completed seq
  drags every lower pending seq, ascending), pass-through rules, and the
  retransmit-duplicate path;
* the reduce math: ``tree_reduce`` (rack partials + root combine) equals
  the flat ``packet_reduce`` to float tolerance under both compensation
  modes — the tree moves bytes, never the answer;
* the gather scenario: at zero loss the tree delivers every packet
  (all-True masks — so the kernel consuming them computes the flat
  answer, per the layer above), and the §9 loss accounting survives
  multi-hop reduction under loss (mask/delivered/counter conservation);
* the runtime: ``ClusterRuntime`` gathers ride the tree transparently
  (covered by the bsp DES cell here; async/ssp share the same
  ``_fwd_path`` plumb).
"""
import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig
from repro.kernels.packet_reduce import packet_reduce, tree_reduce
from repro.net.aggtree import AGG_FLOW, AggIngress, AggSwitch
from repro.net.scenarios import run_scenario
from repro.net.simcore import Packet, Sim
from repro.net.topology import rack_spine

NET = NetConfig(10, 1, 0.001, 4096)


# ---------------------------------------------------------------------------
# AggSwitch unit: order-aware flush + pass-through rules
# ---------------------------------------------------------------------------


class _SinkPipe:
    """Upstream stand-in: records emitted envelope trains."""

    def __init__(self):
        self.trains = []

    def send_train(self, pkts, deliver_train, t_ready=None):
        self.trains.append(list(pkts))
        return len(pkts)


def _switch(members=(0, 1, 2), hold=1e-3):
    sim = Sim()
    up = _SinkPipe()
    sw = AggSwitch(sim, up, members, hold)
    ings = {f: AggIngress(sw, f) for f in members}
    return sim, up, sw, ings


def _data(flow, seq, size=1000, critical=False):
    return Packet(flow, seq, size, kind="data", critical=critical,
                  meta={"g": 0})


def test_membership_complete_seq_flushes_immediately():
    sim, up, sw, ings = _switch()
    for f in (0, 1, 2):
        ings[f].send_train([_data(f, 5)], lambda items: None)
    assert len(up.trains) == 1
    (env,) = up.trains[0]
    assert env.flow == AGG_FLOW and env.seq == 5
    assert len(env.meta["agg"]) == 3
    assert sw.n_merged == 3 and sw.n_envelopes == 1
    assert sw.stats()["pending"] == 0


def test_completed_seq_drags_lower_pending_seqs_in_order():
    sim, up, sw, ings = _switch()
    # seq 3 and 7 partially filled, then seq 9 completes
    ings[0].send_train([_data(0, 3), _data(0, 7), _data(0, 9)],
                       lambda items: None)
    ings[1].send_train([_data(1, 9)], lambda items: None)
    assert up.trains == []          # nothing complete yet
    ings[2].send_train([_data(2, 9)], lambda items: None)
    # one flush: seqs 3, 7 (partial) and 9 (full), ascending
    assert [e.seq for e in up.trains[-1]] == [3, 7, 9]
    assert sw.stats()["pending"] == 0


def test_hold_timer_flushes_stragglers():
    sim, up, sw, ings = _switch(hold=1e-3)
    ings[0].send_train([_data(0, 1)], lambda items: None)
    ings[1].send_train([_data(1, 1)], lambda items: None)
    assert up.trains == []
    sim.run(until=0.01)
    assert sw.n_timeout_flushes == 1
    (env,) = up.trains[0]
    assert env.seq == 1 and len(env.meta["agg"]) == 2


def test_critical_and_reg_packets_bypass_solo():
    sim, up, sw, ings = _switch()
    ings[0].send_train([_data(0, 2, critical=True)], lambda items: None)
    reg = Packet(1, 0, 64, kind="reg")
    ings[1].send_train([reg], lambda items: None)
    assert len(up.trains) == 2 and sw.n_solo == 2 and sw.n_merged == 0
    for train in up.trains:
        assert len(train[0].meta["agg"]) == 1
    assert sw.stats()["pending"] == 0


def test_retransmit_duplicate_forwards_older_copy_solo():
    sim, up, sw, ings = _switch()
    ings[0].send_train([_data(0, 4)], lambda items: None)
    ings[0].send_train([_data(0, 4)], lambda items: None)   # retransmit
    assert sw.n_solo == 1           # older copy forwarded solo
    assert sw.stats()["pending"] == 1   # newest still waits for 1, 2


def test_dead_member_degrades_membership_not_stalls():
    sim, up, sw, ings = _switch()
    ings[0].send_train([_data(0, 6)], lambda items: None)
    ings[1].send_train([_data(1, 6)], lambda items: None)
    assert up.trains == []
    sw.set_live(2, False)           # crash: entry is now membership-full
    assert len(up.trains) == 1
    assert len(up.trains[0][0].meta["agg"]) == 2


def test_envelope_size_is_one_payload_plus_bitmap():
    sim, up, sw, ings = _switch()
    for f in (0, 1, 2):
        ings[f].send_train([_data(f, 0, size=1435)], lambda items: None)
    (env,) = up.trains[0]
    assert env.size == 1435 + 2 * 2     # max member + 2B per extra member


# ---------------------------------------------------------------------------
# reduce math: tree == flat to float tolerance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compensation", ["paper", "count"])
def test_tree_reduce_equals_flat(compensation):
    rng = np.random.default_rng(5)
    w, n, p = 8, 128, 128
    packets = rng.normal(size=(w, n, p)).astype(np.float32)
    mask = (rng.random((w, n)) > 0.3).astype(np.float32)
    flat_out = packet_reduce(packets, mask, compensation=compensation)
    tree_out = tree_reduce(packets, mask, lambda f: f // 2,
                           compensation=compensation)
    np.testing.assert_allclose(np.asarray(tree_out), np.asarray(flat_out),
                               rtol=1e-5, atol=1e-6)


def test_tree_reduce_unbalanced_racks():
    rng = np.random.default_rng(6)
    w, n, p = 8, 128, 128
    packets = rng.normal(size=(w, n, p)).astype(np.float32)
    mask = (rng.random((w, n)) > 0.5).astype(np.float32)
    rack_of = lambda f: 0 if f < 5 else 1   # 5 + 3 split # noqa: E731
    flat_out = packet_reduce(packets, mask)
    tree_out = tree_reduce(packets, mask, rack_of)
    np.testing.assert_allclose(np.asarray(tree_out), np.asarray(flat_out),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# gather scenario: whole delivery + loss accounting through the tree
# ---------------------------------------------------------------------------


def test_rack_gather_zero_loss_delivers_everything():
    # Early Close off (pct threshold 1.0): "whole delivery" is the
    # regime the tree-vs-flat equivalence claim is stated in — with it
    # on, a gather may legitimately close at the threshold first
    net = NetConfig(10, 1, 0.0, 4096)
    full = LTPConfig(data_pct_threshold=1.0, deadline_c_ms=1e4)
    rs = run_scenario("rack_spine_gather", "ltp", net, size_bytes=2e5,
                      racks=2, workers_per_rack=4, oversub=4.0,
                      iters=2, seed=3, coalesce=8, ltp=full)
    for r in rs:
        # full delivery -> all-True masks: the kernel consuming them
        # computes exactly the flat gather's reduction (tree_reduce
        # equivalence above closes the loop numerically)
        assert r.masks is not None and bool(r.masks.all())
        assert r.delivered.min() == 1.0
        assert r.packets_received == r.packets_expected
        assert r.criticals_ok
    stats = rs[-1].agg_stats
    assert stats is not None and stats["n_merged"] > 0
    assert stats["n_envelopes"] > 0
    assert stats["pending"] == 0        # nothing stuck in ToR buffers


def test_rack_gather_agg_stats_absent_when_agg_off():
    rs = run_scenario("rack_spine_gather", "ltp", NET, size_bytes=1e5,
                      racks=2, workers_per_rack=4, agg=False,
                      iters=1, seed=3, coalesce=8)
    assert rs[0].agg_stats is None


def test_rack_gather_lossy_accounting_survives_multihop():
    net = NetConfig(10, 1, 0.01, 4096)
    rs = run_scenario("rack_spine_gather", "ltp", net, size_bytes=2e5,
                      racks=2, workers_per_rack=4, oversub=4.0,
                      iters=3, seed=7, coalesce=8)
    for r in rs:
        n_ps, w, n = r.masks.shape
        # per-(shard, worker) mask fraction IS the delivered fraction —
        # a merged envelope lost on the uplink must count against every
        # member's mask, a delivered one against each exactly once
        per_worker = r.masks.reshape(n_ps, w, n).mean(axis=(0, 2))
        np.testing.assert_allclose(per_worker, r.delivered, atol=1e-9)
        # conservation: the receiver counter covers every mask bit (late
        # post-close arrivals may exceed the frozen masks, never trail)
        assert int(r.masks.sum()) <= r.packets_received
        assert r.packets_received <= r.packets_expected
        assert r.criticals_ok     # criticals bypass aggregation AND loss
    assert rs[-1].agg_stats["n_merged"] > 0


def test_rack_gather_beats_no_agg_on_oversubscribed_uplinks():
    net = NetConfig(10, 1, 0.002, 4096)
    kw = dict(size_bytes=4e5, racks=2, workers_per_rack=8, oversub=8.0,
              iters=2, seed=11, coalesce=8)
    bst_agg = np.mean([r.bst_gather for r in run_scenario(
        "rack_spine_gather", "ltp", net, agg=True, **kw)])
    bst_solo = np.mean([r.bst_gather for r in run_scenario(
        "rack_spine_gather", "ltp", net, agg=False, **kw)])
    assert bst_agg < bst_solo


# ---------------------------------------------------------------------------
# runtime transparency: ClusterRuntime gathers ride the tree
# ---------------------------------------------------------------------------


def test_runtime_bsp_gather_rides_tree():
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticCIFAR, batches
    from repro.models import build
    from repro.optim import make_optimizer
    from repro.config import TrainConfig
    from repro.runtime import ClusterRuntime

    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    w = 8
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=2)
    topo = rack_spine(2, 4, oversub=4.0, agg=True)
    rt = ClusterRuntime(api, make_optimizer(tc), tc, LTPConfig(),
                        NetConfig(10, 1, 0.003, 4096),
                        n_workers=w, protocol="ltp", policy="bsp",
                        transport="des", topology=topo, seed=0)
    hist = rt.run(batches(SyntheticCIFAR(seed=0), 4 * w, 2))
    assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])
    merged = sum(sw.n_merged for sw in rt.net_des.topo.aggs.values())
    assert merged > 0
