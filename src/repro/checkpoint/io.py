"""Checkpointing: pytree <-> .npz with slash-joined key paths.

Host-gathered (fine at example scale; a sharded production store would
write per-device shards — out of scope for the CPU container, noted in
DESIGN.md)."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays: Dict[str, np.ndarray] = {
        _path_str(p): np.asarray(v) for p, v in flat
    }
    arrays["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def restore_checkpoint(path: str, like: Any, strict: bool = True):
    """Restores into the structure of ``like``. Returns (tree, step).

    Raises ``KeyError`` naming every path ``like`` requires that the
    archive lacks, and ``ValueError`` on shape mismatches or (with
    ``strict``, the default) archive paths absent from ``like`` — a
    silent partial restore is how failover corrupts a model.
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    step = int(data["__step__"])
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    keys = [_path_str(p) for p, _ in flat]
    missing = sorted(k for k in keys if k not in data.files)
    if missing:
        raise KeyError(
            f"checkpoint {path!r} is missing {len(missing)} path(s) "
            f"required by `like`: {missing}")
    if strict:
        extra = sorted(set(data.files) - set(keys) - {"__step__"})
        if extra:
            raise ValueError(
                f"checkpoint {path!r} holds {len(extra)} path(s) absent "
                f"from `like`: {extra} (pass strict=False to ignore)")
    leaves = []
    for (p, old), key in zip(flat, keys):
        arr = data[key]
        want = tuple(np.shape(old))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)}, "
                f"expected {want}")
        dtype = getattr(old, "dtype", None)
        leaves.append(jax.numpy.asarray(arr) if dtype is None
                      else jax.numpy.asarray(arr, dtype=dtype))
    _, treedef2 = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(treedef2, leaves), step
