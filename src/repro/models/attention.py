"""Attention: GQA with chunked (memory-bounded) softmax, sliding window,
QK-norm, RoPE/M-RoPE, cross-attention, and single-token decode against a
KV cache.

Memory strategy (DESIGN.md): scores are never materialized for the full
(Sq, Sk) plane — a ``lax.scan`` over query chunks bounds the live scores
buffer to (B, H, cq, Sk_band). Sliding-window layers slice a static-length
KV band per query chunk, so window attention is O(S*w), not O(S^2).
All trip counts are static (the roofline HLO walker multiplies loop bodies
by trip count).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import (
    Params,
    apply_mrope,
    apply_rope,
    dense_init,
    rms_norm,
    split_keys,
)
from repro.models.sharding import ShardCtx, NULL_CTX

NEG_INF = -1e30


def pick_chunk(s: int, target: int = 128) -> int:
    """Largest divisor of ``s`` that is <= target (static)."""
    if s <= target:
        return s
    for c in range(target, 0, -1):
        if s % c == 0:
            return c
    return s


def attn_params(key, cfg: ModelConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm_scale"] = jnp.zeros((hd,), jnp.float32)
    return p


def _constrain_heads(ctx: ShardCtx, x):
    """(B, S, H, hd): prefer head sharding; fall back to seq sharding."""
    b, s, h, hd = x.shape
    if ctx.mesh is None:
        return x
    if h % max(ctx.nm, 1) == 0:
        return ctx.constrain(x, ctx.dp or None, None, "model", None)
    if s % max(ctx.nm, 1) == 0:
        return ctx.constrain(x, ctx.dp or None, "model", None, None)
    return ctx.constrain(x, ctx.dp or None, None, None, None)


def _sdpa(q, k, v, mask, scale: float):
    """q: (B, cq, H, hd); k/v: (B, Sk, KV, hd); mask: (B?, cq, Sk) bool or None.

    GQA via reshape to (B, cq, KV, G, hd). Softmax in f32.
    """
    b, cq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, cq, kvh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF)  # guard fully-masked rows
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = (p / jnp.maximum(denom, 1e-30)).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(b, cq, h, hd)


def multi_head_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset=0,
    kv_len: Optional[jnp.ndarray] = None,
    chunk_q: int = 128,
    ctx: ShardCtx = NULL_CTX,
):
    """Chunked attention. q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd).

    ``q_offset``: absolute position of q[0] (k positions start at 0).
    ``window`` > 0: sliding-window causal attention over a static KV band.
    ``kv_len``: optional per-batch valid KV length (for padded caches).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    cq = pick_chunk(sq, chunk_q)
    n_chunks = sq // cq

    q = _constrain_heads(ctx, q)

    use_band = causal and window > 0 and sk > window + cq
    band = window + cq if use_band else sk

    def chunk_body(carry, iq):
        qs = iq * cq
        qc = jax.lax.dynamic_slice_in_dim(q, qs, cq, axis=1)
        qpos = q_offset + qs + jnp.arange(cq)
        if use_band:
            # static-length KV band ending at the chunk's last position
            start = jnp.clip(qs + q_offset + cq - band, 0, sk - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpos = start + jnp.arange(band)
        else:
            kc, vc = k, v
            kpos = jnp.arange(band)
        mask = jnp.ones((b, cq, band), bool)
        if causal:
            mask &= (kpos[None, :] <= qpos[:, None])[None]
        if window > 0:
            mask &= (kpos[None, :] > qpos[:, None] - window)[None]
        if kv_len is not None:
            mask &= kpos[None, None, :] < kv_len[:, None, None]
        out = _sdpa(qc, kc, vc, mask, scale)
        return carry, out

    if n_chunks == 1:
        _, out = chunk_body(None, 0)
        return out
    _, outs = jax.lax.scan(chunk_body, None, jnp.arange(n_chunks))
    # (n_chunks, B, cq, H, hd) -> (B, Sq, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def decode_attention(q1, cache_k, cache_v, pos, *, window: int = 0):
    """One-token attention. q1: (B, 1, H, hd); cache_* : (B, Smax, KV, hd);
    ``pos``: scalar index of the new token (cache holds [0, pos]).

    For windowed layers the cache is a ring buffer of size ``window``
    (all slots valid once pos >= window; positions implicit — softmax is
    permutation-invariant so ring order is fine).
    """
    b, smax, kvh, hd = cache_k.shape
    scale = 1.0 / math.sqrt(hd)
    h = q1.shape[2]
    g = h // kvh
    qg = q1.reshape(b, 1, kvh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, cache_k).astype(jnp.float32) * scale
    kpos = jnp.arange(smax)
    if window > 0 and smax == window:
        valid = kpos <= pos  # ring: all valid after warmup
    else:
        valid = kpos <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = (p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)).astype(
        cache_v.dtype
    )
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, cache_v)
    return out.reshape(b, 1, h, hd)


def _project_qkv(cfg: ModelConfig, p: Params, x):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm_scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm_scale"], cfg.norm_eps)
    return q, k, v


def _apply_pos(cfg: ModelConfig, q, k, positions):
    if cfg.pos_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_type == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k


def self_attention(
    cfg: ModelConfig,
    p: Params,
    x,
    positions,
    *,
    window: jnp.ndarray | int = 0,
    causal: bool = True,
    ctx: ShardCtx = NULL_CTX,
):
    """Full-sequence self attention (train / prefill).

    ``window`` may be a traced per-layer scalar (scan over heterogeneous
    layer patterns); a static band optimization is applied only when it is
    a Python int.
    """
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _apply_pos(cfg, q, k, positions)
    if isinstance(window, (int,)):
        out = multi_head_attention(
            q, k, v, causal=causal, window=window, ctx=ctx
        )
    else:
        # traced window: compute full attention, mask by the dynamic window
        out = _traced_window_attention(q, k, v, window, ctx=ctx)
    b, s = x.shape[:2]
    return out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]


def _traced_window_attention(q, k, v, window, *, ctx: ShardCtx):
    """Causal attention where ``window`` is a traced scalar (0 = unlimited).

    Used by scans over layer stacks whose pattern mixes 'W' and 'A' layers
    (gemma3). Cost is O(S^2) for the W layers too — acceptable at train/
    prefill sizes; the banded path handles the static-window archs.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    cq = pick_chunk(sq, 128)
    n_chunks = sq // cq
    q = _constrain_heads(ctx, q)

    def chunk_body(carry, iq):
        qs = iq * cq
        qc = jax.lax.dynamic_slice_in_dim(q, qs, cq, axis=1)
        qpos = qs + jnp.arange(cq)
        kpos = jnp.arange(sk)
        mask = (kpos[None, :] <= qpos[:, None])[None]
        wmask = jnp.where(
            window > 0, kpos[None, :] > qpos[:, None] - window, True
        )[None]
        out = _sdpa(qc, k, v, mask & wmask, scale)
        return carry, out

    if n_chunks == 1:
        _, out = chunk_body(None, 0)
        return out
    _, outs = jax.lax.scan(chunk_body, None, jnp.arange(n_chunks))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def self_attention_decode(cfg, p, x1, cache_k, cache_v, pos, *, window: int = 0):
    """One-token self attention with functional cache update.

    Returns (out, new_k, new_v). Cache layout: (B, Smax, KV, hd); for
    windowed layers Smax == window and the write index wraps (ring buffer).
    """
    q, k, v = _project_qkv(cfg, p, x1)  # (B,1,...)
    positions = jnp.full((x1.shape[0], 1), pos, jnp.int32)
    if cfg.pos_type == "mrope":
        pos3 = jnp.broadcast_to(pos, (3, x1.shape[0], 1)).astype(jnp.int32)
        q, k = _apply_pos(cfg, q, k, pos3)
    else:
        q, k = _apply_pos(cfg, q, k, positions)
    smax = cache_k.shape[1]
    widx = jnp.mod(pos, smax) if window > 0 and smax == window else pos
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), widx, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), widx, axis=1)
    out = decode_attention(q, new_k, new_v, pos, window=window)
    b = x1.shape[0]
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, new_k, new_v


def cross_attention(cfg: ModelConfig, p: Params, x, enc_kv):
    """Encoder-decoder cross attention (whisper). enc_kv: precomputed
    (k, v) from encoder output, each (B, Senc, KV, hd)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k, v = enc_kv
    out = multi_head_attention(q, k, v, causal=False, window=0)
    return out.reshape(b, s, h * hd) @ p["wo"]


def cross_attn_params(key, cfg: ModelConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
