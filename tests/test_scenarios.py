"""Topology-engine scenarios: registry, multi-PS conservation/degeneracy,
bandwidth stragglers, cross traffic, per-PS/per-phase Early Close.

Deliberately hypothesis-free so this coverage runs even where the
property-testing extra is absent (the seed container).
"""
import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig
from repro.core.early_close import (
    EarlyCloseController,
    GatherSample,
    MultiPSEarlyClose,
    phase_pct_threshold,
)
from repro.net.scenarios import (
    PROTOCOLS,
    cross_traffic,
    incast_gather,
    list_scenarios,
    multi_ps_gather,
    run_scenario,
    straggler_gather,
    train_iterations,
)
from repro.net.simcore import CrossTrafficSource, Packet, Pipe, Route, Sim, Topology


# ----------------------------------------------------------------------------
# topology primitives
# ----------------------------------------------------------------------------


def test_route_chains_serialization_and_delay():
    sim = Sim()
    a = Pipe(sim, 8e6, 0.010, 0.0, 10, np.random.default_rng(0))
    b = Pipe(sim, 8e6, 0.020, 0.0, 10, np.random.default_rng(0))
    got = []
    Route([a, b]).send(Packet(0, 0, 1000), lambda p: got.append(sim.now))
    sim.run()
    # 1ms serialization + 10ms delay on hop a, then again 1ms + 20ms on b
    np.testing.assert_allclose(got, [0.032], rtol=1e-6)


def test_route_drop_at_any_hop_kills_packet():
    sim = Sim()
    a = Pipe(sim, 8e6, 0.0, 0.0, 10, np.random.default_rng(0))
    b = Pipe(sim, 8e6, 0.0, 1.0, 10, np.random.default_rng(0))  # loss=1
    got = []
    Route([a, b]).send(Packet(0, 0, 1000), lambda p: got.append(p.seq))
    sim.run()
    assert got == []
    assert Route([a, b]).n_dropped_loss == 1


def test_topology_groups_and_stats():
    sim = Sim()
    topo = Topology(sim)
    for p in range(2):
        topo.add_pipe(f"ps{p}/trunk", Pipe(sim, 1e9, 0.0, 0.0, 100,
                                           np.random.default_rng(p)),
                      group=f"ps{p}")
    topo.pipes["ps0/trunk"].send(Packet(0, 0, 500), lambda p: None)
    sim.run()
    s = topo.stats()
    assert s["ps0"]["bytes_delivered"] == 500
    assert s["ps1"]["bytes_delivered"] == 0
    with pytest.raises(ValueError):
        topo.add_pipe("ps0/trunk", Pipe(sim, 1e9, 0.0, 0.0, 100))


def test_cross_traffic_source_offered_load():
    sim = Sim()
    pipe = Pipe(sim, 1e9, 0.0, 0.0, 100_000, np.random.default_rng(0))
    src = CrossTrafficSource(sim, pipe, load=0.5,
                             rng=np.random.default_rng(1),
                             on_mean=5e-3, off_mean=5e-3)
    src.start()
    sim.at(0.2, src.stop)
    sim.run(until=0.5)
    # duty 0.5 at load 0.5 -> ~0.25 of line rate over the 200ms window
    delivered = src.n_delivered * 1500 * 8 / 0.2
    assert 0.1 * 1e9 < delivered < 0.45 * 1e9


# ----------------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------------


def test_registry_contains_all_scenarios():
    names = list_scenarios()
    for expected in ("incast_gather", "multi_ps_gather", "straggler_gather",
                     "cross_traffic", "p2p_transfer", "train_iterations",
                     "fairness_share"):
        assert expected in names


def test_registry_dispatch_and_unknown():
    net = NetConfig(10, 1, 0.0, 4096)
    rs = run_scenario("incast_gather", "ltp", net, w=2, size_bytes=1e5,
                      iters=1, seed=0, straggler_prob=0.0)
    assert len(rs) == 1 and rs[0].bst_gather > 0
    with pytest.raises(ValueError):
        run_scenario("nope", "ltp", net)


@pytest.mark.parametrize("proto", PROTOCOLS)
@pytest.mark.parametrize("name", ["multi_ps_gather", "straggler_gather",
                                  "cross_traffic"])
def test_new_scenarios_run_for_all_protocols(name, proto):
    net = NetConfig(10, 1, 0.001, 4096)
    kw = {"n_ps": 2} if name == "multi_ps_gather" else {}
    rs = run_scenario(name, proto, net, w=2, size_bytes=1e5, iters=1,
                      seed=1, **kw)
    r = rs[0]
    assert np.isfinite(r.bst_gather) and r.bst_gather > 0
    assert np.all((r.delivered > 0) & (r.delivered <= 1.0))


# ----------------------------------------------------------------------------
# multi-PS gather
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("n_ps", [1, 2, 4])
def test_multi_ps_conserves_delivered_packets(n_ps):
    """Lossless network + 100% close threshold: every shard flow delivers
    every packet, for any sharding degree."""
    net = NetConfig(10, 1, 0.0, 4096)
    ltp = LTPConfig(data_pct_threshold=1.0)
    rs = multi_ps_gather("ltp", net, 4, 4e5, n_ps=n_ps, iters=2, ltp=ltp,
                         seed=2, straggler_prob=0.0)
    for r in rs:
        assert r.packets_expected > 0
        assert r.packets_received == r.packets_expected
        np.testing.assert_allclose(r.delivered, 1.0)
        assert r.criticals_ok


def test_multi_ps_conserves_for_reliable_protocols():
    net = NetConfig(10, 1, 0.005, 4096)   # lossy: retransmissions recover
    rs = multi_ps_gather("cubic", net, 3, 2e5, n_ps=2, iters=2, seed=3)
    for r in rs:
        assert r.packets_received == r.packets_expected
        np.testing.assert_array_equal(r.delivered, 1.0)


def test_multi_ps_degenerates_to_incast_at_one_ps():
    """n_ps=1 is *the same computation* as incast_gather — statistics
    match to float tolerance, not just qualitatively."""
    net = NetConfig(10, 1, 0.002, 4096)
    a = incast_gather("ltp", net, 4, 5e5, iters=4, seed=7)
    b = multi_ps_gather("ltp", net, 4, 5e5, n_ps=1, iters=4, seed=7)
    np.testing.assert_allclose([r.bst_gather for r in a],
                               [r.bst_gather for r in b], rtol=1e-9)
    np.testing.assert_allclose(np.stack([r.delivered for r in a]),
                               np.stack([r.delivered for r in b]), rtol=1e-9)


def test_multi_ps_sharding_speeds_up_gather():
    """More PS shards = more aggregate trunk bandwidth = shorter BST
    (MLfabric's observation: aggregation topology dominates)."""
    net = NetConfig(10, 1, 0.0, 4096)
    bst = {}
    for n_ps in (1, 4):
        rs = multi_ps_gather("ltp", net, 8, 1e6, n_ps=n_ps, iters=4, seed=5,
                             straggler_prob=0.0)
        bst[n_ps] = np.mean([r.bst_gather for r in rs[1:]])  # warm rounds
    assert bst[4] < bst[1]


# ----------------------------------------------------------------------------
# stragglers & cross traffic
# ----------------------------------------------------------------------------


def test_straggler_ltp_beats_order_preserving_baselines():
    """A 4x-slower access link pins reliable protocols to its drain time;
    LTP early-closes around it (the paper's §V claim, generalized to
    bandwidth heterogeneity)."""
    net = NetConfig(10, 1, 0.0, 4096)
    means = {}
    for proto in ("ltp", "reno", "cubic"):
        rs = straggler_gather(proto, net, 4, 5e5, iters=4, seed=9,
                              slow_rate_mult=0.25)
        means[proto] = np.mean([r.bst_gather for r in rs])
    assert means["ltp"] < means["reno"]
    assert means["ltp"] < means["cubic"]


def test_straggler_ltp_still_delivers_criticals():
    net = NetConfig(10, 1, 0.001, 4096)
    rs = straggler_gather("ltp", net, 4, 3e5, iters=3, seed=4)
    for r in rs:
        assert r.criticals_ok
        assert r.delivered.min() > 0.2   # even the straggler lands data


def test_cross_traffic_slows_reliable_gather():
    net = NetConfig(10, 1, 0.0, 4096)
    quiet = np.mean([r.bst_gather for r in
                     cross_traffic("cubic", net, 4, 3e5, iters=3, seed=6,
                                   bg_load=0.0)])
    busy = np.mean([r.bst_gather for r in
                    cross_traffic("cubic", net, 4, 3e5, iters=3, seed=6,
                                  bg_load=0.7)])
    assert busy > quiet


# ----------------------------------------------------------------------------
# per-PS / per-phase Early Close + training coupling
# ----------------------------------------------------------------------------


def test_phase_threshold_ramp():
    ltp = LTPConfig(data_pct_threshold=0.8, phase_final_pct_threshold=0.99)
    assert phase_pct_threshold(ltp, 0.0) == pytest.approx(0.8)
    assert phase_pct_threshold(ltp, 0.5) == pytest.approx(0.895)
    assert phase_pct_threshold(ltp, 1.0) == pytest.approx(0.99)
    assert phase_pct_threshold(ltp, 2.0) == pytest.approx(0.99)  # clamped
    off = LTPConfig(data_pct_threshold=0.8)
    assert phase_pct_threshold(off, 0.9) == pytest.approx(0.8)


def test_multi_ps_controller_matches_single_at_one_shard():
    net = NetConfig(10, 1, 0.0, 4096)
    ltp = LTPConfig()
    single = EarlyCloseController(ltp, net, 4, 1e6)
    multi = MultiPSEarlyClose(ltp, net, 4, 1e6, n_ps=1)
    rng = np.random.default_rng(0)
    for _ in range(3):
        t = rng.uniform(0.5, 2.0, 4) * single.deadline
        s = GatherSample(completion_times=t, first_arrival=np.full(4, 1e-3))
        c1, f1 = single.step(s)
        c2, f2 = multi.step([s])
        assert c1 == pytest.approx(c2)
        np.testing.assert_allclose(f1, f2)


def test_multi_ps_controller_closes_at_slowest_shard():
    net = NetConfig(10, 1, 0.0, 4096)
    multi = MultiPSEarlyClose(LTPConfig(), net, 4, 1e6, n_ps=2)
    lt = float(multi.controllers[0].lt.max())
    # both shards finish before LT -> each closes at its own completion,
    # and the iteration closes with the slowest shard
    fast = GatherSample(completion_times=np.full(4, 0.4 * lt),
                        first_arrival=np.full(4, 1e-4))
    slow = GatherSample(completion_times=np.full(4, 0.8 * lt),
                        first_arrival=np.full(4, 1e-4))
    close, frac = multi.step([fast, slow])
    assert close == pytest.approx(0.8 * lt)
    np.testing.assert_allclose(frac, 1.0)


def test_lost_stop_packet_does_not_stall_the_round():
    """A 'stop' dropped on the lossy back pipe must be re-sent (data after
    close re-triggers it) — otherwise the sender retransmits into the
    closed receiver until the sim horizon and the trunk counters explode."""
    net = NetConfig(10, 1, 0.02, 4096)   # ~47% chance/round of >=1 lost stop
    rs = multi_ps_gather("ltp", net, 8, 1e6, n_ps=4, iters=4, seed=0)
    for r in rs:
        trunk_sent = sum(g["n_sent"] for g in r.trunk_stats.values())
        assert trunk_sent < 20 * r.packets_expected


def test_train_iterations_rejects_n_ps_for_non_sharding_scenarios():
    net = NetConfig(10, 1, 0.0, 4096)
    with pytest.raises(ValueError):
        train_iterations("ltp", net, 4, 4e5, iters=1, n_ps=2)  # incast_gather


def test_train_iterations_n_ps_governs_both_legs():
    """The broadcast leg must see the same sharding degree as the gather
    leg, whether n_ps arrives as the named arg or inside scenario_kw —
    and multi_ps_gather's own default must not sneak in unnoticed."""
    net = NetConfig(10, 1, 0.0, 4096)
    one = train_iterations("ltp", net, 4, 4e5, iters=1, seed=1,
                           scenario="multi_ps_gather")     # n_ps defaults to 1
    ref = train_iterations("ltp", net, 4, 4e5, iters=1, seed=1)
    assert one["bst_broadcast"] == pytest.approx(ref["bst_broadcast"])
    np.testing.assert_allclose(one["bst_gather"], ref["bst_gather"], rtol=1e-9)
    two = train_iterations("ltp", net, 4, 4e5, iters=1, seed=1,
                           scenario="multi_ps_gather", n_ps=2)
    assert two["bst_broadcast"] < one["bst_broadcast"]


def test_train_iterations_over_new_scenarios():
    net = NetConfig(10, 1, 0.002, 4096)
    base = train_iterations("ltp", net, 4, 4e5, iters=2, seed=3)
    for scen, kw in [("multi_ps_gather", {"n_ps": 2}),
                     ("straggler_gather", {}),
                     ("cross_traffic", {"bg_load": 0.3})]:
        out = train_iterations("ltp", net, 4, 4e5, iters=2, seed=3,
                               scenario=scen, **kw)
        assert out["bst"].shape == base["bst"].shape
        assert np.all(np.isfinite(out["bst"])) and np.all(out["bst"] > 0)
        assert out["delivered"].shape == (2, 4)
