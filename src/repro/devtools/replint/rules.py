"""The six replint rules (DESIGN.md §13).

Each rule is a function over a :class:`~repro.devtools.replint.core.FileContext`
yielding findings; registration order is report order. All analysis is
purely syntactic (stdlib ``ast``) — rules prefer false positives that a
``# replint: ok(<rule>)`` pragma can document over silent false
negatives, because every invariant here was violated at least once in a
merged PR before being caught by hand.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

from repro.devtools.replint.core import FileContext, Finding, register

# --------------------------------------------------------------------------
# shared helpers


def _dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` attribute chain as a tuple, or None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_setish(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


def _self_attr(node: ast.AST) -> Optional[str]:
    """Attribute name when ``node`` is ``self.<attr>``, else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


# --------------------------------------------------------------------------
# determinism


_WALLCLOCK = {"time", "monotonic", "perf_counter", "process_time",
              "time_ns", "monotonic_ns", "perf_counter_ns"}
_DATETIME_FNS = {"now", "utcnow", "today"}
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "MT19937", "BitGenerator", "RandomState"}
_RANDOM_OK = {"Random", "SystemRandom"}


@register("determinism",
          "no wall clocks, global RNG, id() keys, or set-iteration-order "
          "dependence in repro/net and repro/runtime")
def check_determinism(ctx: FileContext) -> Iterable[Finding]:
    if not ctx.in_package_dirs(("net", "runtime")):
        return
    tree = ctx.tree

    # import aliasing: local name -> dotted module it refers to
    modmap: Dict[str, str] = {}
    from_random: Set[str] = set()
    from_time: Set[str] = set()
    np_default_rng_aliases: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                modmap[alias.asname or alias.name.split(".")[0]] = \
                    alias.name if alias.asname else alias.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            for alias in node.names:
                local = alias.asname or alias.name
                if mod == "random" and alias.name not in _RANDOM_OK:
                    from_random.add(local)
                elif mod == "time" and alias.name in _WALLCLOCK:
                    from_time.add(local)
                elif mod == "numpy.random" and alias.name == "default_rng":
                    np_default_rng_aliases.add(local)

    def flag(node: ast.AST, msg: str) -> Finding:
        return Finding("determinism", ctx.path, node.lineno,
                       node.col_offset, msg)

    # class attrs assigned set-typed values (self.x = set(...)/{...}/frozenset)
    class_set_attrs: Dict[ast.ClassDef, Set[str]] = {}
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        attrs: Set[str] = set()
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign) and _is_setish(node.value):
                for tgt in node.targets:
                    a = _self_attr(tgt)
                    if a:
                        attrs.add(a)
            elif isinstance(node, ast.AnnAssign) and node.value is not None \
                    and _is_setish(node.value):
                a = _self_attr(node.target)
                if a:
                    attrs.add(a)
        # class-level declarations like ``active: frozenset = frozenset()``
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name) and \
                    stmt.value is not None and _is_setish(stmt.value):
                attrs.add(stmt.target.id)
            elif isinstance(stmt, ast.Assign) and _is_setish(stmt.value):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        attrs.add(tgt.id)
        class_set_attrs[cls] = attrs

    # inherit set-typed attrs from same-file base classes (fixpoint over
    # the local class graph: subclasses iterate what the base assigns)
    by_name = {cls.name: cls for cls in class_set_attrs}
    changed = True
    while changed:
        changed = False
        for cls, attrs in class_set_attrs.items():
            for base in cls.bases:
                bcls = by_name.get(base.id) \
                    if isinstance(base, ast.Name) else None
                if bcls is not None and not \
                        class_set_attrs[bcls] <= attrs:
                    attrs.update(class_set_attrs[bcls])
                    changed = True

    # map every node to its nearest enclosing class (for self.attr lookup)
    owner: Dict[int, ast.ClassDef] = {}
    for cls in class_set_attrs:
        for node in ast.walk(cls):
            owner.setdefault(id(node), cls)

    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            findings.extend(_det_check_call(
                node, ctx, modmap, from_random, from_time,
                np_default_rng_aliases))

    # comprehensions consumed by order-insensitive reductions are fine:
    # sorted(x for x in s), max(...), any(...) do not depend on order
    order_free_comps: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("sorted", "min", "max", "sum", "any",
                                     "all", "len", "set", "frozenset"):
            for arg in node.args:
                if isinstance(arg, _COMP_NODES):
                    order_free_comps.add(id(arg))

    # set-iteration-order dependence
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        local_sets: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _is_setish(node.value):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        local_sets.add(tgt.id)
        sites: List[ast.expr] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                sites.append(node.iter)
            elif isinstance(node, _COMP_NODES) \
                    and id(node) not in order_free_comps:
                sites.extend(gen.iter for gen in node.generators)
        for it in sites:
            if _is_setish(it):
                findings.append(flag(
                    it, "iteration over a set expression: order is hash- "
                        "and history-dependent; sort it (or iterate an "
                        "ordered container) to keep replays bitwise"))
                continue
            a = _self_attr(it)
            cls = owner.get(id(fn))
            if a and cls is not None and a in class_set_attrs.get(cls, ()):
                findings.append(flag(
                    it, f"iteration over set attribute 'self.{a}': order "
                        f"is hash- and history-dependent; iterate "
                        f"sorted(self.{a}) to keep replays bitwise"))
            elif isinstance(it, ast.Name) and it.id in local_sets:
                findings.append(flag(
                    it, f"iteration over local set {it.id!r}: order is "
                        f"hash- and history-dependent; sort it to keep "
                        f"replays bitwise"))

    # deduplicate (nested walks can visit a node twice)
    seen: Set[Tuple[int, int, str]] = set()
    for f in findings:
        key = (f.line, f.col, f.message)
        if key not in seen:
            seen.add(key)
            yield f


def _det_check_call(node: ast.Call, ctx: FileContext, modmap: Dict[str, str],
                    from_random: Set[str], from_time: Set[str],
                    np_rng_aliases: Set[str]) -> Iterator[Finding]:
    def flag(msg: str) -> Finding:
        return Finding("determinism", ctx.path, node.lineno,
                       node.col_offset, msg)

    func = node.func
    if isinstance(func, ast.Name):
        if func.id == "id":
            yield flag("id() is address-dependent and varies across "
                       "processes; key on a stable identity instead")
        elif func.id in from_random:
            yield flag(f"global random.{func.id}() draws from shared "
                       f"process state; use a seeded random.Random / "
                       f"np.random.default_rng(seed)")
        elif func.id in from_time:
            yield flag(f"wall-clock {func.id}() in sim code; use sim.now")
        elif func.id in np_rng_aliases and not node.args and not node.keywords:
            yield flag("unseeded default_rng(): pass an explicit seed")
        return

    chain = _dotted(func)
    if not chain:
        return
    root = modmap.get(chain[0])
    resolved = (root,) + chain[1:] if root else chain
    if root == "time" and len(resolved) == 2 and resolved[1] in _WALLCLOCK:
        yield flag(f"wall-clock time.{resolved[1]}() in sim code; "
                   f"use sim.now")
    elif resolved[-1] in _DATETIME_FNS and any(
            p in ("datetime", "date") for p in resolved[:-1]):
        yield flag(f"wall-clock datetime {resolved[-1]}() in sim code; "
                   f"use sim.now")
    elif root == "random" and len(resolved) == 2 \
            and resolved[1] not in _RANDOM_OK:
        yield flag(f"global random.{resolved[1]}() draws from shared "
                   f"process state; use a seeded random.Random / "
                   f"np.random.default_rng(seed)")
    elif root == "numpy" and len(resolved) >= 3 and resolved[1] == "random":
        attr = resolved[2]
        if attr not in _NP_RANDOM_OK:
            yield flag(f"legacy global np.random.{attr}(): use a seeded "
                       f"np.random.default_rng(seed) Generator")
        elif attr == "default_rng" and len(resolved) == 3 \
                and not node.args and not node.keywords:
            yield flag("unseeded np.random.default_rng(): pass an "
                       "explicit seed")


# --------------------------------------------------------------------------
# pool-reset


_CONTAINER_CTORS = {"list", "dict", "set", "frozenset", "deque",
                    "defaultdict", "OrderedDict", "Counter", "bytearray"}
_MUTATORS = {"clear", "update", "extend", "append", "appendleft", "pop",
             "popleft", "add", "discard", "remove", "insert", "setdefault"}


def _init_candidates(init: ast.FunctionDef) -> Dict[str, int]:
    """Mutable-state attrs ``__init__`` creates, attr -> first line.

    An attr is pool-state (must be re-initialized by ``reset``) when its
    value is a constant or a container built without referencing any
    ``__init__`` parameter; anything wired from the constructor args is
    configuration, not per-life state.
    """
    params: Set[str] = set()
    a = init.args
    for arg in [*a.posonlyargs, *a.args, *a.kwonlyargs]:
        params.add(arg.arg)
    if a.vararg:
        params.add(a.vararg.arg)
    if a.kwarg:
        params.add(a.kwarg.arg)
    params.discard("self")

    def refs_param(expr: ast.AST) -> bool:
        return any(isinstance(n, ast.Name) and n.id in params
                   for n in ast.walk(expr))

    def resettable(expr: ast.AST) -> bool:
        if isinstance(expr, ast.Constant):
            return True
        if isinstance(expr, ast.UnaryOp) and \
                isinstance(expr.operand, ast.Constant):
            return True
        if isinstance(expr, (ast.List, ast.Tuple, ast.Dict, ast.Set,
                             ast.ListComp, ast.SetComp, ast.DictComp)):
            return True
        if isinstance(expr, ast.Call):
            chain = _dotted(expr.func)
            return bool(chain) and chain[-1] in _CONTAINER_CTORS
        return False

    out: Dict[str, int] = {}
    for node in ast.walk(init):
        targets: List[Tuple[ast.AST, ast.AST]] = []
        if isinstance(node, ast.Assign):
            targets = [(t, node.value) for t in node.targets]
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [(node.target, node.value)]
        for tgt, value in targets:
            attr = _self_attr(tgt)
            if attr and attr not in out and not refs_param(value) \
                    and resettable(value):
                out[attr] = tgt.lineno
    return out


def _reset_covered(cls_methods: Dict[str, ast.FunctionDef]) -> Set[str]:
    """Attrs re-initialized by ``reset`` or any self-method it calls."""
    covered: Set[str] = set()
    queue = ["reset"]
    visited: Set[str] = set()
    while queue:
        name = queue.pop()
        if name in visited or name not in cls_methods:
            continue
        visited.add(name)
        for node in ast.walk(cls_methods[name]):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    a = _self_attr(tgt)
                    if a:
                        covered.add(a)
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                a = _self_attr(node.target)
                if a:
                    covered.add(a)
            elif isinstance(node, ast.Delete):
                for tgt in node.targets:
                    a = _self_attr(tgt)
                    if a:
                        covered.add(a)
            elif isinstance(node, ast.Call):
                chain = _dotted(node.func)
                if chain and chain[0] == "self":
                    if len(chain) == 2 and chain[1] in cls_methods:
                        queue.append(chain[1])
                    elif len(chain) == 3 and chain[2] in _MUTATORS:
                        covered.add(chain[1])
    return covered


@register("pool-reset",
          "classes implementing the pooling reset() protocol must reset "
          "every mutable attribute __init__ creates")
def check_pool_reset(ctx: FileContext) -> Iterable[Finding]:
    for cls in [n for n in ast.walk(ctx.tree)
                if isinstance(n, ast.ClassDef)]:
        methods = {s.name: s for s in cls.body
                   if isinstance(s, ast.FunctionDef)}
        if "__init__" not in methods or "reset" not in methods:
            continue
        candidates = _init_candidates(methods["__init__"])
        covered = _reset_covered(methods)
        for attr, line in sorted(candidates.items(), key=lambda kv: kv[1]):
            if attr not in covered:
                yield Finding(
                    "pool-reset", ctx.path, line, 0,
                    f"{cls.name}.__init__ makes mutable state "
                    f"'self.{attr}' but reset() never re-initializes it; "
                    f"a pooled reuse would leak the previous life's state")


# --------------------------------------------------------------------------
# gen-fence


_FENCE_TOKENS = {"_ps_epoch", "_flight", "epoch", "gen", "stopped",
                 "_stopped", "closed", "done", "dead", "alive", "_ps_down"}
_REGISTER_ATTRS = {"at", "after", "send", "send_train"}


def _has_fence(fn: ast.AST) -> bool:
    """A closure is considered guarded when it references generation /
    epoch / liveness state, or pops a registry entry."""
    body = fn.body if isinstance(fn, ast.Lambda) else fn
    for node in ast.walk(body if isinstance(body, ast.AST) else fn):
        if isinstance(node, ast.Name) and node.id in _FENCE_TOKENS:
            return True
        if isinstance(node, ast.Attribute) and node.attr in _FENCE_TOKENS:
            return True
        if isinstance(node, ast.Call):
            chain = _dotted(node.func)
            if chain and chain[-1] == "pop":
                return True
    return False


def _is_delegation(fn: ast.AST) -> bool:
    """A lambda/def whose whole body is one call forwards to a method
    that carries its own guard — allowed."""
    if isinstance(fn, ast.Lambda):
        return isinstance(fn.body, ast.Call)
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        body = [s for s in fn.body
                if not (isinstance(s, ast.Expr)
                        and isinstance(s.value, ast.Constant))]
        return len(body) == 1 and isinstance(body[0], ast.Expr) \
            and isinstance(body[0].value, ast.Call)
    return False


@register("gen-fence",
          "meta['g'] only through repro.net.genfence; sim-registered "
          "closures in repro/runtime carry a staleness guard")
def check_gen_fence(ctx: FileContext) -> Iterable[Finding]:
    in_net_rt = ctx.in_package_dirs(("net", "runtime"))
    if not in_net_rt or ctx.filename == "genfence.py":
        return
    # f-string format specs (``f"{x:g}"``) carry a Constant "g" that has
    # nothing to do with the generation key
    in_fstring: Set[int] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.JoinedStr):
            for sub in ast.walk(node):
                in_fstring.add(id(sub))
    # (a) raw "g" meta key anywhere outside the sanctioned helpers
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Constant) and node.value == "g" \
                and id(node) not in in_fstring:
            yield Finding(
                "gen-fence", ctx.path, node.lineno, node.col_offset,
                "raw 'g' generation key; use repro.net.genfence "
                "(GEN_KEY / gen_of / is_stale) so every fence "
                "read/write shares one code path")

    # (b) runtime-layer closures registered on the sim / a transport
    if not ctx.in_package_dirs(("runtime",)):
        return
    for outer in [n for n in ast.walk(ctx.tree)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        local_defs = {n.name: n for n in ast.walk(outer)
                      if isinstance(n, (ast.FunctionDef,
                                        ast.AsyncFunctionDef))
                      and n is not outer}
        for call in [n for n in ast.walk(outer) if isinstance(n, ast.Call)]:
            func = call.func
            if not isinstance(func, ast.Attribute) \
                    or func.attr not in _REGISTER_ATTRS:
                continue
            if func.attr in ("at", "after"):
                base = _dotted(func.value)
                if not base or base[-1] != "sim":
                    continue
            cb_args = list(call.args) + [kw.value for kw in call.keywords]
            for arg in cb_args:
                target: Optional[ast.AST] = None
                label = "<lambda>"
                if isinstance(arg, ast.Lambda):
                    target = arg
                elif isinstance(arg, ast.Name) and arg.id in local_defs:
                    target = local_defs[arg.id]
                    label = arg.id
                if target is None:
                    continue
                if _is_delegation(target) or _has_fence(target):
                    continue
                yield Finding(
                    "gen-fence", ctx.path, call.lineno, call.col_offset,
                    f"closure {label!r} registered on the sim/transport "
                    f"without a staleness guard: check a generation / "
                    f"epoch fence (or pop a flight-registry entry) before "
                    f"touching state, or delegate to a guarded method")


# --------------------------------------------------------------------------
# hotpath


_COMP_NODES = (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)


def _tracker_guarded(test: ast.AST) -> bool:
    """True for ``if self._h_x is not None: ...`` style tracker arms —
    allocation there is off the bitwise-parity path by construction."""
    for n in ast.walk(test):
        if isinstance(n, ast.Attribute) and (
                "tracker" in n.attr or n.attr.startswith(("_h_", "_g_"))):
            return True
        if isinstance(n, ast.Name) and (
                "tracker" in n.id or n.id.startswith("_h_")):
            return True
    return False


def _hot_violations(fn: ast.AST, ctx: FileContext,
                    out: List[Finding]) -> None:
    def visit(node: ast.AST) -> None:
        if isinstance(node, ast.If) and _tracker_guarded(node.test):
            for s in node.orelse:
                visit(s)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            out.append(Finding(
                "hotpath", ctx.path, node.lineno, node.col_offset,
                f"hot path defines closure {node.name!r} per call; "
                f"pre-bind it (functools.partial / default args)"))
            return
        if isinstance(node, ast.Lambda):
            out.append(Finding(
                "hotpath", ctx.path, node.lineno, node.col_offset,
                "hot path allocates a lambda per call; pre-bind it "
                "(functools.partial / default args)"))
            return
        if isinstance(node, _COMP_NODES):
            out.append(Finding(
                "hotpath", ctx.path, node.lineno, node.col_offset,
                "hot path builds a comprehension per call; hoist the "
                "allocation or loop in place"))
            return
        if isinstance(node, ast.JoinedStr):
            out.append(Finding(
                "hotpath", ctx.path, node.lineno, node.col_offset,
                "hot path formats an f-string per call off the tracker "
                "arm; move formatting behind the tracker guard"))
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    for stmt in fn.body:
        visit(stmt)


@register("hotpath",
          "functions marked '# replint: hotpath' may not allocate "
          "closures, comprehensions, or f-strings off the tracker arm")
def check_hotpath(ctx: FileContext) -> Iterable[Finding]:
    hot = ctx.pragmas.hotpath_lines
    if not hot:
        return
    out: List[Finding] = []
    for fn in [n for n in ast.walk(ctx.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        lines = {fn.lineno} | {d.lineno for d in fn.decorator_list}
        if lines & hot:
            _hot_violations(fn, ctx, out)
    yield from out


# --------------------------------------------------------------------------
# frozen-config


_UNHASHABLE = {"List", "Dict", "Set", "DefaultDict", "Deque", "Counter",
               "MutableMapping", "MutableSequence", "MutableSet",
               "list", "dict", "set", "deque", "defaultdict", "bytearray",
               "ndarray"}


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call):
            chain = _dotted(dec.func)
            if chain and chain[-1] == "dataclass":
                for kw in dec.keywords:
                    if kw.arg == "frozen" and \
                            isinstance(kw.value, ast.Constant) and \
                            kw.value.value is True:
                        return True
    return False


@register("frozen-config",
          "frozen dataclasses in config.py must have recursively "
          "hashable field types")
def check_frozen_config(ctx: FileContext) -> Iterable[Finding]:
    if ctx.filename != "config.py":
        return
    for cls in [n for n in ast.walk(ctx.tree)
                if isinstance(n, ast.ClassDef)]:
        if not _is_frozen_dataclass(cls):
            continue
        for stmt in cls.body:
            if not isinstance(stmt, ast.AnnAssign):
                continue
            ann: ast.AST = stmt.annotation
            if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
                try:
                    ann = ast.parse(ann.value, mode="eval").body
                except SyntaxError:
                    continue
            for node in ast.walk(ann):
                name = None
                if isinstance(node, ast.Name):
                    name = node.id
                elif isinstance(node, ast.Attribute):
                    name = node.attr
                if name in _UNHASHABLE:
                    field = stmt.target.id \
                        if isinstance(stmt.target, ast.Name) else "?"
                    yield Finding(
                        "frozen-config", ctx.path, stmt.lineno,
                        stmt.col_offset,
                        f"frozen dataclass {cls.name}.{field} is typed "
                        f"{name}: unhashable fields break configs used "
                        f"as cache keys; use a tuple / frozen type")
                    break


# --------------------------------------------------------------------------
# design-ref


_CITE_RE = re.compile(r"DESIGN\.md\s*§\s*([A-Za-z0-9_]+(?:\.[0-9]+)*)")


@register("design-ref",
          "every §N citation into DESIGN.md resolves to a real section "
          "heading")
def check_design_ref(ctx: FileContext) -> Iterable[Finding]:
    sections = ctx.design_sections
    if sections is None:
        return  # no DESIGN.md governs this file (e.g. bare fixtures)
    for lineno, line in enumerate(ctx.lines, start=1):
        for m in _CITE_RE.finditer(line):
            token = m.group(1)
            if token not in sections:
                yield Finding(
                    "design-ref", ctx.path, lineno, m.start(),
                    f"citation 'DESIGN.md §{token}' does not resolve to "
                    f"any DESIGN.md section heading")
