"""Production meshes.

Single pod: (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is
pure data parallelism over the inter-pod DCN, i.e. exactly the lossy
PS-over-WAN link the paper's LTP targets (DESIGN.md §2).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (device count is locked at first jax init —
the dry-run sets XLA_FLAGS before importing anything else).
"""
from __future__ import annotations



def make_production_mesh(*, multi_pod: bool = False):
    from repro import compat
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many host devices exist (tests/examples)."""
    from repro import compat
    return compat.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
