"""Zamba2-7B — hybrid: Mamba-2 backbone + shared-weight attention blocks
[arXiv:2411.15242].

81 Mamba-2 mixer layers; a single SHARED transformer (attention+MLP) block is
applied every ``shared_attn_every`` mixer layers (weight reuse is the Zamba
trick — one set of attention weights, many applications, each with its own KV
cache slot).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv=32,                 # full MHA on the shared block
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    block_pattern=("M2",),
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_heads=112,           # d_inner=7168, mamba2 head size 64
    shared_attn_every=6,     # shared attn applied after every 6th mamba layer
    pos_type="rope",
    source="arXiv:2411.15242",
)

REDUCED = CONFIG.replace(
    name="zamba2-7b-reduced",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv=4,
    head_dim=64,
    d_ff=512,
    vocab=512,
    ssm_state=16,
    ssm_heads=8,             # d_inner=512, head size 64
    shared_attn_every=1,
)
