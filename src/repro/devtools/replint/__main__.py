"""``python -m repro.devtools.replint`` — CLI for the invariant linter.

Exit codes: 0 clean, 1 findings, 2 usage error. ``--json`` emits a
machine-readable document (schema below); the default human format is
one ``path:line:col: [rule] message`` per finding plus per-rule counts.

JSON schema::

    {"findings": [{"rule": str, "path": str, "line": int,
                   "col": int, "message": str}, ...],
     "counts": {rule: int, ...},       # only rules with findings
     "files_scanned": int}
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.devtools.replint.core import RULES, lint_paths, rule_names


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.devtools.replint",
        description="AST-based invariant linter for this repo "
                    "(DESIGN.md §13)")
    p.add_argument("paths", nargs="*",
                   help="files or directories to lint")
    p.add_argument("--select", metavar="RULES", default=None,
                   help="comma-separated rule names to run "
                        "(default: all)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit JSON instead of human-readable output")
    p.add_argument("--list-rules", action="store_true",
                   help="list available rules and exit")
    p.add_argument("--design", metavar="PATH", default=None,
                   help="DESIGN.md to resolve §N citations against "
                        "(default: nearest DESIGN.md up from each file)")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    parser = _build_parser()
    args = parser.parse_args(argv)
    names = rule_names()

    if args.list_rules:
        for name in names:
            print(f"{name:14s} {RULES[name][1]}")
        return 0

    if not args.paths:
        print("error: no paths given (try: "
              "python -m repro.devtools.replint src/)", file=sys.stderr)
        return 2

    select = None
    if args.select is not None:
        select = [r.strip() for r in args.select.split(",") if r.strip()]
        unknown = [r for r in select if r not in names]
        if unknown or not select:
            print(f"error: unknown rule(s) {', '.join(unknown) or '<none>'}"
                  f"; available: {', '.join(names)}", file=sys.stderr)
            return 2

    findings, n_files = lint_paths(args.paths, select=select,
                                   design=args.design)
    counts: dict = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1

    if args.as_json:
        doc = {"findings": [f.to_json() for f in findings],
               "counts": counts, "files_scanned": n_files}
        print(json.dumps(doc, indent=2, sort_keys=True))
    else:
        for f in findings:
            print(f.render())
        if findings:
            per_rule = ", ".join(f"{r}: {n}" for r, n in sorted(counts.items()))
            print(f"\nreplint: {len(findings)} finding(s) in {n_files} "
                  f"file(s) — {per_rule}")
        else:
            print(f"replint: clean ({n_files} file(s) scanned)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
