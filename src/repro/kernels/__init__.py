"""Pallas TPU kernels for the LTP-sync hot loops (validated interpret=True
on CPU; pass interpret=False on real TPUs).

  dropfill.py       bubble-fill + compensation over packet tiles
  packet_reduce.py  PS-side masked multi-worker reduce
  randomk.py        Random-k sparsification
  ops.py            jit'd padding-aware wrappers
  ref.py            pure-jnp oracles
"""
from repro.kernels.ops import (  # noqa: F401
    ltp_dropfill,
    ltp_packet_reduce,
    randomk_sparsify,
)
