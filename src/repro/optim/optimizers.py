"""Optimizers (no external deps): SGD+momentum (the paper's setup) and AdamW.

Functional: ``init(params) -> state``, ``update(grads, state, params) ->
(updates, state)``; updates are ADDED to params. States are pytrees with
the same sharding as params (elementwise ops — GSPMD propagates).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (updates, new_state)


@functools.lru_cache(maxsize=None)
def sgd_momentum(momentum: float = 0.9, weight_decay: float = 0.0,
                 nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        def new_m(g, m, p):
            g = g.astype(m.dtype)
            if weight_decay:
                g = g + weight_decay * p.astype(m.dtype)
            return momentum * m + g

        def upd(g, m_new, p):
            g = g.astype(m_new.dtype)
            step = (momentum * m_new + g) if nesterov else m_new
            return (-lr * step).astype(p.dtype)

        m = jax.tree.map(new_m, grads, state["m"], params)
        updates = jax.tree.map(upd, grads, m, params)
        return updates, {"m": m}

    return Optimizer(init, update)


@functools.lru_cache(maxsize=None)
def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def f32(p):
            return jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        m = jax.tree.map(
            lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32),
            grads, state["m"])
        v = jax.tree.map(
            lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            grads, state["v"])

        def upd(m, v, p):
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    """Equal knobs -> the SAME (memoized) Optimizer instance. Optimizers
    are stateless function pairs, so sharing is free — and it makes the
    identity-keyed jit caches in ``runtime.step`` hit across trainers
    built from equivalent configs (DESIGN.md §9)."""
    if cfg.optimizer == "adamw":
        return adamw(weight_decay=cfg.weight_decay)
    return sgd_momentum(momentum=cfg.momentum, weight_decay=cfg.weight_decay)


def lr_at(cfg: TrainConfig, step, steps_per_epoch: int = 0):
    """Paper schedule: lr *= decay every ``lr_decay_every`` epochs."""
    lr = cfg.lr
    if cfg.lr_decay_every and steps_per_epoch:
        epoch = step // steps_per_epoch
        n = epoch // cfg.lr_decay_every
        lr = cfg.lr * (cfg.lr_decay ** n)
    return lr
