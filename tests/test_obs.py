"""Observability layer (DESIGN.md §12): Tracker backends, the metrics
registry, Chrome-trace export/validation on a faulted DES run, and the
tracker="none" bitwise-parity guarantee."""
import csv
import json
import os

import pytest

from repro.config import (
    LTPConfig,
    NetConfig,
    ObservabilityConfig,
    TrainConfig,
)
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.tracker import (
    CompositeTracker,
    CsvTracker,
    JsonlTracker,
    MemoryTracker,
    make_tracker,
    read_jsonl,
)
from repro.obs.trace import chrome_trace, validate_chrome_trace
from repro.optim import make_optimizer
from repro.runtime import (
    ClusterRuntime,
    FaultEvent,
    FaultSchedule,
    LognormalStragglerCompute,
)

W = 4
STEPS = 5
NET = NetConfig(10, 1, 0.001, 4096)


@pytest.fixture(scope="module")
def api():
    return build(get_config("papernet").replace(d_model=8, n_layers=3))


def _rt(api, *, obs=None, faults=None, policy="bsp", steps=STEPS, w=W,
        seed=11, **kw):
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
    if faults is not None:
        kw["faults"] = faults
    return ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(staleness_comp=0.5), NET,
        n_workers=w, policy=policy, transport="des",
        compute_model=LognormalStragglerCompute(
            w, base=0.05, seed=seed, sigma=0.3,
            straggler_prob=0.15, straggler_mult=5.0),
        seed=seed, obs=obs, **kw)


def _run(rt, steps=STEPS, w=W):
    rt.run(batches(SyntheticCIFAR(seed=3), 4 * w, steps))
    return rt


# ---------------------------------------------------------------------------
# tracker backends
# ---------------------------------------------------------------------------


def test_memory_tracker_captures_and_finishes():
    t = MemoryTracker()
    t.log_event({"kind": "apply", "t": 0.1, "step": 0})
    t.log_metrics({"loss": 1.5}, step=0)
    t.log_summary({"steps": 1})
    t.finish()
    assert t.events[0]["kind"] == "apply"
    assert t.metrics[0]["loss"] == 1.5 and t.metrics[0]["step"] == 0
    assert t.summary == {"steps": 1}
    assert t.finished


def test_jsonl_tracker_round_trip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with JsonlTracker(path) as t:
        t.log_event({"kind": "apply", "t": 0.1, "step": 0})
        t.log_metrics({"loss": 1.5}, step=0)
        t.log_summary({"steps": 1})
    recs = read_jsonl(path)
    kinds = [r.get("kind") for r in recs]
    assert kinds == ["apply", "metrics", "summary"]
    assert recs[1]["loss"] == 1.5
    assert recs[2]["steps"] == 1


def test_jsonl_tracker_buffers_until_finish(tmp_path):
    # lazy-scalar contract: nothing hits disk before finish(), so JAX
    # scalars finalized in place after the run serialize as floats
    path = str(tmp_path / "lazy.jsonl")
    t = JsonlTracker(path)
    e = {"kind": "apply", "t": 0.1, "loss": None}
    t.log_event(e)
    assert not os.path.exists(path) or os.path.getsize(path) == 0
    e["loss"] = 2.5          # mutate the buffered dict, as the runtime does
    t.finish()
    assert read_jsonl(path)[0]["loss"] == 2.5


def test_csv_tracker_union_header_and_summary(tmp_path):
    path = str(tmp_path / "run.csv")
    with CsvTracker(path) as t:
        t.log_event({"kind": "apply", "t": 0.1, "step": 0})
        t.log_event({"kind": "block", "t": 0.2, "worker": 1})
        t.log_summary({"steps": 1})
    with open(path) as f:
        rows = list(csv.DictReader(f))
    # union-of-keys header: every record exposes every column
    assert {"kind", "t", "step", "worker"} <= set(rows[0].keys())
    assert rows[0]["kind"] == "apply" and rows[1]["worker"] == "1"
    with open(path + ".summary.json") as f:
        assert json.load(f) == {"steps": 1}


def test_composite_fans_out():
    a, b = MemoryTracker(), MemoryTracker()
    c = CompositeTracker([a, b])
    c.log_event({"kind": "apply", "t": 0.0})
    c.finish()
    assert len(a.events) == len(b.events) == 1
    assert a.finished and b.finished


def test_make_tracker_none_and_unknown(tmp_path):
    assert make_tracker(ObservabilityConfig(tracker="none"), "r") is None
    assert make_tracker(ObservabilityConfig(tracker=""), "r") is None
    with pytest.raises(ValueError, match="unknown tracker"):
        make_tracker(ObservabilityConfig(tracker="bogus"), "r")
    t = make_tracker(ObservabilityConfig(
        tracker="memory,jsonl", out_dir=str(tmp_path)), "myrun")
    assert isinstance(t, CompositeTracker)
    t.finish()
    assert os.path.exists(str(tmp_path / "myrun.jsonl"))


def test_tensorboard_tracker_optional():
    pytest.importorskip("tensorboardX")
    from repro.obs.tracker import TensorBoardTracker  # noqa: F401


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_and_gauge():
    c = Counter("n")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = Gauge("g")
    g.set(2.5)
    assert g.value == 2.5


def test_histogram_exact_stats_and_percentiles():
    h = Histogram("h", reservoir=8, seed=0)
    for v in range(100):
        h.observe(float(v))
    snap = h.snapshot()
    # exact regardless of reservoir size
    assert snap["count"] == 100
    assert snap["min"] == 0.0 and snap["max"] == 99.0
    assert snap["mean"] == pytest.approx(49.5)
    # percentiles come from the 8-sample reservoir: bounded, seeded
    assert 0.0 <= snap["p50"] <= 99.0


def test_histogram_deterministic_under_seed():
    def fill(seed):
        h = Histogram("h", reservoir=4, seed=seed)
        for v in range(50):
            h.observe(float(v))
        return h.snapshot()
    assert fill(7) == fill(7)


def test_registry_absorb_and_snapshot():
    reg = MetricsRegistry(reservoir=16, seed=0)
    reg.counter("a").inc(3)
    reg.histogram("h").observe(1.0)
    reg.absorb("flow", {"n_retx": 2, "n_ack_trains": 10})
    reg.absorb("flow", {"n_retx": 5, "n_ack_trains": 11})  # cumulative SET
    snap = reg.snapshot()
    assert snap["a"] == 3
    assert snap["flow/n_retx"] == 5
    assert snap["flow/n_ack_trains"] == 11
    assert snap["h/count"] == 1
    # get-or-create returns the same instrument
    assert reg.counter("a") is reg.counter("a")


# ---------------------------------------------------------------------------
# chrome trace — acceptance criterion: faulted DES run exports a
# Perfetto-loadable trace that passes schema validation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def faulted_rt(api):
    faults = FaultSchedule([
        FaultEvent(0.08, "worker_crash", W - 1),
        FaultEvent(0.30, "ps_fail", 0, recover_s=0.02),
        FaultEvent(0.60, "worker_join", W - 1),
    ])
    rt = _rt(api, obs=ObservabilityConfig(tracker="memory"),
             faults=faults, steps=6, checkpoint_every_s=0.1)
    return _run(rt, steps=6)


def test_faulted_trace_validates(faulted_rt, tmp_path):
    path = str(tmp_path / "trace.json")
    doc = faulted_rt.export_trace(path)
    with open(path) as f:
        loaded = json.load(f)          # the artifact itself must parse
    problems = validate_chrome_trace(
        loaded, n_workers=W, n_ps=faulted_rt.n_ps,
        require_fault_markers=True)
    assert problems == [], problems
    assert doc["traceEvents"]          # and the in-memory doc matches
    phs = {e["ph"] for e in loaded["traceEvents"]}
    assert {"X", "i", "M", "C"} <= phs


def test_trace_has_fault_and_failover_markers(faulted_rt):
    doc = chrome_trace(faulted_rt.tel.events, n_workers=W,
                       n_ps=faulted_rt.n_ps)
    names = {e["name"] for e in doc["traceEvents"]}
    assert any(n.startswith("fault:") for n in names)
    assert "ps_failover" in names
    assert "checkpoint" in names


def test_trace_spans_non_negative_and_metadata_complete(faulted_rt):
    doc = chrome_trace(faulted_rt.tel.events, n_workers=W,
                       n_ps=faulted_rt.n_ps)
    for e in doc["traceEvents"]:
        if e["ph"] == "X":
            assert e["dur"] >= 0, e
    thread_meta = [e for e in doc["traceEvents"]
                   if e["ph"] == "M" and e["name"] == "thread_name"]
    # a track per worker on both the worker and transport processes
    assert len([m for m in thread_meta if m["pid"] == 1]) >= W
    assert len([m for m in thread_meta if m["pid"] == 2]) >= W


def test_tracker_run_populates_metrics_and_summary(faulted_rt):
    mem = faulted_rt.tracker
    assert mem.finished
    assert len(mem.events) == len(faulted_rt.tel.events)
    assert len(mem.metrics) == 6            # one per step
    s = mem.summary
    assert s["n_faults"] == 3 and s["n_failovers"] == 1
    # registry scalars rode along: sim perf + flow counters
    assert "sim/events" in s and "flow/n_retx" in s
    assert "worker/compute_s/count" in s


# ---------------------------------------------------------------------------
# tracker="none" bitwise parity (acceptance criterion)
# ---------------------------------------------------------------------------


def _strip_trunks(events):
    out = []
    for e in events:
        if e["kind"] == "queue" and "trunks" in e:
            e = {k: v for k, v in e.items() if k != "trunks"}
        out.append(e)
    return out


def test_tracker_none_bitwise_parity(api):
    base = _run(_rt(api, obs=None))
    obs = _run(_rt(api, obs=ObservabilityConfig(tracker="memory")))
    assert base.history == obs.history
    # event streams identical modulo the trunks field the sampler adds
    # only on the tracker-active arm
    assert base.tel.events == _strip_trunks(obs.tel.events)
    assert base.tel.summary() == {
        k: v for k, v in obs.tel.summary().items()}
