import json
import os

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# chaos-failure forensics (DESIGN.md §14)
#
# Chaos tests register their runtimes through the ``chaos_forensics``
# fixture; when such a test fails, the makereport hook dumps the seed,
# the armed fault/netfault schedules, and the tail of the telemetry
# stream to ``.pytest_artifacts/<test>.json`` so the exact run can be
# replayed without re-deriving the drawn timeline from the seed.
# ---------------------------------------------------------------------------

_ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                             ".pytest_artifacts")
_FORENSICS_TAIL = 80
_registry = {}   # nodeid -> list of registered runtimes


@pytest.fixture
def chaos_forensics(request):
    """Call the yielded function on every ClusterRuntime the test
    builds; on failure their fault state is dumped as an artifact."""
    rts = _registry.setdefault(request.node.nodeid, [])

    def register(rt):
        rts.append(rt)
        return rt

    yield register
    _registry.pop(request.node.nodeid, None)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return repr(v)


def _forensics(rt):
    out = {
        "seed": getattr(rt, "seed", None),
        "n_workers": getattr(rt, "w", None),
        "n_ps": getattr(rt, "n_ps", None),
        "policy": type(getattr(rt, "policy", None)).__name__,
        "transport": getattr(rt, "transport", None),
        "sim_now": getattr(getattr(rt, "sim", None), "now", None),
    }
    faults = getattr(rt, "faults", None)
    if faults is not None:
        out["faults"] = [e.label() for e in faults]
    net_faults = getattr(rt, "net_faults", None)
    if net_faults is not None:
        out["net_faults"] = [e.label() for e in net_faults]
    tel = getattr(rt, "tel", None)
    if tel is not None and tel.events:
        out["n_events"] = len(tel.events)
        out["events_tail"] = _jsonable(tel.events[-_FORENSICS_TAIL:])
    return out


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    report = outcome.get_result()
    if report.when != "call" or not report.failed:
        return
    rts = _registry.get(item.nodeid)
    if not rts:
        return
    os.makedirs(_ARTIFACT_DIR, exist_ok=True)
    safe = item.nodeid.replace("/", "_").replace("::", "-")
    path = os.path.join(_ARTIFACT_DIR, f"{safe}.json")
    try:
        with open(path, "w") as f:
            json.dump({"test": item.nodeid,
                       "runs": [_forensics(rt) for rt in rts]}, f,
                      indent=1, default=repr)
        report.sections.append(
            ("chaos forensics", f"fault-state dump written to {path}"))
    except Exception as exc:   # a broken dump must not mask the failure
        report.sections.append(
            ("chaos forensics", f"dump failed: {exc!r}"))
