"""cProfile dump for the runtime DES cell (CI diagnosability artifact).

Runs the same w=8 bsp/ltp packet-level co-simulation cell that
``runtime_sweep`` gates (warm: one unprofiled run first, so the profile
shows the steady state the events/sec floor is measured in, not one-time
jit compilation), and writes the top-N cumulative-time functions to
``profile_runtime_des.txt``. CI's perf-smoke job uploads the file as an
artifact — when the regression gate trips, the hot path that moved is
readable straight from the run page.

  PYTHONPATH=src python -m benchmarks.profile_runtime
  PYTHONPATH=src python -m benchmarks.profile_runtime --out prof.txt --top 40
"""
from __future__ import annotations

import argparse
import cProfile
import io
import pstats
import time

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.net import simcore
from repro.optim import make_optimizer
from repro.runtime import ClusterRuntime, LognormalStragglerCompute

from benchmarks.runtime_sweep import COMPUTE_KW

TOP_N = 25


def _cell(api, tc, net, w, steps, seed=11):
    compute = LognormalStragglerCompute(w, base=0.05, seed=seed,
                                        **COMPUTE_KW)
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(staleness_comp=0.5), net,
        n_workers=w, protocol="ltp", policy="bsp", compute_model=compute,
        compute_time=0.05, seed=seed, transport="des")
    rt.run(batches(SyntheticCIFAR(seed=3), tc.batch, steps),
           epoch_steps=max(1, steps // 2))


def run(out: str = "profile_runtime_des.txt", top: int = TOP_N) -> str:
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    net = NetConfig(10, 1, 0.001, 4096)
    w, steps = 8, 2
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
    _cell(api, tc, net, w, steps)            # warm jit caches + pools
    simcore.PERF.reset()
    prof = cProfile.Profile()
    t0 = time.time()
    prof.enable()
    _cell(api, tc, net, w, steps)
    prof.disable()
    wall = time.time() - t0
    buf = io.StringIO()
    buf.write(
        f"runtime DES cell (w={w}, bsp, ltp, steps={steps}) — warm run\n"
        f"wall={wall:.3f}s packet_events={simcore.PERF.packets} "
        f"heap_events={simcore.PERF.events} "
        f"events_per_sec={simcore.PERF.packets / max(wall, 1e-9):,.0f}\n\n")
    stats = pstats.Stats(prof, stream=buf)
    stats.sort_stats("cumulative").print_stats(top)
    with open(out, "w") as f:
        f.write(buf.getvalue())
    print(buf.getvalue().splitlines()[0])
    print(f"wrote {out} (top {top} by cumulative time)")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="profile_runtime_des.txt")
    ap.add_argument("--top", type=int, default=TOP_N)
    args = ap.parse_args(argv)
    run(out=args.out, top=args.top)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
