"""Packet-level discrete-event transport simulator.

Reproduces the paper's protocol-level experiments at packet granularity:
Fig 3 (incast FCT long tail), Fig 4 (TCP under non-congestion loss),
Fig 12/14 (training throughput / BST), Fig 15 (fairness).
"""
from repro.net.simcore import Sim, Pipe, Packet  # noqa: F401
from repro.net.scenarios import (  # noqa: F401
    incast_gather,
    p2p_transfer,
    fairness_share,
    train_iterations,
)
