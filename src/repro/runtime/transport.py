"""Transport legs for the cluster runtime (DESIGN.md §8).

Two backends carry a worker's gradient from grad-ready to the PS on the
runtime's shared ``Sim`` clock:

``AnalyticPerWorkerNet``
    Fast closed-form per-flow timing for the async/SSP paths: each
    worker's gather leg is an independent transfer whose serialization
    shares the trunk with the flows active *at its start* (a bounded
    approximation of true interleaving), inflated by the protocol's
    loss model and an incast tail draw — the same ingredients as
    ``AnalyticIncastModel``, applied per flow instead of per barrier.
    LTP flows run the per-flow Early Close rule (LT threshold, pct
    target, deadline); reliable protocols wait for their last byte.

``DESTransport``
    The packet-level co-simulation: real LTP/TCP senders and receivers
    over a shared ``Topology`` (one trunk per PS shard, optional
    heterogeneous access links and cross traffic via ``GatherSpec``),
    with flows starting the instant the worker's compute finishes. Per
    iteration, bsp runs one ``ShardedGatherReceiver`` barrier gather;
    async/SSP run one single-flow ``PSGatherReceiver`` per (worker,
    shard) so every flow closes independently.

    Flow graphs are POOLED (DESIGN.md §9): senders, receivers, and the
    per-flow ack back-channel pipes are built once and recycled across
    iterations through the ``reset(gen)`` protocol — the per-round flow
    generation fences stale in-flight traffic out of the next round —
    and packet trains (``coalesce``) are on by default.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.config import LTPConfig, NetConfig
from repro.core.early_close import AnalyticIncastModel
from repro.net import senders as snd
from repro.net.genfence import GEN_KEY
from repro.net.ltp_receiver import PSGatherReceiver, ShardedGatherReceiver
from repro.net.scenarios import (
    GatherSpec,
    _build_topology,
    _fwd_path,
    _npkts,
)
from repro.net.simcore import Packet, Pipe, Sim
from repro.net.topology import resolve_topology


class AnalyticPerWorkerNet:
    """Closed-form per-flow transport (the async/SSP fast path).

    ``send(worker, cb)`` schedules ``cb(frac, early_closed)`` at the
    flow's close time. The model: first byte lands after rtprop/2 + eps;
    100% would land after ``bytes * active / (bw/8) * loss_inflation *
    (1 + tail)``; LTP closes per the paper's double-threshold rule
    evaluated against that linear arrival ramp.
    """

    def __init__(self, sim: Sim, net: NetConfig, ltp: LTPConfig,
                 protocol: str, n_workers: int, model_bytes: float,
                 seed: int = 0, tail_prob: float = 0.15,
                 tail_scale: float = 1.5):
        self.sim = sim
        self.net = net
        self.ltp = ltp
        self.protocol = protocol
        self.w = n_workers
        self.bytes = float(model_bytes)
        self.rng = np.random.default_rng(seed + 77)
        self.tail_prob = tail_prob
        self.tail_scale = tail_scale
        # reuse the calibrated per-protocol loss-inflation law
        self._infl = AnalyticIncastModel(
            net, n_workers, protocol=protocol, seed=seed).loss_inflation()
        self.active = 0
        rt = net.rtprop_ms * 1e-3
        share = net.bandwidth_gbps * 1e9 / 8.0 / n_workers
        self.lt = ltp.lt_init_rtprop_mult * rt + self.bytes / share
        self.deadline = self.lt + ltp.deadline_c_ms * 1e-3

    def send(self, worker: int,
             cb: Callable[[float, bool], None]) -> None:
        rt = self.net.rtprop_ms * 1e-3
        bw = self.net.bandwidth_gbps * 1e9 / 8.0
        self.active += 1
        tail = (self.rng.exponential(self.tail_scale)
                if self.rng.random() < self.tail_prob else 0.0)
        t0 = rt
        t_full = rt + self.bytes * self.active / bw * self._infl * (1.0 + tail)
        if self.protocol != "ltp" or t_full <= self.lt:
            t_close, frac, early = t_full, 1.0, False
        else:
            # earliest t >= LT with pct >= threshold; deadline wins
            t_thr = t0 + self.ltp.data_pct_threshold * (t_full - t0)
            t_close = min(max(self.lt, t_thr), self.deadline)
            frac = float(np.clip((t_close - t0) / max(t_full - t0, 1e-12),
                                 0.0, 1.0))
            if t_close >= t_full:
                t_close, frac, early = t_full, 1.0, False
            else:
                early = True

        def done():
            self.active -= 1
            cb(frac, early)

        # ``cb`` is the runtime's on_delivered/on_close, which pops its
        # flight-registry entry itself; the analytic net has no pooled
        # flow lives of its own to fence.
        self.sim.after(t_close, done)  # replint: ok(gen-fence)


def _send_stop_pkt(tr: "DESTransport", back: Pipe, s) -> None:
    """Early-Close "stop" on the ack back-channel. Under coalescing the
    stop rides the same train machinery as data ACKs (one-packet train),
    matching ``_DESFlowSet``'s ack path; per-packet otherwise. The stop
    carries the sender's current flow generation so a stop for a
    finished iteration cannot kill the pooled sender's next life."""
    stop = Packet(s.flow, -2, 41, kind="stop", meta={GEN_KEY: s.gen})
    if tr.coalesce > 1:
        back.send_train([stop], s.on_ack_train)
    else:
        back.send(stop, s.on_ack)


class _DESFlowSet:
    """Per-worker flow bundle on the shared topology: one single-flow
    gather receiver per PS shard; fires ``cb`` once all shards have
    closed.

    Pooled (DESIGN.md §9): the runtime creates ONE flow set per worker
    and recycles it every iteration through ``begin`` — the back-channel
    pipes, senders, receivers, and their wiring closures are built once;
    each iteration only resets their state (a new flow generation drops
    stragglers from the previous round).
    """

    def __init__(self, tr: "DESTransport", worker: int):
        self.tr = tr
        self.worker = worker
        self.gen = 0
        self.idle = True    # free for reuse (its last round fully closed)
        self.cb: Optional[Callable[[np.ndarray, float, bool], None]] = None
        self.masks: List[Optional[np.ndarray]] = [None] * tr.n_ps
        self.closed = 0
        self.early = False
        self.backs: List[Pipe] = []
        self.senders: List = []
        self.recvs: List = []
        self._ones = np.ones(tr.n, bool)
        for p in range(tr.n_ps):
            self._build_flow(p)

    def _build_flow(self, p: int) -> None:
        tr, w = self.tr, self.worker
        path = _fwd_path(tr.topo, tr.spec, tr.owner[p], w, tr.protocol)
        back = Pipe(tr.sim, tr.bw, tr.half_rtt, tr.net.loss_rate, 10_000,
                    tr.rng)
        if tr.protocol == "ltp":
            def send_stop(flow, p=p, back=back):
                _send_stop_pkt(tr, back, self.senders[p])

            def on_close(recv, p=p):
                full = recv.all_full
                self._shard_done(p, recv.delivery_masks()[0], not full)

            recv = PSGatherReceiver(
                tr.sim, [w], tr.lt_per_worker[w], tr.deadline_per_worker[w],
                tr.pct_eff[p], send_stop, on_close=on_close)
            # orphan recovery: data from an older generation means that
            # life of the sender never got its stop (lost in flight) —
            # re-stop it, but only while it still lives that generation
            # (a reset sender must not be killed by its past round)
            recv.on_stale = (lambda flow, g, p=p, back=back:
                             self._stop_stale(p, g, back))
            s = snd.LTPSender(tr.sim, path,
                              recv.on_data, tr.n, critical=tr.crit, flow=w,
                              rng=tr.rng, train_len=tr.coalesce)
            if tr.heal:
                s.heal = True
                s.on_flow_dead = tr._flow_dead
            recv.attach_ack(w, lambda pkt, s=s, back=back:
                            back.send(pkt, s.on_ack))
            if tr.coalesce > 1:
                s.deliver_train = recv.on_data_train
                recv.attach_ack_train(
                    w, lambda acks, s=s, back=back:
                    back.send_train(acks, s.on_ack_train))
        else:
            def on_done(s, p=p):
                self._shard_done(p, self._ones, False)

            s = snd.make_sender(tr.protocol, tr.sim, path, None,
                                tr.n, flow=w, rng=tr.rng, on_done=on_done,
                                train_len=tr.coalesce)
            recv = snd.TcpReceiver(tr.sim, lambda pkt, s=s, back=back:
                                   back.send(pkt, s.on_ack), w)
            s.deliver = recv.on_data
            if tr.coalesce > 1:
                s.deliver_train = recv.on_data_train
                recv.send_ack_train = (lambda acks, s=s, back=back:
                                       back.send_train(acks, s.on_ack_train))
            recv.n_total = tr.n
        self.backs.append(back)
        self.senders.append(s)
        self.recvs.append(recv)

    def _stop_stale(self, p: int, g, back: Pipe) -> None:
        s = self.senders[p]
        if g is not None and s.gen == g and not s.done:
            _send_stop_pkt(self.tr, back, s)

    def begin(self, cb: Callable[[np.ndarray, float, bool], None]) -> None:
        """Start (or restart) this worker's shard flows for one round."""
        self.gen += 1
        self.idle = False
        self.cb = cb
        self.masks = [None] * self.tr.n_ps
        self.closed = 0
        self.early = False
        for p in range(self.tr.n_ps):
            self.backs[p].recycle()
            if self.tr.protocol == "ltp":
                self.recvs[p].reset(gen=self.gen)
            else:
                self.recvs[p].reset(gen=self.gen, n_total=self.tr.n)
            self.senders[p].reset(gen=self.gen)
            self.senders[p].start()

    def teardown(self) -> None:
        """Hard-stop this bundle mid-round (node/PS death, DESIGN.md
        §10): the flow generation bumps so every packet still in flight
        is fenced out as stale, the pooled senders go silent, receivers
        deactivate, and the set returns to the free list. The runtime
        accounts the dropped gradient; no callback fires."""
        self.gen += 1
        self.cb = None
        for p in range(self.tr.n_ps):
            self.senders[p].kill()
            self.senders[p].gen = self.gen
            if self.tr.protocol == "ltp":
                self.recvs[p].deactivate(gen=self.gen)
            else:
                self.recvs[p].reset(gen=self.gen)
            self.backs[p].recycle()
        self.masks = [None] * self.tr.n_ps
        self.closed = 0
        self.early = False
        self.idle = True

    def _shard_done(self, p: int, mask: np.ndarray, early: bool) -> None:
        if self.cb is None or self.masks[p] is not None:
            return
        self.masks[p] = mask
        self.early = self.early or early
        self.closed += 1
        if self.closed >= self.tr.n_ps:
            stacked = np.stack(self.masks)          # (n_ps, n)
            frac = float(stacked.mean())
            self.idle = True    # every shard closed: free for reuse
            self.cb(stacked, frac, self.early)


class _DESBarrierGather:
    """Per-iteration bsp gather on the shared topology: one
    ``ShardedGatherReceiver`` over all W workers; senders join as their
    compute finishes (the runtime's start_delays, made event-driven).

    Pooled (DESIGN.md §9): built once per transport; each iteration
    calls ``begin`` to reset the sharded receiver and bump the flow
    generation, and ``add_worker`` resets+restarts that worker's pooled
    senders instead of constructing new ones.
    """

    def __init__(self, tr: "DESTransport"):
        self.tr = tr
        self.gen = 0
        self.cb: Optional[Callable[[ShardedGatherReceiver], None]] = None
        self.t0 = tr.sim.now
        self._senders: Dict = {}
        self._backs: Dict = {}

        def send_stop(p, f):
            s = self._senders.get((p, f))
            if s is not None:
                _send_stop_pkt(tr, self._backs[(p, f)], s)

        self.sharded = ShardedGatherReceiver(
            tr.sim, tr.n_ps, list(range(tr.w)),
            [tr.lt_shard] * tr.n_ps, [tr.deadline_shard] * tr.n_ps,
            tr.ltp.data_pct_threshold, send_stop)
        self._n_closed = 0
        for p, shard in enumerate(self.sharded.shards):
            # per-shard effective Early-Close threshold (the budget
            # controller's knob, DESIGN.md §14) — identical to the
            # config value until a controller moves it
            shard.pct_threshold = tr.pct_eff[p]
            shard.on_close = self._shard_closed
            # orphan recovery: a sender whose stop was lost and whose
            # shard closed before its next add_worker reset would pump
            # retransmissions forever — re-stop it while it still lives
            # the stale generation (see _DESFlowSet._stop_stale)
            shard.on_stale = (lambda flow, g, p=p:
                              self._stop_stale(p, flow, g))

    def begin(self, cb: Callable[[ShardedGatherReceiver], None],
              members=None) -> None:
        """Arm the barrier for a fresh iteration. ``members`` (optional)
        is the active worker set: flows outside it are abandoned up
        front so the close rule only waits on live nodes."""
        self.gen += 1
        self.cb = cb
        self.t0 = self.tr.sim.now
        self._n_closed = 0
        self.sharded.reset(gen=self.gen)
        if members is not None and len(members) < self.tr.w:
            for w in range(self.tr.w):
                if w not in members:
                    self.sharded.abandon_worker(w)

    def abandon_worker(self, worker: int) -> None:
        """Mid-round node death: kill the worker's pooled senders, fence
        their generation, and drop the flows from every shard's close
        rule (which may complete the barrier)."""
        self.tr._mark_live(worker, False)
        for p in range(self.tr.n_ps):
            s = self._senders.get((p, worker))
            if s is not None:
                s.kill()
                s.gen = self.gen + 1   # fence: future stops can't match
                self._backs[(p, worker)].recycle()
        self.sharded.abandon_worker(worker)

    def abort(self) -> None:
        """PS death mid-round: silence everything; no callback fires.
        The next ``begin`` revives the pooled graph."""
        self.cb = None
        for s in self._senders.values():
            s.kill()
        self.sharded.deactivate(gen=self.gen + 1)
        self.gen += 1
        for back in self._backs.values():
            back.recycle()

    def _stop_stale(self, p: int, flow: int, g) -> None:
        s = self._senders.get((p, flow))
        if s is not None and g is not None and s.gen == g and not s.done:
            _send_stop_pkt(self.tr, self._backs[(p, flow)], s)

    def _shard_closed(self, shard: PSGatherReceiver) -> None:
        if self.cb is None:
            return
        self.tr.on_early_close(shard.ps_id, self.tr.sim.now,
                               float(shard.agg_pct), shard.all_full,
                               lat=shard.bst_gather())
        self._n_closed += 1
        if self._n_closed >= self.tr.n_ps:
            self.cb(self.sharded)

    def add_worker(self, worker: int) -> None:
        """Start worker's shard flows now (its compute just finished)."""
        tr = self.tr
        if tr.topo.aggs:
            tr._mark_live(worker, True)
        for p in range(tr.n_ps):
            shard = self.sharded.shard(p)
            if shard.closed:
                continue   # shard already gave up on this straggler
            key = (p, worker)
            s = self._senders.get(key)
            if s is None:
                back = Pipe(tr.sim, tr.bw, tr.half_rtt, tr.net.loss_rate,
                            10_000, tr.rng)
                s = snd.LTPSender(
                    tr.sim, _fwd_path(tr.topo, tr.spec, tr.owner[p], worker,
                                      tr.protocol),
                    shard.on_data, tr.n, critical=tr.crit,
                    flow=worker, rng=tr.rng, train_len=tr.coalesce)
                if tr.heal:
                    s.heal = True
                    s.on_flow_dead = tr._flow_dead
                if tr.coalesce > 1:
                    s.deliver_train = shard.on_data_train
                self._backs[key] = back
                self._senders[key] = s
                s.gen = self.gen    # align with this round's receivers
            else:
                back = self._backs[key]
                back.recycle()
                s.reset(gen=self.gen)
            shard.attach_ack(worker, lambda pkt, s=s, back=back:
                             back.send(pkt, s.on_ack))
            if tr.coalesce > 1:
                shard.attach_ack_train(
                    worker, lambda acks, s=s, back=back:
                    back.send_train(acks, s.on_ack_train))
            s.start()


#: default train length for the runtime's packet-level co-simulation:
#: the netsim grid's measured sweet spot (BENCH_netsim.json). Pass
#: ``coalesce=1`` for the per-packet reference path.
DEFAULT_COALESCE = 32


class DESTransport:
    """Packet-level transport on the runtime's shared clock. bsp uses
    ``start_gather``/``add_worker`` (one barrier gather per iteration);
    async/SSP use ``send`` (independent per-worker flow sets). LTP flows
    in this transport carry static LT thresholds from the paper's init
    formula (per-link attainable share); the epoch-adaptive LT update of
    ``scenarios._iterate_gather`` is out of scope here.

    ``coalesce`` defaults to ``DEFAULT_COALESCE`` packet trains
    (DESIGN.md §7/§9) — the per-packet path is opt-in via
    ``coalesce=1``, not the default the runtime silently pays for."""

    def __init__(self, sim: Sim, net: NetConfig, ltp: LTPConfig,
                 protocol: str, n_workers: int, model_bytes: float,
                 n_ps: Optional[int] = None, spec: Optional[GatherSpec] = None,
                 seed: int = 0, coalesce: Optional[int] = None,
                 on_early_close: Optional[Callable] = None,
                 topology: Optional[GatherSpec] = None):
        self.sim = sim
        self.net = net
        self.ltp = ltp
        self.protocol = protocol
        self.w = n_workers
        self.spec = resolve_topology(topology, n_ps=n_ps, spec=spec,
                                     owner="DESTransport")
        self.spec.validate_workers(n_workers, "DESTransport")
        self.n_ps = self.spec.n_ps
        self.rng = np.random.default_rng(seed + 101)
        self.bw = net.bandwidth_gbps * 1e9
        self.half_rtt = net.rtprop_ms * 1e-3
        shard_bytes = model_bytes / self.n_ps
        self.n = _npkts(shard_bytes, protocol)
        if coalesce is None:
            # auto: coalesced by default, but never trains so long that
            # the Early Close rule loses granularity on short flows
            # (~8 close checks per shard flow minimum)
            self.coalesce = min(DEFAULT_COALESCE, max(1, self.n // 8))
        else:
            self.coalesce = max(1, int(coalesce))
        self.topo, self.sources = _build_topology(
            sim, net, n_workers, self.spec, self.rng, self.coalesce)
        # shard -> owning-PS route map (identity until a PS failover
        # rebalance re-homes a dead PS's shards, DESIGN.md §10)
        self.owner: List[int] = list(range(self.n_ps))
        crit = np.zeros(self.n, bool)
        ncrit = max(2, int(0.01 * self.n))
        crit[: ncrit // 2] = True
        crit[-(ncrit - ncrit // 2):] = True
        self.crit = crit
        rt = net.rtprop_ms * 1e-3
        c = ltp.deadline_c_ms * 1e-3
        self.lt_per_worker = np.empty(n_workers)
        for f in range(n_workers):
            share = self.spec.worker_share_bps(f, n_workers, net) / 8.0
            self.lt_per_worker[f] = (ltp.lt_init_rtprop_mult * rt
                                     + shard_bytes / share)
        self.deadline_per_worker = self.lt_per_worker + c
        self.lt_shard = float(self.lt_per_worker.max())
        self.deadline_shard = self.lt_shard + c
        self._on_early_close = on_early_close
        # self-healing (DESIGN.md §14): armed by the runtime only while
        # a network fault plane is active; the default keeps every
        # pooled sender on the exact pre-fault-plane timing
        self.heal = False
        self._on_flow_dead: Optional[Callable[[int], None]] = None
        # per-shard effective Early-Close pct threshold — the budget
        # controller's actuation knob (DESIGN.md §14); equals the config
        # value until a controller moves it
        self.pct_eff: List[float] = [ltp.data_pct_threshold] * self.n_ps
        # flow pools (DESIGN.md §9): per-worker flow-set free lists
        # (async/SSP; a worker's next flow can start while the previous
        # one is still draining, so reuse requires ``idle``), one barrier
        # gather (bsp), recycled across iterations
        self._flowsets: Dict[int, List[_DESFlowSet]] = {}
        self._barrier: Optional[_DESBarrierGather] = None
        # trunk handles cached once: telemetry sampling must not rebuild
        # a name->depth dict per sample
        self._trunks = [self.topo.pipes[f"ps{p}/trunk"]
                        for p in range(self.n_ps)]

    def stop(self) -> None:
        for src in self.sources:
            src.stop()

    # -- self-healing + budget control (DESIGN.md §14) ----------------------
    def enable_healing(self, on_flow_dead: Callable[[int], None]) -> None:
        """Arm RTO backoff + blackhole detection on every pooled LTP
        sender (existing and future). ``on_flow_dead(worker)`` fires
        when a sender declares its path dead after ``BLACKHOLE_RTOS``
        silent RTOs — the runtime tears the worker's flows exactly like
        the node-death ``flow_torn`` path."""
        self.heal = True
        self._on_flow_dead = on_flow_dead
        for s in self._all_senders():
            if isinstance(s, snd.LTPSender):
                s.heal = True
                s.on_flow_dead = self._flow_dead

    def _flow_dead(self, worker: int) -> None:
        if self._on_flow_dead is not None:
            self._on_flow_dead(worker)

    def set_pct_threshold(self, shard: int, pct: float) -> None:
        """Move shard's effective Early-Close pct threshold (the budget
        controller's actuation, DESIGN.md §14). Applies to the pooled
        receivers in place — ``pct_threshold`` survives their pooled
        resets — and to flow graphs built later."""
        self.pct_eff[shard] = float(pct)
        for pool in self._flowsets.values():
            for fs in pool:
                r = fs.recvs[shard]
                if hasattr(r, "pct_threshold"):
                    r.pct_threshold = float(pct)
        if self._barrier is not None:
            self._barrier.sharded.shard(shard).pct_threshold = float(pct)

    def _all_senders(self) -> List:
        out: List = []
        for pool in self._flowsets.values():
            for fs in pool:
                out.extend(fs.senders)
        if self._barrier is not None:
            out.extend(self._barrier._senders.values())
        return out

    def _mark_live(self, worker: int, alive: bool) -> None:
        """Keep the ToR aggregation points' live-membership in sync with
        node churn (DESIGN.md §10/§11): a dead rack member must not gate
        membership flushes (the switch would fall back to hold-timer
        flushes for every seq); a rejoined one must again."""
        for sw in self.topo.aggs.values():
            sw.set_live(worker, alive)

    # -- fault teardown (DESIGN.md §10) -------------------------------------
    def teardown_worker(self, worker: int) -> None:
        """Node death: fence + silence the worker's in-flight flow sets.
        (bsp barrier flows are torn through the gather's
        ``abandon_worker`` — the runtime owns that round state.)"""
        self._mark_live(worker, False)
        for fs in self._flowsets.get(worker, []):
            if not fs.idle:
                fs.teardown()

    def teardown_all(self) -> None:
        """PS death: fence + silence every in-flight flow graph."""
        for pool in self._flowsets.values():
            for fs in pool:
                if not fs.idle:
                    fs.teardown()
        if self._barrier is not None:
            self._barrier.abort()

    def set_shard_owners(self, owner: List[int]) -> None:
        """Re-home shard routes after a PS failover rebalance. The
        pooled flow graphs were built against the old routes, so the
        pools are dropped and rebuilt lazily on the next send — a rare,
        bounded cost (faults, not steady state)."""
        if list(owner) == self.owner:
            return
        self.owner = list(owner)
        self._flowsets = {}
        self._barrier = None

    def on_early_close(self, shard: int, t: float, delivered: float,
                       full: bool, lat: float = 0.0) -> None:
        """``lat`` is the gather's close latency (close - t0): the budget
        controller's primary distress signal — a degraded fabric shows up
        as late closes long before the delivered fraction moves."""
        if self._on_early_close is not None and not full:
            self._on_early_close(shard, t, delivered, lat)

    # -- async/SSP: independent per-worker flow sets ------------------------
    def send(self, worker: int,
             cb: Callable[[np.ndarray, float, bool], None]) -> None:
        if self.topo.aggs:
            self._mark_live(worker, True)
        pool = self._flowsets.setdefault(worker, [])
        fs = next((f for f in pool if f.idle), None)
        if fs is None:
            fs = _DESFlowSet(self, worker)
            pool.append(fs)
        fs.begin(cb)

    # -- bsp: one barrier gather per iteration ------------------------------
    def start_gather(self, cb: Callable[[ShardedGatherReceiver], None],
                     members=None) -> _DESBarrierGather:
        if self._barrier is None:
            self._barrier = _DESBarrierGather(self)
        self._barrier.begin(cb, members=members)
        return self._barrier

    def queue_depth_pkts(self) -> float:
        """Max trunk queue depth right now (telemetry sampler hook);
        O(n_ps) over cached pipe handles — no dict rebuild per sample."""
        return max((p.queue_len() for p in self._trunks), default=0.0)

    def trunk_depths(self) -> Tuple[float, ...]:
        """Per-trunk queue depths right now (observability sampler hook,
        DESIGN.md §12); O(n_ps) on the ``Sim.every`` grid only."""
        return tuple(p.queue_len() for p in self._trunks)

    def flow_stats(self) -> Dict[str, float]:
        """Cumulative per-flow protocol counters summed over every
        pooled sender/receiver plus the in-network aggregation points
        (DESIGN.md §12): retransmits, ACK trains consumed, sender-side
        generation-fenced ACKs, receiver-side fenced data packets,
        post-close stop re-sends, and ``agg/*`` switch stats. Pools
        dropped by a failover rebalance (``set_shard_owners``) take
        their counts with them — a rare, bounded fault path."""
        out: Dict[str, float] = {"n_retx": 0, "n_ack_trains": 0,
                                 "n_gen_fenced": 0, "n_stale_fenced": 0,
                                 "n_stop_resends": 0}
        senders: List = []
        recvs: List = []
        for pool in self._flowsets.values():
            for fs in pool:
                senders.extend(fs.senders)
                recvs.extend(fs.recvs)
        if self._barrier is not None:
            senders.extend(self._barrier._senders.values())
            recvs.extend(self._barrier.sharded.shards)
        out["n_flow_dead"] = 0
        for s in senders:
            out["n_retx"] += getattr(s, "n_retx", 0)
            out["n_ack_trains"] += getattr(s, "n_ack_trains", 0)
            out["n_gen_fenced"] += getattr(s, "n_gen_fenced", 0)
            out["n_flow_dead"] += getattr(s, "n_flow_dead", 0)
        for r in recvs:
            out["n_stale_fenced"] += getattr(r, "n_stale_fenced", 0)
            out["n_stop_resends"] += getattr(r, "n_stop_resends", 0)
        for sw in self.topo.aggs.values():
            for k, v in sw.stats().items():
                if k != "pending":
                    out[f"agg/{k}"] = out.get(f"agg/{k}", 0) + v
        return out
