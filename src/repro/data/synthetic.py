"""Deterministic synthetic datasets (offline container — no CIFAR download).

SyntheticCIFAR: class-templated 32x32x3 images + noise. Linear-separable-ish
but noisy enough that accuracy climbs over epochs like a real small-vision
task; used for the paper's accuracy/TTA experiments (Figs 5, 12, 13).

SyntheticLM: sequences from a fixed random bigram chain over the vocab.
The achievable cross-entropy floor is the chain's conditional entropy, so
training curves show real learning (loss falls from ln(V) toward the
floor) — used for LM-side LTP accuracy checks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticCIFAR:
    n_classes: int = 10
    n_train: int = 50_000
    n_test: int = 10_000
    noise: float = 0.6
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # two template components per class -> not linearly trivial
        self.templates = rng.normal(0, 1, (self.n_classes, 2, 32, 32, 3)).astype(
            np.float32
        )

    def _make(self, n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, self.n_classes, n)
        comp = rng.integers(0, 2, n)
        mix = rng.uniform(0.6, 1.0, (n, 1, 1, 1)).astype(np.float32)
        base = self.templates[labels, comp] * mix
        imgs = base + rng.normal(0, self.noise, base.shape).astype(np.float32)
        return imgs.astype(np.float32), labels.astype(np.int32)

    def train_batch(self, batch: int, step: int) -> Dict[str, np.ndarray]:
        imgs, labels = self._make(batch, seed=1000 + step)
        return {"images": imgs, "labels": labels}

    def test_set(self, n: int = 2048) -> Dict[str, np.ndarray]:
        imgs, labels = self._make(n, seed=7)
        return {"images": imgs, "labels": labels}


@dataclasses.dataclass
class SyntheticLM:
    vocab: int = 512
    seed: int = 0
    concentration: float = 0.02   # smaller -> peakier bigram -> lower floor

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        logits = rng.gumbel(size=(self.vocab, self.vocab)) / self.concentration
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.trans = (p / p.sum(axis=1, keepdims=True)).astype(np.float64)
        self.entropy_floor = float(
            -(self.trans * np.log(np.maximum(self.trans, 1e-12))).sum(axis=1).mean()
        )
        self._cum = np.cumsum(self.trans, axis=1)

    def sample(self, batch: int, seq: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        out = np.empty((batch, seq + 1), np.int64)
        out[:, 0] = rng.integers(0, self.vocab, batch)
        u = rng.random((batch, seq))
        for t in range(seq):
            out[:, t + 1] = np.array(
                [np.searchsorted(self._cum[s], x) for s, x in zip(out[:, t], u[:, t])]
            )
        return np.minimum(out, self.vocab - 1)

    def train_batch(self, batch: int, seq: int, step: int) -> Dict[str, np.ndarray]:
        toks = self.sample(batch, seq, seed=2000 + step)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


def batches(dataset, batch: int, steps: int, seq: int = 0) -> Iterator[Dict]:
    for step in range(steps):
        if isinstance(dataset, SyntheticLM):
            yield dataset.train_batch(batch, seq, step)
        else:
            yield dataset.train_batch(batch, step)
