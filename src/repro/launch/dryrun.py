import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct stand-ins — no allocation — and
extract the roofline inputs from the compiled artifact.

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
                                               [--ltp]   # LTP-sync train step

Outputs one JSON per combination under benchmarks/dryrun_results/.
"""  # noqa: E402

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.config import LTPConfig
from repro.configs import ARCH_IDS, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import (
    HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh,
)
from repro.models import build
from repro.models.api import input_specs, shape_supported
from repro.models.sharding import ShardCtx, dp_axes, param_specs, spec_for
from repro.optim import sgd_momentum
from repro.shapes import SHAPES, get_shape
from repro.train.trainer import TrainState, make_ltp_train_step, make_plain_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../..", "benchmarks",
                           "dryrun_results")


# ----------------------------------------------------------------------------
# Sharding of inputs
# ----------------------------------------------------------------------------


def _fits(n: int, k: int) -> bool:
    return k > 1 and n % k == 0


def batch_spec(name: str, sds, shape, mesh, *, dp) -> P:
    """PartitionSpec for one input leaf by name/shape convention."""
    dims = sds.shape
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    dpspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    nm = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if name == "positions3":
        return P(None, dpspec if _fits(dims[1], ndp) else None, None)
    spec = [None] * len(dims)
    if dims and _fits(dims[0], ndp):
        spec[0] = dpspec
    if name in ("patch_embeds", "frames") and _fits(dims[-1], nm):
        spec[-1] = "model"
    return P(*spec)


def cache_spec(sds, global_batch: int, mesh, *, dp) -> P:
    """Heuristic cache sharding: batch dim over dp, largest remaining
    model-divisible dim over 'model'."""
    dims = sds.shape
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    dpspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    nm = mesh.shape["model"] if "model" in mesh.axis_names else 1
    spec: list = [None] * len(dims)
    for i, d in enumerate(dims):
        if d == global_batch and _fits(d, ndp):
            spec[i] = dpspec
            break
    best = -1
    for i, d in enumerate(dims):
        if spec[i] is None and _fits(d, nm):
            if best < 0 or d > dims[best]:
                best = i
    if best >= 0:
        spec[best] = "model"
    return P(*spec)


def input_shardings(cfg, shape, mesh) -> Any:
    dp = dp_axes(mesh)
    specs = input_specs(cfg, shape)

    def assign(path, sds):
        name = ""
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                name = str(p.key)
                break   # top-level name decides ('cache' subtree handled below)
        if name == "cache":
            return cache_spec(sds, shape.global_batch, mesh, dp=dp)
        if name == "pos":
            return P()
        return batch_spec(name, sds, shape, mesh, dp=dp)

    return specs, jax.tree_util.tree_map_with_path(assign, specs)


# ----------------------------------------------------------------------------
# Step builders
# ----------------------------------------------------------------------------


def build_train(cfg, shape, mesh, *, ltp: bool, zero: bool = False):
    if ltp:
        # XLA:CPU's AllReducePromotion pass CHECK-fails on the bf16
        # all-reduces the partitioner emits inside manual shard_map
        # regions (CloneAllReduce/"copy"). The LTP variant therefore
        # lowers with f32 activations on this backend — matmul partial
        # sums are f32 on real TPUs anyway; byte terms reported by the
        # dry-run are f32-inflated on this backend accordingly.
        cfg = cfg.replace(dtype="float32")
    api = build(cfg)
    opt = sgd_momentum()
    key = jax.random.PRNGKey(0)
    state_sds = jax.eval_shape(
        lambda: TrainState(
            params=(p := api.init(key)),
            opt_state=opt.init(p),
            step=jnp.zeros((), jnp.int32),
        )
    )
    fsdp = not ltp   # LTP workers hold replicated weights (PS semantics)
    state_specs = jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for(path, x.shape, mesh, fsdp=fsdp), state_sds
    )
    in_sds, in_specs = input_shardings(cfg, shape, mesh)
    lr_sds = jax.ShapeDtypeStruct((), jnp.float32)

    if not ltp:
        step = make_plain_train_step(api, opt, mesh)
        args = (state_sds, in_sds, lr_sds)
        shardings = (state_specs, in_specs, P())
        fn = step
    else:
        # every data-parallel rank is one of the paper's workers; on the
        # multi-pod mesh that covers the cross-pod DCN link (XLA:CPU's
        # partitioner CHECK-fails on a pod-only manual submesh, so the
        # worker set is (pod, data) rather than pod alone)
        worker = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        nw = 1
        for a in worker:
            nw *= mesh.shape[a]
        ltp_cfg = LTPConfig()
        if zero:
            # ZeRO-style packet-space momentum, sharded over the workers
            from repro.core.ltp_sync import zero_momentum_shapes
            m_sds = zero_momentum_shapes(state_sds.params, ltp_cfg, nw)
            wspec = worker if len(worker) > 1 else worker[0]
            state_sds = TrainState(
                params=state_sds.params,
                opt_state={"m_pkts": m_sds},
                step=state_sds.step,
            )
            state_specs = TrainState(
                params=state_specs.params,
                opt_state={"m_pkts": [P(wspec, None)] * len(m_sds)},
                step=P(),
            )
        step = make_ltp_train_step(
            api, opt, mesh, ltp_cfg, worker, in_specs
        )
        frac_sds = jax.ShapeDtypeStruct((nw,), jnp.float32)
        key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
        args = (state_sds, in_sds, frac_sds, key_sds, lr_sds)
        shardings = (state_specs, in_specs, P(), P(), P())
        fn = step
    return fn, args, shardings


def build_prefill(cfg, shape, mesh):
    api = build(cfg)
    ctx = ShardCtx(mesh)
    in_sds, in_specs = input_shardings(cfg, shape, mesh)
    params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    p_specs = param_specs(params_sds, mesh)

    def fn(params, inputs):
        return api.prefill(params, inputs, ctx=ctx)

    return fn, (params_sds, in_sds), (p_specs, in_specs)


def build_decode(cfg, shape, mesh):
    api = build(cfg)
    ctx = ShardCtx(mesh)
    in_sds, in_specs = input_shardings(cfg, shape, mesh)
    params_sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    p_specs = param_specs(params_sds, mesh)

    def fn(params, cache, token, pos):
        return api.decode_step(params, cache, token, pos, ctx=ctx)

    args = (params_sds, in_sds["cache"], in_sds["token"], in_sds["pos"])
    shardings = (p_specs, in_specs["cache"], in_specs["token"], in_specs["pos"])
    return fn, args, shardings


# ----------------------------------------------------------------------------
# Runner
# ----------------------------------------------------------------------------


def to_named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def run_one(arch: str, shape_name: str, *, multi_pod: bool, ltp: bool = False,
            zero: bool = False, save: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "step": {"train": "train_step", "prefill": "prefill",
                 "decode": "serve_step"}[shape.kind],
        "ltp": ltp, "zero": zero, "ok": False,
    }
    sup, why = shape_supported(cfg, shape)
    if not sup:
        rec["skipped"] = why
        rec["ok"] = True
        _save(rec, save)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        if shape.kind == "train":
            fn, args, specs = build_train(cfg, shape, mesh, ltp=ltp, zero=zero)
        elif shape.kind == "prefill":
            fn, args, specs = build_prefill(cfg, shape, mesh)
        else:
            fn, args, specs = build_decode(cfg, shape, mesh)
        shardings = to_named(mesh, specs)
        t0 = time.time()
        with compat.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, f, None)
                if v is not None:
                    rec.setdefault("memory", {})[f] = int(v)
        ca = compiled.cost_analysis() or {}
        rec["xla_cost"] = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                          if k in ca}
        t0 = time.time()
        cost = hlo_analysis.analyze(compiled.as_text())
        rec["analyze_s"] = round(time.time() - t0, 1)
        rec["walker"] = {
            "flops": cost.flops,
            "bytes": cost.bytes,
            "collective_bytes": cost.collective_bytes,
            "by_collective": cost.by_collective,
        }
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _save(rec, save)
    return rec


def _save(rec: Dict, save: bool):
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = "_ltpzero" if rec.get("zero") else ("_ltp" if rec.get("ltp") else "")
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def roofline_terms(rec: Dict, n_chips: int) -> Dict[str, float]:
    """Three roofline terms in seconds (per-device walker numbers)."""
    w = rec.get("walker", {})
    return {
        "compute_s": w.get("flops", 0) / PEAK_FLOPS_BF16,
        "memory_s": w.get("bytes", 0) / HBM_BW,
        "collective_s": w.get("collective_bytes", 0) / ICI_BW,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--ltp", action="store_true",
                    help="lower the LTP-sync train step instead of plain")
    ap.add_argument("--ltp-zero", action="store_true",
                    help="LTP with packet-space reduce-scatter + sharded "
                         "momentum (beyond-paper, see EXPERIMENTS §Perf)")
    args = ap.parse_args(argv)

    archs = [a for a in ARCH_IDS if a != "papernet"] if args.arch is None \
        else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]
    if not args.all and args.arch is None and args.shape is None:
        ap.error("pass --all or --arch/--shape")

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                t0 = time.time()
                rec = run_one(arch, shape, multi_pod=mp,
                              ltp=args.ltp or args.ltp_zero, zero=args.ltp_zero)
                status = "SKIP" if "skipped" in rec else (
                    "OK" if rec["ok"] else "FAIL")
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
                mem = rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30
                print(f"[{status:4s}] {arch:18s} {shape:12s} "
                      f"{rec['mesh']:8s}{' ltp' if args.ltp else ''} "
                      f"temp={mem:6.2f}GiB wall={time.time()-t0:5.1f}s "
                      f"{rec.get('error','')}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
