"""Batched serving demo: prefill + decode with the KV-cache serve path
(the same serve_step the decode dry-runs lower at pod scale).

  PYTHONPATH=src python examples/serve.py [--arch smollm_360m] [--new 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced(args.arch).replace(dtype="float32")
    api = build(cfg)
    if api.decode_step is None:
        raise SystemExit(f"{args.arch} has no serve path")
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    max_seq = args.prompt_len + args.new
    cache = api.init_cache(args.batch, max_seq, jnp.float32)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)

    decode = jax.jit(api.decode_step)
    # prompt processing token-by-token (works for every family incl. SSM)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, t], jnp.int32(t))
    prefill_s = time.time() - t0

    # batched greedy decode
    tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.new - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0
    toks = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prompt {args.prompt_len} toks: {prefill_s:.2f}s | "
          f"decode {args.new} toks: {decode_s:.2f}s "
          f"({args.batch * (args.new-1) / max(decode_s,1e-9):.1f} tok/s)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {toks[b].tolist()}")


if __name__ == "__main__":
    main()
