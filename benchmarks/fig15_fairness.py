"""Paper Fig 15: bandwidth shares when LTP coexists with other congestion
controls on one bottleneck."""
from __future__ import annotations

from repro.config import NetConfig
from repro.net.scenarios import fairness_share

from benchmarks.common import emit


def run(quick: bool = True):
    rows = []
    dur = 0.15 if quick else 0.5
    pairs = [("ltp", "bbr")] if quick else \
        [("ltp", "bbr"), ("ltp", "cubic"), ("bbr", "bbr"), ("ltp", "ltp")]
    for a, b in pairs:
        sa, sb = fairness_share(a, b, NetConfig(10, 1, 0.0, 4096),
                                duration=dur, seed=0)
        rows.append({
            "proto_a": a, "proto_b": b,
            "share_a": round(sa, 3), "share_b": round(sb, 3),
            "a_vs_b_ratio": round(sa / max(sb, 1e-9), 3),
        })
    return emit(rows, "fig15_fairness")


if __name__ == "__main__":
    run(quick=False)
