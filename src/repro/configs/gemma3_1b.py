"""Gemma3-1B — dense with 5:1 local:global attention, 512-token window
[hf:google/gemma-3-1b-pt]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv=1,
    head_dim=256,            # gemma3 decouples head_dim from d_model/n_heads
    d_ff=6912,
    vocab=262144,
    block_pattern=("W", "W", "W", "W", "W", "A"),  # 5 local : 1 global
    window=512,
    rope_theta=1e6,
    qk_norm=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)

REDUCED = CONFIG.replace(
    name="gemma3-1b-reduced",
    n_layers=2,              # one local + one global layer
    block_pattern=("W", "A"),
    d_model=256,
    n_heads=4,
    n_kv=1,
    head_dim=32,
    d_ff=512,
    vocab=512,
    window=64,
)
