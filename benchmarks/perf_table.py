"""Render the README perf table from ``BENCH_netsim.json``.

  PYTHONPATH=src python -m benchmarks.perf_table [path/to/BENCH_netsim.json]

Prints a GitHub-flavored markdown table; the README "Performance" section
is this script's output, regenerated whenever the baseline is refreshed.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.sweep_scenarios import REPO_ROOT


def render(path: str) -> str:
    with open(path) as f:
        doc = json.load(f)
    m = doc["metrics"]
    k = m.get("grid64_coalesce", "?")
    lines = [
        "| cell (64 workers, 2 MB model) | wall s | sim packet-events/s |",
        "|---|---:|---:|",
    ]
    for proto in ("ltp", "cubic"):
        for n_ps in (1, 4):
            wall = m.get(f"grid64_{proto}_ps{n_ps}_wall_s")
            eps = m.get(f"grid64_{proto}_ps{n_ps}_events_per_sec")
            if wall is None:
                continue
            lines.append(f"| {proto} x {n_ps} PS (trains of {k}) "
                         f"| {wall:g} | {eps:,.0f} |")
    ref = m.get("grid64_ref_per_packet_events_per_sec")
    twin = m.get("grid64_ref_coalesced_events_per_sec")
    if ref and twin:
        lines.append(f"| 64x4 reference: per-packet -> trains of {k} "
                     f"| — | {ref:,.0f} -> {twin:,.0f} "
                     f"({m.get('grid64_coalesce_speedup', '?')}x) |")
    sweep = m.get("sweep_small_wall_s")
    if sweep is not None:
        lines.append(f"| small scenario grid (4 protocols x 7 cells) "
                     f"| {sweep:g} | — |")
    return "\n".join(lines)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else os.path.join(REPO_ROOT, "BENCH_netsim.json")
    print(render(path))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
