"""Event-driven cluster runtime: compute/network co-simulation with
pluggable PS aggregation policies (DESIGN.md §8)."""
from repro.runtime.compute import (  # noqa: F401
    COMPUTE_MODELS,
    ComputeModel,
    DeterministicCompute,
    LognormalStragglerCompute,
    TraceCompute,
    make_compute_model,
)
from repro.net.netfaults import (  # noqa: F401
    LINK_FAULT_KINDS,
    LinkFaultEvent,
    LinkFaultSchedule,
    NetFaultPlane,
    netfault_schedule_from_config,
)
from repro.runtime.budget import BudgetController  # noqa: F401
from repro.runtime.faults import (  # noqa: F401
    FAULT_KINDS,
    FaultEvent,
    FaultSchedule,
    ShardLedger,
    schedule_from_config,
)
from repro.runtime.policies import (  # noqa: F401
    POLICIES,
    AggregationPolicy,
    AsyncPolicy,
    BSPPolicy,
    PendingGrad,
    SSPPolicy,
    make_policy,
)
from repro.runtime.runtime import ClusterRuntime  # noqa: F401
from repro.runtime.telemetry import Telemetry  # noqa: F401
from repro.runtime.transport import (  # noqa: F401
    AnalyticPerWorkerNet,
    DESTransport,
)
