"""Per-architecture smoke tests: REDUCED variant of each assigned family,
one forward/train step on CPU, asserting output shapes + no NaNs; decode
smoke where the family supports it (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import build
from repro.models.api import demo_inputs, shape_supported
from repro.optim import sgd_momentum
from repro.shapes import InputShape

TRAIN = InputShape("t", 64, 2, "train")
DECODE = InputShape("d", 96, 2, "decode")
KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    api = build(cfg)
    params = api.init(KEY)
    batch = demo_inputs(cfg, TRAIN, KEY)
    loss, grads = jax.value_and_grad(lambda p: api.loss_fn(p, batch))(params)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    # one optimizer step moves the loss
    opt = sgd_momentum()
    st = opt.init(params)
    upd, _ = opt.update(grads, st, params, jnp.float32(0.1))
    params2 = jax.tree.map(lambda p, u: p + u, params, upd)
    loss2 = api.loss_fn(params2, batch)
    assert np.isfinite(float(loss2))
    assert float(loss2) < float(loss) + 1.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_reduced(arch)
    api = build(cfg)
    if api.decode_step is None:
        pytest.skip("train-only workload (papernet)")
    params = api.init(KEY)
    cache = api.init_cache(2, 96, jnp.float32)
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache2 = api.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (2, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    # cache got written somewhere
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["falcon_mamba_7b", "zamba2_7b"])
def test_ssm_decode_matches_forward(arch):
    """Step-by-step decode must reproduce the full-sequence forward
    (recurrence correctness — the SSM analogue of a KV-cache test)."""
    cfg = get_reduced(arch).replace(dtype="float32")
    api = build(cfg)
    params = api.init(KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (1, 12), 0, cfg.vocab)
    from repro.models import transformer
    logits_full, _, _ = transformer.forward(cfg, params, {"tokens": toks},
                                            remat=False)
    cache = api.init_cache(1, 16, jnp.float32)
    outs = []
    for t in range(12):
        lg, cache = api.decode_step(params, cache, toks[:, t], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec[0], np.float32),
        np.asarray(logits_full[0], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_attention_decode_matches_forward():
    cfg = get_reduced("smollm_360m").replace(dtype="float32")
    api = build(cfg)
    params = api.init(KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 10), 0, cfg.vocab)
    from repro.models import transformer
    logits_full, _, _ = transformer.forward(cfg, params, {"tokens": toks},
                                            remat=False)
    cache = api.init_cache(2, 16, jnp.float32)
    outs = []
    for t in range(10):
        lg, cache = api.decode_step(params, cache, toks[:, t], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_mla_decode_matches_forward():
    cfg = get_reduced("deepseek_v2_236b").replace(dtype="float32")
    api = build(cfg)
    params = api.init(KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (1, 8), 0, cfg.vocab)
    from repro.models import transformer
    logits_full, _, _ = transformer.forward(cfg, params, {"tokens": toks},
                                            remat=False)
    cache = api.init_cache(1, 8, jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, cache, toks[:, t], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    # absorbed decode == decompressed forward (MoE routing may flip on ties)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=5e-2, atol=5e-2)


def test_sliding_window_attention_banded_equals_masked():
    """Static-banded window attention == full attention with window mask."""
    from repro.models.attention import multi_head_attention
    k1, k2, k3 = jax.random.split(KEY, 3)
    b, s, h, kv, hd, w = 2, 256, 4, 2, 16, 64
    q = jax.random.normal(k1, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(k2, (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, s, kv, hd), jnp.float32)
    banded = multi_head_attention(q, k, v, causal=True, window=w, chunk_q=64)
    # reference: full attention with explicit band mask via _traced path
    from repro.models.attention import _traced_window_attention
    full = _traced_window_attention(q, k, v, jnp.int32(w),
                                    ctx=__import__("repro.models.sharding",
                                                   fromlist=["NULL_CTX"]).NULL_CTX)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long500k_support_matrix(arch):
    cfg = get_reduced(arch)
    long = InputShape("long_500k", 1024, 1, "decode")
    ok, why = shape_supported(cfg, long)
    expected = {
        "falcon_mamba_7b": True, "zamba2_7b": True, "gemma3_1b": True,
        "mixtral_8x22b": True,
        "yi_34b": False, "smollm_360m": False, "qwen2_vl_72b": False,
        "qwen3_14b": False, "whisper_small": False, "deepseek_v2_236b": False,
        "papernet": False,
    }
    assert ok == expected[arch], (arch, why)
