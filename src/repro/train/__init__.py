from repro.train.trainer import TrainState, make_plain_train_step, make_ltp_train_step  # noqa: F401
from repro.train.dp_sim import PSTrainer  # noqa: F401
