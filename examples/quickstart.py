"""Quickstart: the paper's experiment in miniature.

Trains the CIFAR-like CNN over 8 simulated workers + 1 PS with LTP
(Early Close + bubble-filling) vs a lossless TCP-like baseline on a
lossy 10G network, and prints throughput / accuracy side by side.

  PYTHONPATH=src python examples/quickstart.py [--steps 60]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.models.cnn import accuracy
from repro.optim import make_optimizer
from repro.train import PSTrainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--loss-rate", type=float, default=0.001)
    ap.add_argument("--workers", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config("papernet").replace(d_model=16)
    api = build(cfg)
    tc = TrainConfig(batch=128, lr=0.05, steps=args.steps)
    data = SyntheticCIFAR(seed=0)
    test = {k: jnp.asarray(v) for k, v in data.test_set(1024).items()}
    net = NetConfig(bandwidth_gbps=10, rtprop_ms=1,
                    loss_rate=args.loss_rate, queue_pkts=4096)

    print(f"== papernet on {args.workers} workers, loss={args.loss_rate} ==")
    # short smoke runs (CI) still get at least one eval at the end
    eval_every = max(1, min(20, args.steps))
    results = {}
    for proto in ["ltp", "cubic"]:
        print(f"\n--- protocol: {proto} ---")
        tr = PSTrainer(api, make_optimizer(tc), tc, LTPConfig(), net,
                       n_workers=args.workers, protocol=proto,
                       compute_time=0.05, seed=0)
        tr.run(batches(data, tc.batch, tc.steps), epoch_steps=20,
               eval_fn=lambda p: accuracy(cfg, p, test),
               eval_every=eval_every, log_every=10)
        results[proto] = tr
    print("\n== summary ==")
    for proto, tr in results.items():
        accs = [h.get("eval") for h in tr.history if "eval" in h]
        print(f"{proto:6s}: throughput {tr.throughput(tc.batch):7.0f} img/s "
              f"| final acc {accs[-1]:.3f} "
              f"| mean delivered "
              f"{np.mean([h['delivered'] for h in tr.history]):.3f}")
    sp = results["ltp"].throughput(tc.batch) / results["cubic"].throughput(tc.batch)
    print(f"LTP speedup vs cubic: {sp:.2f}x (accuracy preserved)")


if __name__ == "__main__":
    main()
