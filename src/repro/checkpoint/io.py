"""Checkpointing: pytree <-> .npz with slash-joined key paths.

Host-gathered (fine at example scale; a sharded production store would
write per-device shards — out of scope for the CPU container, noted in
DESIGN.md)."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays: Dict[str, np.ndarray] = {
        _path_str(p): np.asarray(v) for p, v in flat
    }
    arrays["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def restore_checkpoint(path: str, like: Any):
    """Restores into the structure of ``like``. Returns (tree, step)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    step = int(data["__step__"])
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, old in flat:
        key = _path_str(p)
        arr = data[key]
        assert arr.shape == tuple(old.shape), (key, arr.shape, old.shape)
        leaves.append(jax.numpy.asarray(arr, dtype=old.dtype))
    _, treedef2 = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(treedef2, leaves), step
