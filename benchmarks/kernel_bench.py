"""Kernel microbench — the PS-side hot loop as Pallas tiles (DESIGN.md §7).

Times ``kernels.dropfill`` (bubble-fill + compensation gate) and
``kernels.packet_reduce`` (fused masked multi-worker reduction) through
the ``ops.py`` padding wrappers, plus the end-to-end sync step
(``core.ltp_sync.reduce_packet_stream``) under both backends.

On CPU the kernels run in interpret mode, so the GB/s figures are the
*interpreter's* — a stable regression baseline for CI, not hardware
numbers; on a real TPU pass ``interpret=False`` for roofline rates.

Writes ``BENCH_kernels.json`` at the repo root (consumed by
``benchmarks.check_regression``) and the usual rows under results/.

  PYTHONPATH=src python -m benchmarks.run --only kernel_bench
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LTPConfig
from repro.core.ltp_sync import reduce_packet_stream
from repro.kernels import ops

from benchmarks.common import emit
from benchmarks.sweep_scenarios import write_bench


def _time(fn, *args, reps: int = 3, **kw) -> float:
    """Best-of-reps wall seconds, after one compile/warmup call."""
    jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.time() - t0)
    return best


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    w = 8
    n = 1024 if quick else 8192
    p = 360                       # non-lane-aligned: exercises ops padding
    pkts_w = jnp.asarray(rng.normal(size=(w, n, p)).astype(np.float32))
    masks_w = jnp.asarray((rng.random((w, n)) < 0.8).astype(np.float32))
    pkts = pkts_w[0]
    mask = masks_w[0]
    scale = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))

    rows = []
    metrics = {}

    t = _time(ops.ltp_dropfill, pkts, mask, scale)
    gb = 2 * n * p * 4 / 1e9      # one read + one write of the stream
    rows.append({"kernel": "dropfill", "shape": f"({n},{p})",
                 "wall_s": round(t, 4), "gbps": round(gb / t, 3)})
    metrics["dropfill_wall_s"] = round(t, 4)
    metrics["dropfill_gbps"] = round(gb / t, 3)

    t = _time(ops.ltp_packet_reduce, pkts_w, masks_w)
    gb = (w + 1) * n * p * 4 / 1e9    # W reads + one write per output tile
    rows.append({"kernel": "packet_reduce", "shape": f"({w},{n},{p})",
                 "wall_s": round(t, 4), "gbps": round(gb / t, 3)})
    metrics["packet_reduce_wall_s"] = round(t, 4)
    metrics["packet_reduce_gbps"] = round(gb / t, 3)

    ltp = LTPConfig(compensation="count")
    for backend in ("python", "pallas"):
        fn = jax.jit(lambda pw, mw, be=backend: reduce_packet_stream(
            pw, mw, ltp, w, backend=be))
        t = _time(fn, pkts_w, masks_w)
        rows.append({"kernel": f"sync_{backend}", "shape": f"({w},{n},{p})",
                     "wall_s": round(t, 4)})
        metrics[f"sync_{backend}_wall_s"] = round(t, 4)

    write_bench(metrics, quick, "BENCH_kernels.json")
    emit(rows, "kernel_bench")
    return rows


if __name__ == "__main__":
    run(quick=True)
