"""Network-layer fault plane: link/switch chaos for the netsim
(DESIGN.md §14).

``LinkFaultSchedule`` is the fabric-level sibling of the node-level
``runtime.faults.FaultSchedule``: a seeded, immutable, time-sorted list
of link and switch events, armed on the shared ``Sim`` clock and
dispatched through a ``NetFaultPlane`` that maps event targets onto the
live ``Topology`` pipes and ``AggSwitch`` instances. Determinism is the
contract — the same schedule against the same seeds replays the same
co-simulation event-for-event.

Event semantics (realized by ``NetFaultPlane.dispatch``):

  link_down      admin-down a named pipe. The pipe's ``link_gen`` bumps,
                 so every delivery already on the wire is fenced out at
                 arrival (the §9 generation pattern applied to the
                 physical layer — no silent delivery from a dead link).
                 New sends reroute onto the spine-redundant backup where
                 one exists, and blackhole otherwise. ``recover_s`` > 0
                 schedules the matching ``link_up``.
  link_up        admin-up the pipe.
  link_flap      a square-wave of down/up toggles: ``duty`` fraction of
                 each ``period_s`` spent down, for ``duration_s``.
  link_degrade   cut the line rate to ``rate_factor`` x base and/or add
                 ``extra_loss`` random loss; ``recover_s`` > 0 schedules
                 the matching ``link_restore``.
  link_restore   restore base rate/loss.
  switch_crash   crash every ``AggSwitch`` homed in the target rack:
                 pending partial reductions are lost (their members'
                 seqs stay un-ACKed — senders retransmit after
                 recovery), intake blackholes until ``switch_recover``.
  switch_recover bring the rack's switches back.
  partition      cut the target rack clean off the spine: uplink AND its
                 backup go down together (no reroute escape). ``heal``
                 reverses it; ``recover_s`` > 0 schedules it.
  heal           reconnect a partitioned rack.

Targets are strings: pipe names from the topology registry
(``"rack2/up"``, ``"ps0/trunk"``) for link events, ``"rack{r}"`` for
switch and partition events.

Safety guarantee for drawn schedules: ``LinkFaultSchedule.random`` never
admin-downs a trunk (a trunk has no redundant twin — downing it would
sever every path to that shard), never partitions a PS-home rack (that
would sever every *other* rack's path to the shard), and thins partition
/ switch-crash draws so at most ``max_cut`` racks are ever cut
concurrently — the fabric mirror of ``FaultSchedule.random``'s
``min_active`` thinning. ``max_concurrent_cut`` replays a schedule's cut
timeline and is what the property tests pin.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.net.simcore import Pipe, Sim, Topology

LINK_FAULT_KINDS = (
    "link_down",
    "link_up",
    "link_flap",
    "link_degrade",
    "link_restore",
    "switch_crash",
    "switch_recover",
    "partition",
    "heal",
)

#: kinds whose active interval severs a rack's every path (used by the
#: cut-ceiling thinning and by ``max_concurrent_cut``)
_CUT_KINDS = ("partition", "switch_crash")


@dataclasses.dataclass(frozen=True)
class LinkFaultEvent:
    """One injected fabric fault on the sim clock."""

    t: float
    kind: str
    target: str = ""
    recover_s: float = 0.0     # auto-recovery delay (0 = permanent)
    rate_factor: float = 1.0   # link_degrade: line-rate multiplier
    extra_loss: float = 0.0    # link_degrade: added loss probability
    period_s: float = 0.0      # link_flap: square-wave period
    duty: float = 0.5          # link_flap: fraction of period spent down
    duration_s: float = 0.0    # link_flap: total flapping time

    def __post_init__(self):
        if self.kind not in LINK_FAULT_KINDS:
            raise ValueError(
                f"unknown link fault kind {self.kind!r}; expected one of "
                f"{LINK_FAULT_KINDS}")
        if self.t < 0:
            raise ValueError(f"fault time must be >= 0, got {self.t}")

    def label(self) -> str:
        """Human-readable marker text for trace exports (DESIGN.md §12),
        e.g. ``"link_flap rack1/up @0.10s (20.0ms duty 0.50 for 0.20s)"``."""
        s = f"{self.kind} {self.target} @{self.t:.2f}s"
        if self.kind == "link_flap":
            s += (f" ({self.period_s * 1e3:.1f}ms duty {self.duty:.2f} "
                  f"for {self.duration_s:.2f}s)")
        elif self.kind == "link_degrade":
            s += (f" (rate x{self.rate_factor:g} "
                  f"loss +{self.extra_loss:g})")
        elif self.recover_s:
            s += f" (+{self.recover_s:.2f}s recovery)"
        return s


def max_concurrent_cut(events: Iterable[LinkFaultEvent]) -> int:
    """Replay the cut timeline: the maximum number of racks severed at
    any one instant by partition / switch-crash intervals. A target
    with a ``recover_s`` interval heals automatically; an explicit
    ``heal`` / ``switch_recover`` event closes a permanent cut."""
    open_t: Dict[str, float] = {}
    ivals: List[Tuple[str, float, float]] = []
    for ev in sorted(events, key=lambda e: e.t):
        if ev.kind in _CUT_KINDS:
            if ev.target in open_t:
                continue
            if ev.recover_s > 0:
                ivals.append((ev.target, ev.t, ev.t + ev.recover_s))
            else:
                open_t[ev.target] = ev.t
        elif ev.kind in ("heal", "switch_recover") and ev.target in open_t:
            ivals.append((ev.target, open_t.pop(ev.target), ev.t))
    for tgt in sorted(open_t):
        ivals.append((tgt, open_t[tgt], math.inf))
    # merge per target, then sweep: count = distinct racks concurrently cut
    per: Dict[str, List[Tuple[float, float]]] = {}
    for tgt, t0, t1 in ivals:
        per.setdefault(tgt, []).append((t0, t1))
    edges: List[Tuple[float, int]] = []
    for tgt in sorted(per):
        merged: List[List[float]] = []
        for t0, t1 in sorted(per[tgt]):
            if merged and t0 <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], t1)
            else:
                merged.append([t0, t1])
        for t0, t1 in merged:
            edges.append((t0, +1))
            edges.append((t1, -1))
    depth = best = 0
    for _t, d in sorted(edges, key=lambda e: (e[0], -e[1])):
        depth += d
        best = max(best, depth)
    return best


class LinkFaultSchedule:
    """Ordered, deterministic fabric-fault timeline (pure data).

    Construct from an explicit event list, or draw one with
    ``LinkFaultSchedule.random``. ``arm`` registers every event on the
    shared clock exactly like ``FaultSchedule.arm``; dispatch goes
    through a ``NetFaultPlane`` (or any callable) so the schedule never
    holds live topology references.
    """

    def __init__(self, events: Iterable[LinkFaultEvent] = ()):
        evs = list(events)
        for ev in evs:
            if not isinstance(ev, LinkFaultEvent):
                raise TypeError(f"expected LinkFaultEvent, got {type(ev)!r}")
        # stable sort: ties keep insertion order (replay identical
        # regardless of assembly order)
        self.events: Tuple[LinkFaultEvent, ...] = tuple(
            sorted(evs, key=lambda e: e.t))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[LinkFaultEvent]:
        return iter(self.events)

    def __repr__(self) -> str:
        return f"LinkFaultSchedule({list(self.events)!r})"

    def arm(self, sim: Sim,
            dispatch: Callable[[LinkFaultEvent], None]) -> None:
        """Schedule every event: ``dispatch(ev)`` fires at ``ev.t``."""
        for ev in self.events:
            sim.at(ev.t, lambda ev=ev: dispatch(ev))

    @classmethod
    def random(cls, spec, t_end: float, *, seed: int = 0,
               link_down_rate: float = 0.0,
               link_recover_s: float = 0.05,
               flap_rate: float = 0.0,
               flap_period_s: float = 0.02,
               flap_duty: float = 0.5,
               flap_duration_s: float = 0.2,
               degrade_rate: float = 0.0,
               degrade_rate_factor: float = 0.25,
               degrade_extra_loss: float = 0.05,
               degrade_duration_s: float = 0.2,
               switch_crash_at: Iterable[float] = (),
               switch_recover_s: float = 0.05,
               partition_at: Iterable[float] = (),
               partition_heal_s: float = 0.1,
               max_cut: int = 1) -> "LinkFaultSchedule":
        """Seeded random fabric chaos over ``[0, t_end]`` for ``spec``
        (a resolved ``repro.net.topology.Topology`` / ``GatherSpec``).

        Down/flap draws are Poisson per rack uplink (reroutable via the
        spine-redundant backup, so they degrade rather than sever);
        degrade draws cover uplinks and trunks. Explicit switch crashes
        and partitions land round-robin on eligible racks. Trunks are
        never admin-downed, PS-home racks are never partitioned, and
        cuts are thinned to at most ``min(max_cut, racks - 1)``
        concurrently severed racks — a drawn schedule can never wedge
        the cluster (see module docstring).
        """
        if max_cut < 0:
            raise ValueError("max_cut must be >= 0")
        rng = np.random.default_rng(seed)
        hier = bool(getattr(spec, "hierarchical", False))
        racks = int(getattr(spec, "racks", 0)) if hier else 0
        uplinks = [f"rack{r}/up" for r in range(racks)]
        trunks = [f"ps{p}/trunk" for p in range(spec.n_ps)]
        raw: List[LinkFaultEvent] = []
        for link in uplinks:
            for rate, make in (
                (link_down_rate, lambda t, l=None: LinkFaultEvent(
                    t, "link_down", l, recover_s=link_recover_s)),
                (flap_rate, lambda t, l=None: LinkFaultEvent(
                    t, "link_flap", l, period_s=flap_period_s,
                    duty=flap_duty, duration_s=flap_duration_s)),
            ):
                if rate <= 0:
                    continue
                t = float(rng.exponential(1.0 / rate))
                while t < t_end:
                    raw.append(make(t, link))
                    t += float(rng.exponential(1.0 / rate))
        if degrade_rate > 0:
            for link in uplinks + trunks:
                t = float(rng.exponential(1.0 / degrade_rate))
                while t < t_end:
                    raw.append(LinkFaultEvent(
                        t, "link_degrade", link,
                        recover_s=degrade_duration_s,
                        rate_factor=degrade_rate_factor,
                        extra_loss=degrade_extra_loss))
                    t += float(rng.exponential(1.0 / degrade_rate))
        if hier and racks > 0:
            ps_homes = {spec.ps_rack(p) for p in range(spec.n_ps)}
            agg = bool(getattr(spec, "inetwork_agg", False))
            sw_racks = list(range(racks)) if agg else []
            part_racks = [r for r in range(racks) if r not in ps_homes]
            for i, t in enumerate(switch_crash_at):
                if sw_racks:
                    raw.append(LinkFaultEvent(
                        float(t), "switch_crash",
                        f"rack{sw_racks[i % len(sw_racks)]}",
                        recover_s=switch_recover_s))
            for i, t in enumerate(partition_at):
                if part_racks:
                    raw.append(LinkFaultEvent(
                        float(t), "partition",
                        f"rack{part_racks[i % len(part_racks)]}",
                        recover_s=partition_heal_s))
        raw.sort(key=lambda e: e.t)
        # cut-ceiling thinning: replay the cut timeline, dropping any
        # partition/switch-crash whose interval would push the number of
        # concurrently severed racks past the ceiling
        ceiling = min(max_cut, max(racks - 1, 0))
        active: List[Tuple[float, str]] = []   # (heal time, rack)
        kept: List[LinkFaultEvent] = []
        for ev in raw:
            if ev.kind not in _CUT_KINDS:
                kept.append(ev)
                continue
            active = [(end, tgt) for end, tgt in active if end > ev.t]
            cut_now = {tgt for _end, tgt in active}
            if ev.target in cut_now or len(cut_now) >= ceiling:
                continue
            active.append((ev.t + ev.recover_s, ev.target))
            kept.append(ev)
        return cls(kept)


def netfault_schedule_from_config(cfg, spec,
                                  t_end: float) -> "LinkFaultSchedule":
    """Draw the schedule a ``repro.config.NetFaultConfig`` describes,
    once the run horizon ``t_end`` is known."""
    return LinkFaultSchedule.random(
        spec, t_end, seed=cfg.seed,
        link_down_rate=cfg.link_down_rate,
        link_recover_s=cfg.link_recover_s,
        flap_rate=cfg.flap_rate, flap_period_s=cfg.flap_period_s,
        flap_duty=cfg.flap_duty, flap_duration_s=cfg.flap_duration_s,
        degrade_rate=cfg.degrade_rate,
        degrade_rate_factor=cfg.degrade_rate_factor,
        degrade_extra_loss=cfg.degrade_extra_loss,
        degrade_duration_s=cfg.degrade_duration_s,
        switch_crash_at=cfg.switch_crash_at,
        switch_recover_s=cfg.switch_recover_s,
        partition_at=cfg.partition_at,
        partition_heal_s=cfg.partition_heal_s,
        max_cut=cfg.max_cut)


class NetFaultPlane:
    """Maps schedule events onto the live fabric (DESIGN.md §14).

    ``install`` marks every registered pipe faultable (their deliveries
    start riding the ``link_gen`` fence) and, on hierarchical fabrics,
    attaches a spine-redundant backup pipe to every rack uplink — the
    second spine plane that ``link_down`` reroutes onto and that only a
    ``partition`` cuts together with the primary. Installation happens
    lazily on the first dispatched event, so a runtime carrying an empty
    schedule never touches the fabric at all (the zero-fault parity
    pin).

    ``on_event`` (if set) fires once per dispatched schedule event;
    ``on_path`` (if set) fires as ``on_path(kind, target)`` for derived
    path-state changes: ``"reroute"`` when a downed link's traffic
    diverts onto its backup, ``"blackhole"`` when no escape exists.
    Both are telemetry taps — the runtime records them.
    """

    def __init__(self, sim: Sim, topo: Topology, spec, *, seed: int = 0,
                 on_event: Optional[Callable[[LinkFaultEvent], None]] = None,
                 on_path: Optional[Callable[[str, str], None]] = None):
        self.sim = sim
        self.topo = topo
        self.spec = spec
        self.seed = seed
        self.on_event = on_event
        self.on_path = on_path
        self.installed = False
        self.n_reroutes = 0     # link cuts that found a live backup
        self.n_blackholes = 0   # link cuts with no escape path

    # -- fabric arming -------------------------------------------------------
    def install(self) -> None:
        if self.installed:
            return
        self.installed = True
        for name in sorted(self.topo.pipes):
            self.topo.pipes[name].faultable = True
        if getattr(self.spec, "hierarchical", False):
            for r in range(self.spec.racks):
                p = self.topo.pipes.get(f"rack{r}/up")
                if p is None or p.backup is not None:
                    continue
                bk = Pipe(self.sim, p.rate, p.delay, p.loss, p.cap,
                          np.random.default_rng(
                              self.seed * 7919 + 104729 + r),
                          p.overhead)
                bk.faultable = True
                p.backup = self.topo.add_pipe(f"rack{r}/backup", bk,
                                              group="backup")

    # -- dispatch ------------------------------------------------------------
    def dispatch(self, ev: LinkFaultEvent) -> None:
        """Realize one schedule event on the fabric (the ``arm`` target)."""
        self.install()
        if self.on_event is not None:
            self.on_event(ev)
        k = ev.kind
        if k == "link_down":
            self._set_link(ev.target, False)
            if ev.recover_s > 0:
                self.sim.after(ev.recover_s,
                               partial(self._set_link, ev.target, True))
        elif k == "link_up":
            self._set_link(ev.target, True)
        elif k == "link_flap":
            period = max(ev.period_s, 1e-9)
            down_s = min(max(ev.duty, 0.0), 1.0) * period
            n = max(1, int(round(ev.duration_s / period)))
            for i in range(n):
                self.sim.after(i * period,
                               partial(self._set_link, ev.target, False))
                self.sim.after(i * period + down_s,
                               partial(self._set_link, ev.target, True))
        elif k == "link_degrade":
            pipe = self.topo.pipes[ev.target]
            pipe.set_degraded(ev.rate_factor, ev.extra_loss)
            if ev.recover_s > 0:
                self.sim.after(ev.recover_s, pipe.clear_degraded)
        elif k == "link_restore":
            self.topo.pipes[ev.target].clear_degraded()
        elif k == "switch_crash":
            self._set_switches(ev.target, False)
            if ev.recover_s > 0:
                self.sim.after(ev.recover_s,
                               partial(self._set_switches, ev.target, True))
        elif k == "switch_recover":
            self._set_switches(ev.target, True)
        elif k == "partition":
            self._set_partition(ev.target, True)
            if ev.recover_s > 0:
                self.sim.after(ev.recover_s,
                               partial(self._set_partition, ev.target,
                                       False))
        elif k == "heal":
            self._set_partition(ev.target, False)

    # -- realizations --------------------------------------------------------
    def _set_link(self, name: str, up: bool) -> None:
        pipe = self.topo.pipes[name]
        was = pipe.up
        pipe.set_up(up)
        if was and not up:
            if pipe.backup is not None and pipe.backup.up:
                self.n_reroutes += 1
                if self.on_path is not None:
                    self.on_path("reroute", name)
            else:
                self.n_blackholes += 1
                if self.on_path is not None:
                    self.on_path("blackhole", name)

    @staticmethod
    def _rack_of(target: str) -> int:
        return int(target[4:]) if target.startswith("rack") else int(target)

    def _set_switches(self, target: str, up: bool) -> None:
        r = self._rack_of(target)
        aggs = getattr(self.topo, "aggs", None) or {}
        for key in sorted(aggs):
            if key[1] == r:
                if up:
                    aggs[key].recover()
                else:
                    aggs[key].crash()
        if not up:
            self.n_blackholes += 1
            if self.on_path is not None:
                self.on_path("blackhole", target)

    def _set_partition(self, target: str, cut: bool) -> None:
        r = self._rack_of(target)
        pipe = self.topo.pipes.get(f"rack{r}/up")
        if pipe is None:
            return
        pipe.set_up(not cut)
        if pipe.backup is not None:
            pipe.backup.set_up(not cut)
        if cut:
            self.n_blackholes += 1
            if self.on_path is not None:
                self.on_path("blackhole", target)
