"""Observability layer (DESIGN.md §12): pluggable Tracker backends,
a counters/gauges/histograms metrics registry, and a Chrome-trace
(Perfetto-loadable) exporter over the §8 runtime event stream."""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import (
    chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.obs.tracker import (
    TRACKER_BACKENDS,
    CompositeTracker,
    CsvTracker,
    JsonlTracker,
    MemoryTracker,
    NullTracker,
    TensorBoardTracker,
    Tracker,
    make_tracker,
    read_jsonl,
)
