"""Coalesced packet-train engine vs the per-packet reference (DESIGN.md §7).

Two layers of equivalence:

* link level — a same-seed burst through ``Pipe.send_train`` /
  ``Route.send_train`` is *exactly* the per-packet path: same admitted
  prefix, same loss draws (the train consumes the RNG stream in per-packet
  order), same per-packet arrival times, same drop/byte counters. Seeded
  property tests sweep rate/delay/loss/queue/size.

* scenario level — a coalesced gather is the same *physics* driven by a
  coarser event clock (acks batch per train), so delivered bytes, drop
  accounting, and gather completion times match the per-packet run within
  a tolerance rather than exactly.
"""
import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig
from repro.net.scenarios import incast_gather, multi_ps_gather, run_scenario
from repro.net.simcore import Packet, Pipe, Route, Sim

try:        # property tests run wherever the test extra is installed (CI);
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # the seeded sweeps below cover the seed container
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------------------------
# link level: exact equivalence
# ----------------------------------------------------------------------------


def _burst_pipe(train: bool, seed: int, n: int, rate: float, delay: float,
                loss: float, cap: int, size_step: int):
    sim = Sim()
    pipe = Pipe(sim, rate, delay, loss, cap, np.random.default_rng(seed))
    got = []
    pkts = [Packet(0, i, 800 + (i % max(size_step, 1)) * 31) for i in range(n)]
    if train:
        pipe.send_train(pkts, lambda items: got.extend(
            (p.seq, t) for p, t in items))
    else:
        for p in pkts:
            pipe.send(p, lambda q, s=sim: got.append((q.seq, s.now)))
    sim.run()
    stats = (pipe.n_sent, pipe.n_dropped_queue, pipe.n_dropped_loss,
             pipe.bytes_delivered)
    return got, stats, sim.n_events


def _assert_pipe_equivalent(seed, n, rate, delay, loss, cap, size_step):
    a, sa, ev_a = _burst_pipe(False, seed, n, rate, delay, loss, cap, size_step)
    b, sb, ev_b = _burst_pipe(True, seed, n, rate, delay, loss, cap, size_step)
    assert sa == sb                                   # drops + bytes conserve
    assert [x[0] for x in a] == [x[0] for x in b]     # same survivors, order
    np.testing.assert_allclose([x[1] for x in a], [x[1] for x in b],
                               rtol=1e-12)            # same arrival times
    assert ev_b <= max(1, ev_a)                       # one event per train


def _assert_route_equivalent(seed, n, loss, cap2, rate2_frac):
    """Two-hop route: the relay carries per-packet hop arrivals as logical
    enqueue times, so serialization/queueing at the second hop is exact."""

    def run(train: bool):
        sim = Sim()
        p1 = Pipe(sim, 1e8, 0.001, loss, 400, np.random.default_rng(seed))
        p2 = Pipe(sim, 1e8 * rate2_frac, 0.002, loss, cap2,
                  np.random.default_rng(seed + 1))
        route = Route([p1, p2])
        got = []
        pkts = [Packet(0, i, 1200) for i in range(n)]
        if train:
            route.send_train(pkts, lambda items: got.extend(
                (p.seq, t) for p, t in items))
        else:
            for p in pkts:
                route.send(p, lambda q, s=sim: got.append((q.seq, s.now)))
        sim.run()
        return got, (p1.n_dropped_queue, p1.n_dropped_loss,
                     p2.n_dropped_queue, p2.n_dropped_loss,
                     p2.bytes_delivered)

    a, sa = run(False)
    b, sb = run(True)
    assert sa == sb
    assert [x[0] for x in a] == [x[0] for x in b]
    np.testing.assert_allclose([x[1] for x in a], [x[1] for x in b],
                               rtol=1e-12)


@pytest.mark.parametrize("seed", range(8))
def test_pipe_train_exactly_matches_per_packet_seeded(seed):
    rng = np.random.default_rng(1000 + seed)
    _assert_pipe_equivalent(
        seed=seed,
        n=int(rng.integers(1, 300)),
        rate=float(rng.uniform(1e6, 1e10)),
        delay=float(rng.uniform(0.0, 0.05)),
        loss=float(rng.uniform(0.0, 0.9)),
        cap=int(rng.integers(1, 500)),
        size_step=int(rng.integers(1, 13)),
    )


@pytest.mark.parametrize("seed", range(8))
def test_route_train_exactly_matches_per_packet_seeded(seed):
    rng = np.random.default_rng(2000 + seed)
    _assert_route_equivalent(
        seed=seed,
        n=int(rng.integers(1, 200)),
        loss=float(rng.uniform(0.0, 0.5)),
        cap2=int(rng.integers(5, 200)),
        rate2_frac=float(rng.uniform(0.2, 1.0)),
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(1, 300),
        rate=st.floats(1e6, 1e10),
        delay=st.floats(0.0, 0.05),
        loss=st.floats(0.0, 0.9),
        cap=st.integers(1, 500),
        size_step=st.integers(1, 13),
    )
    def test_pipe_train_exactly_matches_per_packet(seed, n, rate, delay,
                                                   loss, cap, size_step):
        _assert_pipe_equivalent(seed, n, rate, delay, loss, cap, size_step)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(1, 200),
        loss=st.floats(0.0, 0.5),
        cap2=st.integers(5, 200),
        rate2_frac=st.floats(0.2, 1.0),
    )
    def test_route_train_exactly_matches_per_packet(seed, n, loss, cap2,
                                                    rate2_frac):
        _assert_route_equivalent(seed, n, loss, cap2, rate2_frac)


def test_train_conservation_under_mixed_interleaving():
    """Trains and singles interleaved on one pipe: every packet is exactly
    one of delivered / queue-dropped / loss-dropped."""
    sim = Sim()
    rng = np.random.default_rng(7)
    pipe = Pipe(sim, 5e7, 0.001, 0.2, 60, rng)
    delivered = [0]
    n_sent = 0
    for round_ in range(30):
        pkts = [Packet(0, round_ * 100 + i, 1000) for i in range(17)]
        n_sent += len(pkts)
        if round_ % 2:
            pipe.send_train(pkts, lambda items: delivered.__setitem__(
                0, delivered[0] + len(items)))
        else:
            for p in pkts:
                pipe.send(p, lambda q: delivered.__setitem__(
                    0, delivered[0] + 1))
        sim.run()
    assert delivered[0] + pipe.n_dropped_queue + pipe.n_dropped_loss == n_sent
    assert delivered[0] * 1000 == pipe.bytes_delivered


# ----------------------------------------------------------------------------
# scenario level: same physics, coarser clock
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("scenario,kw", [
    ("incast_gather", {"straggler_prob": 0.0}),
    ("multi_ps_gather", {"n_ps": 2, "straggler_prob": 0.0}),
    ("straggler_gather", {"slow_rate_mult": 0.5}),
])
@pytest.mark.parametrize("protocol", ["ltp", "cubic"])
def test_gather_coalesced_matches_per_packet(scenario, kw, protocol):
    net = NetConfig(10, 1, 0.002, 4096)
    ref = run_scenario(scenario, protocol, net, w=4, size_bytes=4e5,
                       iters=3, seed=11, coalesce=1, **kw)
    fast = run_scenario(scenario, protocol, net, w=4, size_bytes=4e5,
                        iters=3, seed=11, coalesce=16, **kw)
    bst_ref = np.array([r.bst_gather for r in ref])
    bst_fast = np.array([r.bst_gather for r in fast])
    # same completion-time regime: batched acks coarsen the CC clock, so
    # means agree within 50% and no single round drifts past 3x
    np.testing.assert_allclose(bst_fast.mean(), bst_ref.mean(), rtol=0.5)
    ratio = bst_fast / bst_ref
    assert np.all((ratio > 1 / 3) & (ratio < 3)), ratio
    # delivered fractions stay in the same band
    d_ref = np.mean([r.delivered.mean() for r in ref])
    d_fast = np.mean([r.delivered.mean() for r in fast])
    assert abs(d_ref - d_fast) < 0.15
    for r in fast:
        assert r.packets_received <= r.packets_expected
        if protocol == "cubic":
            assert r.packets_received == r.packets_expected
        else:
            assert r.criticals_ok


def test_coalesced_gather_cuts_events():
    from repro.net import simcore

    net = NetConfig(10, 1, 0.001, 4096)

    def events(coalesce):
        simcore.PERF.reset()
        incast_gather("ltp", net, 4, 5e5, iters=2, seed=5,
                      straggler_prob=0.0, coalesce=coalesce)
        return simcore.PERF.events, simcore.PERF.packets

    ev1, pk1 = events(1)
    ev16, pk16 = events(16)
    assert ev16 < ev1 / 4           # >=4x fewer heap events
    assert pk16 > 0.5 * pk1         # while moving comparable traffic


def test_gather_masks_shape_and_consistency():
    """GatherResult.masks is (n_ps, W, n) and its mean equals the reported
    delivered fractions."""
    net = NetConfig(10, 1, 0.0, 4096)
    ltp = LTPConfig(data_pct_threshold=0.7)
    rs = multi_ps_gather("ltp", net, 4, 4e5, n_ps=2, iters=2, ltp=ltp,
                         seed=2, straggler_prob=0.5, straggler_scale=1.0,
                         coalesce=8)
    for r in rs:
        assert r.masks is not None and r.masks.ndim == 3
        n_ps, w, n = r.masks.shape
        assert (n_ps, w) == (2, 4) and n > 0
        np.testing.assert_allclose(r.masks.mean(axis=(0, 2)), r.delivered,
                                   atol=1e-9)
