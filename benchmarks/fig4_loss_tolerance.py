"""Paper Fig 4: bandwidth-utilization reduction of congestion controls under
non-congestion loss (p2p, warm connections). Grid: {1G/40ms, 10G/1ms} x
loss rates x {cubic, reno, bbr, ltp}."""
from __future__ import annotations

from repro.config import NetConfig
from repro.net.scenarios import p2p_transfer

from benchmarks.common import emit

LOSSES_FULL = [0.0, 0.0001, 0.001, 0.005, 0.01, 0.03, 0.05]
LOSSES_QUICK = [0.0, 0.001, 0.01]


def run(quick: bool = True):
    rows = []
    links = [("10G_1ms", 10.0, 1.0)] if quick else \
        [("10G_1ms", 10.0, 1.0), ("1G_40ms", 1.0, 40.0)]
    losses = LOSSES_QUICK if quick else LOSSES_FULL
    protos = ["cubic", "reno", "bbr", "ltp"]
    size = 4e6 if quick else 8e6
    base = {}
    for link, bw, rt in links:
        for loss in losses:
            net = NetConfig(bw, rt, loss, 1024)
            for proto in protos:
                warm = p2p_transfer(proto, net, size, seed=0)["warm"]
                r = p2p_transfer(proto, net, size, seed=1, warm=warm)
                util = r["utilization"]
                if loss == losses[0]:
                    base[(link, proto)] = util
                reduction = util / max(base.get((link, proto), util), 1e-9) - 1.0
                rows.append({
                    "link": link, "loss": loss, "protocol": proto,
                    "utilization": round(util, 4),
                    "reduction_vs_lossless": round(reduction, 4),
                })
    return emit(rows, "fig4_loss_tolerance")


if __name__ == "__main__":
    run(quick=False)
