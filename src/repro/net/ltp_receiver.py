"""LTP receiver(s): per-packet out-of-order ACK, Early Close, bubble
accounting (paper §III-B/C).

``LTPFlowReceiver`` handles one flow. ``PSGatherReceiver`` coordinates the
incast gather at the PS: per-link LT thresholds, one shared deadline, and
the close rule over the aggregate received percentage + critical-packet
completeness. On close it broadcasts "stop" to all senders and records,
per flow, exactly which packets must be bubble-filled.

``ShardedGatherReceiver`` (DESIGN.md §5) is the multi-PS composition: one
independent ``PSGatherReceiver`` per model shard, each with its own LT
threshold, deadline timer, and close decision. A worker appears once per
shard; aggregate statistics reduce over shards (BST = slowest shard's
close; a worker's delivered fraction = mean over its shard flows).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Set

import numpy as np

from repro.net.simcore import Packet, Sim


class LTPFlowReceiver:
    """Tracks one sender's flow; emits per-packet ACKs."""

    def __init__(self, sim: Sim, send_ack: Callable[[Packet], None], flow: int):
        self.sim = sim
        self.send_ack = send_ack
        self.flow = flow
        self.n: Optional[int] = None
        self.critical: Optional[np.ndarray] = None
        self.received: Set[int] = set()
        self.t_start: Optional[float] = None
        self.t_full: Optional[float] = None
        self.closed = False

    @property
    def pct(self) -> float:
        if not self.n:
            return 0.0
        return len(self.received) / self.n

    @property
    def criticals_done(self) -> bool:
        if self.n is None:
            return False
        if self.critical is None:
            return True
        need = np.flatnonzero(self.critical)
        return all(int(s) in self.received for s in need)

    def on_data(self, pkt: Packet, notify: Callable[[], None]):
        if self.closed:
            return
        if pkt.kind == "reg":
            self.n = pkt.meta["n"]
            self.critical = pkt.meta.get("critical")
            if self.t_start is None:
                self.t_start = self.sim.now
            self.send_ack(Packet(self.flow, -1, 41, kind="ack", meta={}))
            if self.n is not None and len(self.received) >= self.n \
                    and self.t_full is None:
                self.t_full = self.sim.now
            notify()
            return
        self.received.add(pkt.seq)
        ack = Packet(self.flow, pkt.seq, 41, kind="ack",
                     meta={"echo": pkt.meta, "order": pkt.meta.get("order", -1)})
        self.send_ack(ack)
        if self.n is not None and len(self.received) >= self.n and self.t_full is None:
            self.t_full = self.sim.now
        notify()

    def bubbles(self) -> np.ndarray:
        """(n,) bool — packets that must be zero-filled at close."""
        if self.n is None:
            return np.zeros(0, bool)
        mask = np.ones(self.n, bool)
        for s in self.received:
            if 0 <= s < self.n:
                mask[s] = False
        return mask


class PSGatherReceiver:
    """The PS side of one gather iteration over W flows (paper Fig 7).

    close rule: before LT -> wait for 100%; in [LT, deadline) -> close when
    aggregate pct >= threshold and all criticals are in; at deadline ->
    close unconditionally (criticals are retransmitted via CQ and in
    practice always land before the deadline; if not, the close is late —
    counted in stats).
    """

    def __init__(self, sim: Sim, flows: List[int], lt_threshold: float,
                 deadline: float, pct_threshold: float,
                 send_stop: Callable[[int], None],
                 on_close: Optional[Callable[["PSGatherReceiver"], None]] = None,
                 ps_id: int = 0):
        self.sim = sim
        self.ps_id = ps_id
        self.lt = lt_threshold
        self.deadline = deadline
        self.pct_threshold = pct_threshold
        self.send_stop = send_stop
        self.on_close = on_close
        self.flows: Dict[int, LTPFlowReceiver] = {}
        self.t0 = sim.now
        self.closed = False
        self.close_time: Optional[float] = None
        for f in flows:
            self.flows[f] = LTPFlowReceiver(sim, lambda p: None, f)
        sim.at(self.t0 + lt_threshold, self._check)
        sim.at(self.t0 + deadline, self._check)

    def attach_ack(self, flow: int, send_ack: Callable[[Packet], None]):
        self.flows[flow].send_ack = send_ack

    def on_data(self, pkt: Packet):
        fr = self.flows.get(pkt.flow)
        if fr is None:
            return
        if self.closed:
            # data after close means the flow's "stop" was lost in flight:
            # re-send it (once per arriving packet, so the retry rate is
            # bounded by the sender's own transmission rate)
            self.send_stop(pkt.flow)
            return
        fr.on_data(pkt, self._check)

    @property
    def agg_pct(self) -> float:
        ps = [f.pct for f in self.flows.values()]
        return float(np.mean(ps)) if ps else 0.0

    @property
    def all_full(self) -> bool:
        return all(f.n is not None and len(f.received) >= f.n
                   for f in self.flows.values())

    @property
    def criticals_done(self) -> bool:
        return all(f.criticals_done for f in self.flows.values())

    def _check(self):
        if self.closed:
            return
        t = self.sim.now - self.t0
        if self.all_full:
            self._close()
            return
        if t >= self.deadline:
            if self.criticals_done:
                self._close()
            # else: criticals still owed; CQ retransmissions land shortly —
            # the close fires on the arrival that completes them.
            return
        if t >= self.lt and self.agg_pct >= self.pct_threshold and self.criticals_done:
            self._close()

    def _close(self):
        self.closed = True
        self.close_time = self.sim.now
        for f in self.flows:
            self.send_stop(f)
        for fr in self.flows.values():
            fr.closed = True
        if self.on_close:
            self.on_close(self)

    # --- results -------------------------------------------------------------
    def delivered_fracs(self) -> np.ndarray:
        return np.array([f.pct for f in self.flows.values()])

    def full_times(self) -> np.ndarray:
        return np.array([
            (f.t_full - self.t0) if f.t_full is not None else np.inf
            for f in self.flows.values()
        ])

    def bst_gather(self) -> float:
        return (self.close_time or self.sim.now) - self.t0


class ShardedGatherReceiver:
    """Multi-PS gather state: one ``PSGatherReceiver`` per model shard.

    Each shard closes independently (its own LT threshold + deadline);
    the *iteration* is done when the slowest shard closes. Statistics
    reduce over shards so the result shapes match the single-PS case:
    per-worker delivered fraction is the mean over that worker's shard
    flows, and full time is the max (the worker is only "fully
    delivered" once every shard has its packets).
    """

    def __init__(self, sim: Sim, n_ps: int, workers: List[int],
                 lt_thresholds: List[float], deadlines: List[float],
                 pct_threshold: float,
                 send_stop: Callable[[int, int], None]):
        """``send_stop(ps, worker)`` stops worker's flow toward shard ps."""
        self.sim = sim
        self.n_ps = n_ps
        self.workers = list(workers)
        self.shards: List[PSGatherReceiver] = [
            PSGatherReceiver(
                sim, list(workers), lt_thresholds[p], deadlines[p],
                pct_threshold,
                send_stop=lambda w, p=p: send_stop(p, w),
                ps_id=p,
            )
            for p in range(n_ps)
        ]

    def shard(self, ps: int) -> PSGatherReceiver:
        return self.shards[ps]

    @property
    def all_closed(self) -> bool:
        return all(s.closed for s in self.shards)

    @property
    def criticals_done(self) -> bool:
        return all(s.criticals_done for s in self.shards)

    # --- reductions over shards ----------------------------------------------
    def bst_gather(self) -> float:
        return max(s.bst_gather() for s in self.shards)

    def delivered_fracs(self) -> np.ndarray:
        """(W,) mean delivered fraction per worker across shards."""
        return np.mean([s.delivered_fracs() for s in self.shards], axis=0)

    def full_times(self) -> np.ndarray:
        """(W,) time at which the worker's *last* shard hit 100%."""
        return np.max([s.full_times() for s in self.shards], axis=0)

    def per_shard_full_times(self) -> np.ndarray:
        """(n_ps, W) raw 100%-times — feeds per-PS LT adaptation."""
        return np.stack([s.full_times() for s in self.shards])

    def payload_packets_received(self) -> int:
        return sum(len(f.received) for s in self.shards
                   for f in s.flows.values())
