"""The four assigned input shapes.

``kind`` selects which step gets lowered in the dry-run:
  train   -> train_step(tokens, labels)
  prefill -> prefill_step (full-sequence forward, build cache)
  decode  -> serve_step (ONE new token against a seq_len KV cache / SSM state)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown input shape {name!r}; options: {sorted(SHAPES)}")
    return SHAPES[name]
