"""Substrate tests: optimizers, data pipeline, checkpointing, compression,
HLO walker, PSTrainer integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.core import compression
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data import SyntheticCIFAR, SyntheticLM, batches
from repro.launch import hlo_analysis as ha
from repro.models import build
from repro.optim import adamw, lr_at, sgd_momentum
from repro.train import PSTrainer


def test_sgdm_matches_reference():
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.1, -0.2])}
    opt = sgd_momentum(momentum=0.9)
    st = opt.init(params)
    for _ in range(3):
        upd, st = opt.update(grads, st, params, jnp.float32(0.1))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    # reference loop
    p = np.array([1.0, 2.0]); m = np.zeros(2); g = np.array([0.1, -0.2])
    for _ in range(3):
        m = 0.9 * m + g
        p -= 0.1 * m
    np.testing.assert_allclose(params["w"], p, rtol=1e-6)


def test_adamw_decreases_quadratic():
    opt = adamw()
    params = {"w": jnp.array([5.0, -3.0])}
    st = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        upd, st = opt.update(g, st, params, jnp.float32(0.05))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule():
    tc = TrainConfig(lr=0.1, lr_decay_every=10, lr_decay=0.8)
    assert float(lr_at(tc, 0, epoch_steps := 5)) == pytest.approx(0.1)
    assert float(lr_at(tc, 5 * 10, 5)) == pytest.approx(0.08)
    assert float(lr_at(tc, 5 * 20, 5)) == pytest.approx(0.064)


def test_synthetic_lm_floor():
    lm = SyntheticLM(vocab=64, seed=0)
    assert 0 < lm.entropy_floor < np.log(64)
    toks = lm.sample(4, 32, seed=1)
    assert toks.shape == (4, 33)
    assert toks.max() < 64


def test_synthetic_cifar_learnable():
    d = SyntheticCIFAR(seed=0)
    b = d.train_batch(64, 0)
    assert b["images"].shape == (64, 32, 32, 3)
    assert b["labels"].shape == (64,)
    # same class templates differ from others on average
    t = d.test_set(512)
    assert len(np.unique(t["labels"])) == 10


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
            "b": (jnp.ones(4, jnp.int32), jnp.zeros(())),
            }
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, tree, step=42)
    back, step = restore_checkpoint(p, tree)
    assert step == 42
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_compression_topk_randomk():
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (1000,))}
    sp, res = compression.random_k(grads, 0.3, key)
    d = float(compression.measure_density(sp))
    assert abs(d - 0.3) < 0.06
    np.testing.assert_allclose(
        np.asarray(sp["w"] + res), np.asarray(grads["w"]), rtol=1e-6)
    sp2, res2 = compression.top_k(grads, 0.2)
    d2 = float(compression.measure_density(sp2))
    assert abs(d2 - 0.2) < 0.05
    kept = np.asarray(sp2["w"])
    dropped_max = np.abs(np.asarray(grads["w"])[kept == 0]).max()
    kept_min = np.abs(kept[kept != 0]).min()
    assert kept_min >= dropped_max - 1e-6   # top-k keeps the largest


def test_hlo_walker_scan_equals_unroll():
    W = jnp.ones((64, 64), jnp.float32)

    def f_scan(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ W, None), x, None, length=7)
        return y

    def f_unroll(x):
        for _ in range(7):
            x = x @ W
        return x

    x = jnp.ones((64, 64))
    costs = []
    for f in (f_scan, f_unroll):
        c = jax.jit(f).lower(x).compile()
        costs.append(ha.analyze(c.as_text()).flops)
    expected = 2 * 64**3 * 7
    np.testing.assert_allclose(costs, expected, rtol=1e-6)


def test_hlo_walker_collectives():
    from repro import compat
    mesh = compat.make_mesh((1,), ("d",))

    def f(x):
        return jax.lax.psum(x, "d")

    from jax.sharding import PartitionSpec as P
    g = compat.shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P())
    c = jax.jit(g).lower(jnp.ones((1, 256), jnp.float32)).compile()
    cost = ha.analyze(c.as_text())
    assert cost.collective_bytes >= 256 * 4 or cost.collective_bytes == 0
    # (1-device mesh may elide the collective; key assertion: no crash)


def test_pstrainer_short_run_decreases_loss():
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    tc = TrainConfig(batch=64, lr=0.1, steps=25)
    tr = PSTrainer(api, sgd_momentum(), tc, LTPConfig(), NetConfig(10, 1, 0.001, 4096),
                   n_workers=4, protocol="ltp", compute_time=0.01, seed=0)
    data = SyntheticCIFAR(seed=1)
    hist = tr.run(batches(data, tc.batch, tc.steps))
    tail = np.mean([h["loss"] for h in hist[-5:]])
    head = np.mean([h["loss"] for h in hist[:5]])
    assert tail < head
    assert all(0.0 <= h["delivered"] <= 1.0 for h in hist)
    assert tr.sim_time > 0


def test_pstrainer_ltp_vs_baseline_same_seed_close():
    """With ~full delivery LTP matches the lossless baseline closely."""
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    tc = TrainConfig(batch=64, lr=0.05, steps=8)
    data = SyntheticCIFAR(seed=1)
    runs = {}
    for proto, loss_rate in [("ltp", 0.0), ("cubic", 0.0)]:
        tr = PSTrainer(api, sgd_momentum(), tc, LTPConfig(), NetConfig(10, 1, loss_rate, 8192),
                       n_workers=4, protocol=proto, compute_time=0.01, seed=0)
        hist = tr.run(batches(data, tc.batch, tc.steps))
        runs[proto] = hist[-1]["loss"]
    assert abs(runs["ltp"] - runs["cubic"]) < 0.35
