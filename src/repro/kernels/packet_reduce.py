"""Pallas TPU kernel: PS-side masked multi-worker packet reduction.

Aggregates W workers' packetized gradients with per-(worker, packet)
delivery masks and bubble-fill compensation:

    paper:  out[p] = sum_w g[w,p] * m[w,p] / W
    count:  out[p] = sum_w g[w,p] * m[w,p] / max(sum_w m[w,p], 1)

The worker dimension is accumulated *inside* the kernel (static unroll over
W — typically 8..64), so each (BLOCK_P, payload) output tile is written once
and each input tile is read once: one HBM pass, the roofline optimum for
this memory-bound reduction. This is the TPU adaptation of the paper's PS
aggregation hot loop (their C++ server thread).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 128


def _reduce_kernel(pkts_ref, mask_ref, out_ref, *, n_workers: int,
                   compensation: str):
    """pkts: (W, BLOCK_P, payload); mask: (W, BLOCK_P, 1)."""
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    cnt = jnp.zeros((out_ref.shape[0], 1), jnp.float32)
    for w in range(n_workers):          # static unroll
        m = mask_ref[w]
        acc = acc + pkts_ref[w].astype(jnp.float32) * m
        cnt = cnt + m
    if compensation == "count":
        out_ref[...] = (acc / jnp.maximum(cnt, 1.0)).astype(out_ref.dtype)
    else:
        out_ref[...] = (acc / n_workers).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("compensation", "interpret"))
def packet_reduce(packets, mask, *, compensation: str = "paper",
                  interpret: bool = True):
    """packets: (W, n_packets, payload) f32; mask: (W, n_packets) f32.

    Requires payload % 128 == 0, n_packets % BLOCK_P == 0. Returns
    (n_packets, payload) float32.
    """
    w, n, p = packets.shape
    assert p % 128 == 0 and n % BLOCK_P == 0, (w, n, p)
    mask3 = mask[..., None].astype(jnp.float32)
    grid = (n // BLOCK_P,)
    kernel = functools.partial(
        _reduce_kernel, n_workers=w, compensation=compensation
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, BLOCK_P, p), lambda i: (0, i, 0)),
            pl.BlockSpec((w, BLOCK_P, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_P, p), lambda i: (i, 0)),
        interpret=interpret,
    )(packets, mask3)


def tree_reduce(packets, mask, rack_of, *, compensation: str = "paper",
                interpret: bool = True):
    """Hierarchical (rack → root) masked reduction, DESIGN.md §11.

    Models the aggregation tree's math: each rack's ToR partially reduces
    its members' delivered packets with the same kernel the PS uses, the
    root combines the per-rack partial sums. ``rack_of`` maps worker w →
    rack id. Returns (n_packets, payload) float32 equal to the flat
    ``packet_reduce(packets, mask)`` to float tolerance (pinned by
    tests/test_aggtree.py) — the tree moves bytes, never the answer.

    Per rack the kernel's own normalizations are inverted back to raw
    masked sums (x rack W for "paper", x per-packet counts for "count"),
    so the root division is the only lossy float step beyond summation
    order.
    """
    w, n, p = packets.shape
    racks = {}
    for f in range(w):
        racks.setdefault(int(rack_of(f)), []).append(f)
    acc = jnp.zeros((n, p), jnp.float32)
    cnt = jnp.zeros((n, 1), jnp.float32)
    for members in racks.values():
        sub_p = packets[jnp.array(members)]
        sub_m = mask[jnp.array(members)]
        partial = packet_reduce(sub_p, sub_m, compensation=compensation,
                                interpret=interpret)
        if compensation == "count":
            c = jnp.sum(sub_m.astype(jnp.float32), axis=0)[:, None]
            acc = acc + partial * jnp.maximum(c, 1.0)
            cnt = cnt + c
        else:
            acc = acc + partial * len(members)
            cnt = cnt + jnp.sum(sub_m.astype(jnp.float32), axis=0)[:, None]
    if compensation == "count":
        return acc / jnp.maximum(cnt, 1.0)
    return acc / w
