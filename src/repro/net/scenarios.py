"""Simulation scenarios mirroring the paper's evaluation setups.

  p2p_transfer    point-to-point goodput under loss        (Fig 4)
  incast_gather   W-to-1 gather; FCT tail / BST            (Fig 3, 14)
  train_iterations gather+broadcast loop -> BST + delivered fractions
                  (consumed by the training coupling; Fig 12/13)
  fairness_share  two flows on one bottleneck              (Fig 15)

All scenarios use scaled transfer sizes (document the scale where used) —
event counts stay ~O(1e5-1e6) so full sweeps run in seconds on CPU.
Iterations carry warm CC state across rounds (persistent connections, as
real PS frameworks keep sockets open between batches).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.config import LTPConfig, NetConfig
from repro.net import senders as snd
from repro.net.ltp_receiver import LTPFlowReceiver, PSGatherReceiver
from repro.net.simcore import Packet, Pipe, Sim

PROTOCOLS = ("ltp", "bbr", "cubic", "reno")


def _mk_sender(protocol: str, sim: Sim, pipe: Pipe, deliver, n: int, flow: int,
               rng, on_done=None, critical=None):
    if protocol == "ltp":
        return snd.LTPSender(sim, pipe, deliver, n, critical=critical,
                             flow=flow, rng=rng, on_done=on_done)
    cls = {"bbr": snd.BBRSender, "cubic": snd.CubicSender,
           "reno": snd.RenoSender}[protocol]
    return cls(sim, pipe, deliver, n, flow=flow, on_done=on_done)


def _warm(sender, state: Optional[dict]):
    if not state:
        return
    if isinstance(sender, snd.LTPSender) or isinstance(sender, snd.BBRSender):
        est = sender.est
        est.rtprop = state.get("rtprop", est.rtprop)
        if state.get("btlbw", 0) > 0:
            est._bw_samples.append((sender.sim.now, state["btlbw"]))
            sender.startup = False
    else:
        # idle restart: slow-start back toward the previous operating point
        # (RFC 2861 style — cwnd resets, ssthresh remembers)
        sender.ssthresh = state.get("ssthresh", sender.ssthresh)
        sender.srtt = state.get("srtt", sender.srtt)


def _save_warm(sender) -> dict:
    if isinstance(sender, (snd.LTPSender, snd.BBRSender)):
        return {"rtprop": sender.est.rtprop, "btlbw": sender.est.btlbw}
    return {
        "ssthresh": max(sender.cwnd, sender.ssthresh)
        if math.isfinite(sender.ssthresh) else sender.cwnd,
        "srtt": sender.srtt,
    }


def _npkts(size_bytes: float, protocol: str) -> int:
    payload = snd.LTP_PAYLOAD if protocol == "ltp" else snd.MSS
    return max(1, int(math.ceil(size_bytes / payload)))


# ----------------------------------------------------------------------------
# p2p
# ----------------------------------------------------------------------------


def p2p_transfer(protocol: str, net: NetConfig, size_bytes: float,
                 seed: int = 0, warm: Optional[dict] = None) -> Dict:
    """One flow over one lossy link. Returns fct/goodput/utilization."""
    sim = Sim()
    rng = np.random.default_rng(seed)
    bw = net.bandwidth_gbps * 1e9
    fwd = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
               net.queue_pkts, rng)
    back = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
                10_000, rng)
    n = _npkts(size_bytes, protocol)
    done = {}

    def on_done(s):
        done["t"] = sim.now

    if protocol == "ltp":
        sender = snd.LTPSender(sim, fwd, None, n, rng=rng, on_done=on_done)
        recv = LTPFlowReceiver(sim, lambda p: back.send(p, sender.on_ack), 0)
        sender.deliver = lambda p: recv.on_data(p, lambda: None)
    else:
        sender = _mk_sender(protocol, sim, fwd, None, n, 0, rng, on_done)
        recv = snd.TcpReceiver(sim, lambda p: back.send(p, sender.on_ack), 0)
        sender.deliver = recv.on_data
    _warm(sender, warm)
    sender.start()
    sim.run(until=3600.0)
    fct = done.get("t", sim.now) - 0.0
    goodput = size_bytes * 8.0 / max(fct, 1e-12)
    return {
        "fct": fct,
        "goodput_bps": goodput,
        "utilization": goodput / bw,
        "warm": _save_warm(sender),
    }


def utilization_cached(protocol: str, net: NetConfig, size_bytes: float = 4e6,
                       _cache={}) -> float:
    """Steady-state (warm-connection) p2p utilization at this transfer size."""
    key = (protocol, net.bandwidth_gbps, net.rtprop_ms, net.loss_rate,
           round(math.log2(max(size_bytes, 1e5))))
    if key not in _cache:
        warm = p2p_transfer(protocol, net, size_bytes)["warm"]
        _cache[key] = p2p_transfer(protocol, net, size_bytes, seed=1,
                                   warm=warm)["utilization"]
    return _cache[key]


# ----------------------------------------------------------------------------
# incast gather
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class GatherResult:
    bst_gather: float
    fcts: np.ndarray              # (W,) per-flow 100%-or-close time
    delivered: np.ndarray         # (W,) fraction delivered at close
    full_times: np.ndarray        # (W,) time to 100% (inf if early-closed)
    criticals_ok: bool


def _run_gather(protocol: str, net: NetConfig, w: int, size_bytes: float,
                rng: np.random.Generator, warm: List[Optional[dict]],
                lt: float, deadline: float, pct_thresh: float,
                critical_frac: float = 0.01,
                start_delays: Optional[np.ndarray] = None,
                ) -> Tuple[GatherResult, List[dict]]:
    """One gather round. Returns (result, warm_states).

    ``start_delays``: per-flow start offsets modelling host-side stragglers
    (GC pauses, CPU contention, slow gradient production) — the source of
    the paper's Fig-3 "starved flows" beyond pure protocol dynamics."""
    sim = Sim()
    bw = net.bandwidth_gbps * 1e9
    bottleneck = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
                      net.queue_pkts, rng)
    n = _npkts(size_bytes, protocol)
    senders = []
    if protocol == "ltp":
        crit = np.zeros(n, bool)
        ncrit = max(2, int(critical_frac * n))
        crit[: ncrit // 2] = True
        crit[-(ncrit - ncrit // 2):] = True
        ps = PSGatherReceiver(sim, list(range(w)), lt, deadline, pct_thresh,
                              send_stop=lambda f: None)
        stops = {}

        def send_stop(f):
            stops[f]()
        ps.send_stop = send_stop
        for f in range(w):
            back = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
                        10_000, rng)
            s = snd.LTPSender(sim, bottleneck, ps.on_data, n, critical=crit,
                              flow=f, rng=rng)
            ps.attach_ack(f, lambda p, s=s, back=back: back.send(p, s.on_ack))
            stops[f] = (lambda s=s, back=back: back.send(
                Packet(s.flow, -2, 41, kind="stop"), s.on_ack))
            _warm(s, warm[f] if warm else None)
            senders.append(s)
        for f, s in enumerate(senders):
            d = float(start_delays[f]) if start_delays is not None else 0.0
            sim.at(d, s.start)
        sim.run(until=3600.0)
        res = GatherResult(
            bst_gather=ps.bst_gather(),
            fcts=np.minimum(ps.full_times(), ps.bst_gather()),
            delivered=ps.delivered_fracs(),
            full_times=ps.full_times(),
            criticals_ok=ps.criticals_done,
        )
        return res, [_save_warm(s) for s in senders]

    # order-preserving protocols: reliable, BST = max FCT
    fcts = np.full(w, np.inf)
    receivers = []
    for f in range(w):
        back = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
                    10_000, rng)
        def on_done(s, f=f):
            fcts[f] = sim.now
        s = _mk_sender(protocol, sim, bottleneck, None, n, f, rng, on_done)
        r = snd.TcpReceiver(sim, lambda p, s=s, back=back: back.send(p, s.on_ack), f)
        s.deliver = r.on_data
        # registration so the receiver knows flow length
        _warm(s, warm[f] if warm else None)
        senders.append(s)
        receivers.append(r)
    for f, (s, r) in enumerate(zip(senders, receivers)):
        r.n_total = n
        d = float(start_delays[f]) if start_delays is not None else 0.0
        sim.at(d, s.start)
    sim.run(until=3600.0)
    res = GatherResult(
        bst_gather=float(np.max(np.where(np.isfinite(fcts), fcts, sim.now))),
        fcts=np.where(np.isfinite(fcts), fcts, sim.now),
        delivered=np.ones(w),
        full_times=fcts,
        criticals_ok=True,
    )
    return res, [_save_warm(s) for s in senders]


def incast_gather(protocol: str, net: NetConfig, w: int, size_bytes: float,
                  iters: int = 10, ltp: Optional[LTPConfig] = None,
                  seed: int = 0, straggler_prob: float = 0.15,
                  straggler_scale: float = 0.6) -> List[GatherResult]:
    """Repeated gather rounds with Early Close threshold adaptation.

    Stragglers: with prob ``straggler_prob`` a worker starts its flow late
    by Exp(straggler_scale * ECT) — host-side jitter (the paper's Fig-3
    "starved flows"). Set straggler_prob=0 for a pure-protocol incast.
    """
    ltp = ltp or LTPConfig()
    rng = np.random.default_rng(seed)
    bw_share = net.bandwidth_gbps * 1e9 / 8.0 / w
    rt = net.rtprop_ms * 1e-3
    ect = rt + size_bytes / bw_share
    lt = np.full(w, ltp.lt_init_rtprop_mult * rt + size_bytes / bw_share)
    results: List[GatherResult] = []
    warm: List[Optional[dict]] = [None] * w
    best_full = np.full(w, np.inf)
    iters_per_epoch = max(1, iters // 3)
    for i in range(iters):
        delays = np.where(
            rng.random(w) < straggler_prob,
            rng.exponential(straggler_scale * ect, w),
            0.0,
        )
        deadline = float(lt.max()) + ltp.deadline_c_ms * 1e-3
        res, warm = _run_gather(protocol, net, w, size_bytes, rng, warm,
                                float(lt.max()), deadline,
                                ltp.data_pct_threshold,
                                start_delays=delays)
        results.append(res)
        ok = np.isfinite(res.full_times)
        best_full[ok] = np.minimum(best_full[ok], res.full_times[ok])
        if (i + 1) % iters_per_epoch == 0:   # epoch boundary: update LT
            upd = np.isfinite(best_full)
            lt[upd] = best_full[upd]
            if not upd.all():
                # some link never reached 100% (early-closed every round):
                # re-apply the paper's ECT formula with the *measured*
                # per-link BtlBw (repro extension, cf. paper §VI-B)
                for f in np.flatnonzero(~upd):
                    btlbw = (warm[f] or {}).get("btlbw", 0.0) / 8.0  # bytes/s
                    if btlbw > 0:
                        lt[f] = (ltp.lt_init_rtprop_mult * rt
                                 + size_bytes / btlbw)
            best_full[:] = np.inf
    return results


# ----------------------------------------------------------------------------
# full training-iteration loop (gather + broadcast)
# ----------------------------------------------------------------------------


def train_iterations(protocol: str, net: NetConfig, w: int, model_bytes: float,
                     iters: int = 10, ltp: Optional[LTPConfig] = None,
                     seed: int = 0, scale: float = 1.0) -> Dict:
    """Gather (simulated, possibly Early-Closed) + broadcast (reliable,
    one-to-many — modeled via measured p2p utilization since it has no
    incast contention). ``scale`` < 1 simulates a scaled-down model size
    and rescales times back up (documented wherever used)."""
    size = model_bytes * scale
    gs = incast_gather(protocol, net, w, size, iters, ltp, seed)
    util = utilization_cached(protocol, net, size_bytes=max(4e6, w * size))
    bcast = (net.rtprop_ms * 1e-3
             + w * size / (net.bandwidth_gbps * 1e9 / 8.0 * max(util, 1e-3)))
    bst = np.array([g.bst_gather + bcast for g in gs]) / scale
    delivered = np.stack([g.delivered for g in gs])
    return {
        "bst": bst,
        "bst_gather": np.array([g.bst_gather for g in gs]) / scale,
        "bst_broadcast": bcast / scale,
        "delivered": delivered,
        "fct_all": np.concatenate([g.fcts for g in gs]) / scale,
    }


# ----------------------------------------------------------------------------
# fairness
# ----------------------------------------------------------------------------


def fairness_share(proto_a: str, proto_b: str, net: NetConfig,
                   duration: float = 2.0, seed: int = 0) -> Tuple[float, float]:
    """Two long flows share the bottleneck; returns (bytes_a, bytes_b)
    normalized shares over ``duration``."""
    sim = Sim()
    rng = np.random.default_rng(seed)
    bw = net.bandwidth_gbps * 1e9
    bottleneck = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate,
                      net.queue_pkts, rng)
    delivered = {0: 0, 1: 0}
    sender_objs = []
    for f, proto in enumerate((proto_a, proto_b)):
        n = 10_000_000  # effectively infinite
        back = Pipe(sim, bw, net.rtprop_ms * 1e-3 / 2, net.loss_rate, 10_000, rng)
        if proto == "ltp":
            s = snd.LTPSender(sim, bottleneck, None, n, rng=rng, flow=f)
            r = LTPFlowReceiver(sim, lambda p, s=s, back=back: back.send(p, s.on_ack), f)
            def deliver(p, r=r, f=f):
                if p.kind == "data":
                    delivered[f] += p.size
                r.on_data(p, lambda: None)
            s.deliver = deliver
        else:
            s = _mk_sender(proto, sim, bottleneck, None, n, f, rng)
            r = snd.TcpReceiver(sim, lambda p, s=s, back=back: back.send(p, s.on_ack), f)
            def deliver(p, r=r, f=f):
                if p.kind == "data":
                    delivered[f] += p.size
                r.on_data(p)
            s.deliver = deliver
        sender_objs.append(s)
    for s in sender_objs:
        s.start()
    sim.run(until=duration)
    tot = delivered[0] + delivered[1]
    if tot == 0:
        return 0.5, 0.5
    return delivered[0] / tot, delivered[1] / tot
