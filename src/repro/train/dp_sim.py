"""PSTrainer — the paper's 8-worker/1-PS training loop, exactly, on one
host device.

Per-worker gradients come from a ``vmap`` over the worker axis (identical
semantics to W data-parallel machines holding replicated weights). The
transport layer is pluggable:

  * protocol="ltp":      Early Close controller decides each iteration's
                         per-worker delivered fraction; non-critical packets
                         drop i.i.d.; bubbles are zero-filled; compensation
                         per LTPConfig. BST comes from the same controller.
  * protocol tcp-family: lossless sync (delivered=1); BST from the transport
                         model (or DES samples) — only wall-clock differs.

Wall-clock per iteration = compute_time + BST, which is how throughput
(Fig 12), TTA (Fig 13) and BST (Fig 14) are all derived from one loop.
Transport timing backend: AnalyticIncastModel (fast) or precomputed DES
samples (pass ``bst_trace`` — e.g. from any registered net scenario via
``repro.net.scenarios.train_iterations``).

Delivery masks are drawn host-side each step — Bernoulli(frac) with
critical packets pinned, or, when ``mask_trace`` is given, the actual
per-(worker, packet) delivery masks a DES gather produced
(``train_iterations(...)["delivery_masks"]``) — and feed one fused
masked multi-worker reduction (``core.ltp_sync.reduce_packet_stream``).
``LTPConfig.sync_backend`` picks the aggregation backend: the jnp
reference ("python") or the Pallas dropfill/packet_reduce kernels
("pallas"); both agree to float tolerance.

Multi-PS (DESIGN.md §5): with ``n_ps > 1`` the model shards over n_ps
parameter servers, each behind its own trunk; Early Close runs one
controller per shard (``MultiPSEarlyClose``) and the iteration closes
when the slowest shard closes. Phase-aware loss tolerance (§3.3): when
``LTPConfig.phase_final_pct_threshold`` is set, controllers receive the
training progress each step and tighten the received-pct threshold as
training converges.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.core import ltp_sync as ls
from repro.core import packets as pk
from repro.core.early_close import (
    AnalyticIncastModel,
    MultiPSEarlyClose,
    broadcast_time,
)
from repro.models.api import ModelApi
from repro.optim import Optimizer, lr_at


def params_bytes(params) -> int:
    return sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))


class PSTrainer:
    def __init__(
        self,
        api: ModelApi,
        opt: Optimizer,
        train: TrainConfig,
        ltp: LTPConfig,
        net: NetConfig,
        n_workers: int = 8,
        protocol: str = "ltp",
        compute_time: float = 0.05,
        bst_trace: Optional[np.ndarray] = None,
        delivered_trace: Optional[np.ndarray] = None,
        mask_trace: Optional[np.ndarray] = None,
        seed: int = 0,
        n_ps: int = 1,
    ):
        self.api = api
        self.opt = opt
        self.train_cfg = train
        self.ltp = ltp
        self.net = net
        self.w = n_workers
        self.protocol = protocol
        self.compute_time = compute_time
        self.bst_trace = bst_trace
        self.delivered_trace = delivered_trace
        self.mask_trace = (np.asarray(mask_trace, bool)
                           if mask_trace is not None else None)
        self._mask_rng = np.random.default_rng(seed + 23)
        key = jax.random.PRNGKey(seed)
        self.params = api.init(key)
        self.opt_state = opt.init(self.params)
        self.plan = pk.make_plan(
            self.params, ltp.packet_floats, ltp.critical_per_tensor
        )
        self.residual = (
            jnp.zeros((n_workers, self.plan.n_packets, self.plan.packet_floats))
            if ltp.error_feedback else None
        )
        self.model_bytes = self.plan.n_floats * 4
        self.n_ps = n_ps
        self.controller = MultiPSEarlyClose(ltp, net, n_workers,
                                            self.model_bytes, n_ps=n_ps)
        # one analytic incast per PS shard (independent tail draws)
        self.gather_models = [
            AnalyticIncastModel(net, n_workers, protocol=protocol,
                                seed=seed + 1 + 1000 * p)
            for p in range(n_ps)
        ]
        self.sim_time = 0.0
        self.step_idx = 0
        self.history: List[Dict] = []
        self._step_fn = self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self):
        api, opt, ltp, plan, w = self.api, self.opt, self.ltp, self.plan, self.w
        use_ltp = self.protocol == "ltp"

        def per_worker_grads(params, batch):
            def one(b):
                return jax.value_and_grad(lambda p: api.loss_fn(p, b))(params)
            return jax.vmap(one)(batch)   # (W,) losses, (W, ...) grads

        def step(params, opt_state, residual, batch, masks, frac, lr):
            losses, grads_w = per_worker_grads(params, batch)
            flat_w = jax.vmap(lambda g: pk.flatten(plan, g))(grads_w)
            if use_ltp:
                # the PS hot loop: ONE fused masked multi-worker reduction
                # (kernels.packet_reduce under sync_backend="pallas")
                if residual is not None:
                    # error feedback materializes the gated stream anyway —
                    # gate once (dropfill under pallas), reduce the result
                    flat_w = flat_w + residual
                    sent = ls.apply_delivery(
                        flat_w.reshape(w * plan.n_packets, plan.packet_floats),
                        masks.reshape(-1), backend=ltp.sync_backend,
                        interpret=ltp.kernel_interpret,
                    ).reshape(flat_w.shape)
                    new_residual = flat_w - sent
                    mean_flat = ls.reduce_packet_stream(
                        sent, masks, ltp, w, expected_frac=frac,
                        premasked=True)
                else:
                    new_residual = None
                    mean_flat = ls.reduce_packet_stream(
                        flat_w, masks, ltp, w, expected_frac=frac)
                realized = jnp.mean(masks)
            else:
                mean_flat = jnp.mean(flat_w, axis=0)
                new_residual = residual
                realized = jnp.ones(())
            dtypes = [x.dtype for x in jax.tree_util.tree_leaves(params)]
            mean_grads = pk.unflatten(plan, mean_flat, dtypes)
            updates, opt_state = opt.update(mean_grads, opt_state, params, lr)
            params = jax.tree.map(lambda p, u: p + u, params, updates)
            return params, opt_state, new_residual, jnp.mean(losses), realized

        return jax.jit(step)

    # ------------------------------------------------------------------
    def _delivery_masks(self, it: int, frac: np.ndarray) -> np.ndarray:
        """(W, n_packets) float32 per-(worker, packet) delivery mask.

        From the DES ``mask_trace`` when given (the trace's packet stream
        is tiled/cropped onto the plan's packets), else Bernoulli(frac)
        per packet. Critical packets are always pinned to 1 — the CQ
        retransmit guarantee (paper §III-E).
        """
        n = self.plan.n_packets
        if self.mask_trace is not None:
            m = self.mask_trace[it % len(self.mask_trace)]
            reps = -(-n // m.shape[1])
            m = np.tile(m, (1, reps))[:, :n].astype(np.float32)
        else:
            m = (self._mask_rng.random((self.w, n))
                 < np.asarray(frac)[:, None]).astype(np.float32)
        m[:, self.plan.critical] = 1.0
        return m

    # ------------------------------------------------------------------
    def _transport(self, it: int):
        """Returns (bst_seconds, delivered_frac (W,))."""
        if self.bst_trace is not None:
            bst = float(self.bst_trace[it % len(self.bst_trace)])
            if self.delivered_trace is not None:
                return bst, np.asarray(self.delivered_trace[it % len(self.delivered_trace)])
            return bst, np.ones(self.w)
        shard_bytes = self.model_bytes / self.n_ps
        samples = [m.sample(shard_bytes) for m in self.gather_models]
        if self.protocol == "ltp":
            # phase-aware threshold: feed training progress to the
            # per-shard controllers before the close decision
            total = max(1, self.train_cfg.steps)
            self.controller.set_progress(self.step_idx / total)
            close, frac = self.controller.step(samples)
            bst = close + broadcast_time(self.net, self.model_bytes,
                                         n_ps=self.n_ps)
        else:
            close = max(float(s.completion_times.max()) for s in samples)
            bst = close + broadcast_time(
                self.net, self.model_bytes, n_ps=self.n_ps
            ) * self.gather_models[0].loss_inflation()
            frac = np.ones(self.w)
        return bst, frac

    def run(self, batches, *, epoch_steps: int = 0, eval_fn=None,
            eval_every: int = 0, log_every: int = 0) -> List[Dict]:
        for batch in batches:
            batch = jax.tree.map(
                lambda x: jnp.asarray(x).reshape(
                    (self.w, x.shape[0] // self.w) + x.shape[1:]
                ),
                batch,
            )
            bst, frac = self._transport(self.step_idx)
            masks = (self._delivery_masks(self.step_idx, frac)
                     if self.protocol == "ltp"
                     else np.ones((self.w, self.plan.n_packets), np.float32))
            lr = lr_at(self.train_cfg, self.step_idx, epoch_steps)
            (self.params, self.opt_state, self.residual, loss, realized) = \
                self._step_fn(self.params, self.opt_state, self.residual,
                              batch, jnp.asarray(masks),
                              jnp.asarray(frac, jnp.float32),
                              jnp.asarray(lr, jnp.float32))
            self.sim_time += self.compute_time + bst
            rec = {
                "step": self.step_idx,
                "loss": float(loss),
                "bst": bst,
                "delivered": float(realized),
                "sim_time": self.sim_time,
            }
            if epoch_steps and (self.step_idx + 1) % epoch_steps == 0:
                self.controller.new_epoch()
            if eval_fn is not None and eval_every and \
                    (self.step_idx + 1) % eval_every == 0:
                rec["eval"] = float(eval_fn(self.params))
            self.history.append(rec)
            if log_every and self.step_idx % log_every == 0:
                msg = f"step {self.step_idx:5d} loss {rec['loss']:.4f} " \
                      f"bst {bst*1e3:6.1f}ms delivered {rec['delivered']:.3f}"
                if "eval" in rec:
                    msg += f" eval {rec['eval']:.4f}"
                print(msg, flush=True)
            self.step_idx += 1
        return self.history

    # throughput in items/sec of simulated wall-clock
    def throughput(self, items_per_step: int) -> float:
        if not self.history:
            return 0.0
        return items_per_step * len(self.history) / self.sim_time
