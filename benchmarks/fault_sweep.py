"""Fault-injection sweep: training cost of worker churn and PS failover
on the elastic cluster runtime (DESIGN.md §10).

Two questions the fault layer must answer with numbers:

* **churn overhead** — how much simulated time and final loss a given
  crash rate costs, per policy, relative to the fault-free run on the
  same seed (the analytic grid below);
* **failover acceptance** — the headline gate: a 16-worker packet-level
  DES run that loses two workers *and* the parameter server mid-train
  must still converge. ``fault_des16_final_loss_ratio`` (faulted final
  loss / fault-free final loss) is ceiling-gated at 1.10 by
  ``benchmarks.check_regression``: elasticity that silently costs more
  than 10% of final loss is a regression, not a feature.

Every cell is seeded end to end (schedule, compute jitter, packet loss),
so the records are machine-independent and bitwise reproducible.

  PYTHONPATH=src python -m benchmarks.fault_sweep --quick
  PYTHONPATH=src python -m benchmarks.run --only fault_sweep
"""
from __future__ import annotations

import argparse
import time

from repro.config import LTPConfig, NetConfig, RuntimeConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.optim import make_optimizer
from repro.runtime import ClusterRuntime, FaultEvent, FaultSchedule

from benchmarks.common import emit
from benchmarks.sweep_scenarios import write_bench

NET = NetConfig(10, 1, 0.001, 4096)

#: the des16 acceptance scenario: two crashes straddling a PS failure,
#: snapshot grid armed. Times sit mid-train for an 8-step, 0.05 s/iter
#: run so the crashes fence live flows and the failover really rolls
#: back applied state (not a warm-up no-op).
DES16_FAULTS = FaultSchedule([
    FaultEvent(0.07, "worker_crash", target=3),
    FaultEvent(0.13, "worker_crash", target=11),
    FaultEvent(0.20, "ps_fail", target=0, recover_s=0.05),
])


def _cell(api, tc, w, policy, steps, *, faults=None, transport="analytic",
          checkpoint_every_s=0.0, seed=11):
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(), NET,
        n_workers=w, protocol="ltp", policy=policy, compute_time=0.05,
        seed=seed, transport=transport, faults=faults,
        checkpoint_every_s=checkpoint_every_s,
        runtime_cfg=RuntimeConfig(staleness_comp=0.5))
    t0 = time.time()
    rt.run(batches(SyntheticCIFAR(seed=3), tc.batch, steps))
    wall = time.time() - t0
    s = rt.tel.summary()
    return {
        "scenario": f"fault_w{w}", "policy": policy, "transport": transport,
        "n_faults": s.get("n_faults", 0),
        "n_flow_torn": s.get("n_flow_torn", 0),
        "n_ps_lost": s.get("n_ps_lost", 0),
        "n_failovers": s.get("n_failovers", 0),
        "simtime_s": round(rt.sim_time, 4),
        "final_loss": round(float(rt.history[-1]["loss"]), 6),
        "n_steps_done": len(rt.history),
        "wall_s": round(wall, 2),
    }


def run(quick: bool = True):
    steps = 8 if quick else 12
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    rows = []
    metrics = {}
    t_start = time.time()

    # churn-overhead grid: crash rate x policy, analytic transport,
    # rejoining crashers — overhead relative to the rate-0 twin
    w = 16
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
    for policy in ("bsp", "async"):
        base_row = None
        for rate in (0.0, 1.0, 2.0):
            sched = FaultSchedule.random(
                w, steps * 0.05 * 3.0, seed=7, crash_rate=rate,
                rejoin_after_s=0.1, min_active=max(2, w // 2))
            row = _cell(api, tc, w, policy, steps,
                        faults=sched, checkpoint_every_s=0.05)
            row["crash_rate"] = rate
            rows.append(row)
            if rate == 0.0:
                base_row = row
            else:
                key = f"fault_w{w}_{policy}_rate{rate:g}"
                metrics[f"{key}_sim_overhead"] = round(
                    row["simtime_s"] / base_row["simtime_s"], 3)
                metrics[f"{key}_loss_ratio"] = round(
                    row["final_loss"] / base_row["final_loss"], 4)

    # failover acceptance: 16-worker DES, 2 crashes + PS failover,
    # against the fault-free twin on the same seed
    tc16 = TrainConfig(batch=4 * 16, lr=0.05, steps=steps)
    free = _cell(api, tc16, 16, "bsp", steps, transport="des")
    free["scenario"] = "fault_des16_free"
    rows.append(free)
    faulted = _cell(api, tc16, 16, "bsp", steps, transport="des",
                    faults=DES16_FAULTS, checkpoint_every_s=0.05)
    faulted["scenario"] = "fault_des16"
    rows.append(faulted)
    assert faulted["n_steps_done"] == steps, \
        "faulted des16 run did not complete every step"
    assert faulted["n_failovers"] == 1
    ratio = faulted["final_loss"] / free["final_loss"]
    metrics["fault_des16_final_loss_ratio"] = round(ratio, 4)
    metrics["fault_des16_sim_overhead"] = round(
        faulted["simtime_s"] / free["simtime_s"], 3)
    metrics["fault_des16_n_flow_torn"] = faulted["n_flow_torn"]
    metrics["fault_des16_n_ps_lost"] = faulted["n_ps_lost"]
    metrics["fault_sweep_wall_s"] = round(time.time() - t_start, 3)
    write_bench(metrics, quick, "BENCH_faults.json")
    emit(rows, "fault_sweep")
    print(f"des16 failover: final-loss ratio {ratio:.4f} "
          f"(2 crashes + PS failover vs fault-free, ceiling 1.10), "
          f"sim overhead {metrics['fault_des16_sim_overhead']}x")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized grid (default: full)")
    args = ap.parse_args(argv)
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
