"""Yi-34B — llama-architecture dense decoder with GQA [arXiv:2403.04652]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    block_pattern=("A",),
    rope_theta=5e6,
    source="arXiv:2403.04652",
)

REDUCED = CONFIG.replace(
    name="yi-34b-reduced",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv=4,
    head_dim=32,
    d_ff=512,
    vocab=512,
)
