"""Telemetry façade unit coverage (DESIGN.md §8/§12): the per-kind
index behind ``of()``, ``blocked_seconds()`` edge cases, the
fault-scalar summary rules, and the Tracker sink hook."""
import pytest

from repro.obs.tracker import MemoryTracker
from repro.runtime.telemetry import Telemetry


def _tel(events):
    t = Telemetry()
    for kind, ts, fields in events:
        t.record(kind, ts, **fields)
    return t


# ---------------------------------------------------------------------------
# of() — per-kind index
# ---------------------------------------------------------------------------


def test_of_matches_stream_order_and_filter():
    t = _tel([("block", 0.1, {"worker": 0}),
              ("apply", 0.2, {"step": 0}),
              ("block", 0.3, {"worker": 1}),
              ("unblock", 0.4, {"worker": 0})])
    assert t.of("block") == [e for e in t.events if e["kind"] == "block"]
    assert [e["t"] for e in t.of("block")] == [0.1, 0.3]
    assert t.of("nonexistent") == []


def test_of_returns_fresh_list():
    t = _tel([("apply", 0.1, {"step": 0})])
    got = t.of("apply")
    got.clear()
    assert len(t.of("apply")) == 1          # index not corrupted
    # the dicts themselves ARE shared (finalization mutates in place)
    assert t.of("apply")[0] is t.events[0]


def test_record_disabled_keeps_index_empty():
    t = Telemetry(enabled=False)
    t.record("apply", 0.1, step=0)
    assert t.events == [] and t.of("apply") == []


# ---------------------------------------------------------------------------
# blocked_seconds() edge cases (ISSUE 8 satellite)
# ---------------------------------------------------------------------------


def test_blocked_empty_stream():
    assert Telemetry().blocked_seconds() == 0.0


def test_blocked_unmatched_block_counts_to_stream_end():
    t = _tel([("block", 1.0, {"worker": 0}),
              ("apply", 3.5, {"step": 0})])
    assert t.blocked_seconds() == pytest.approx(2.5)


def test_blocked_duplicate_block_keeps_first_timestamp():
    # the setdefault path: a second block for an already-blocked worker
    # must not restart its interval
    t = _tel([("block", 1.0, {"worker": 0}),
              ("block", 2.0, {"worker": 0}),
              ("unblock", 3.0, {"worker": 0})])
    assert t.blocked_seconds() == pytest.approx(2.0)


def test_blocked_unmatched_unblock_ignored():
    t = _tel([("unblock", 1.0, {"worker": 0}),
              ("apply", 2.0, {"step": 0})])
    assert t.blocked_seconds() == 0.0


def test_blocked_interleaved_multi_worker_pairs():
    # w0: [1, 4], w1: [2, 3] interleaved; w2 left open until t_end=5
    t = _tel([("block", 1.0, {"worker": 0}),
              ("block", 2.0, {"worker": 1}),
              ("unblock", 3.0, {"worker": 1}),
              ("unblock", 4.0, {"worker": 0}),
              ("block", 4.5, {"worker": 2}),
              ("apply", 5.0, {"step": 0})])
    assert t.blocked_seconds() == pytest.approx(3.0 + 1.0 + 0.5)


# ---------------------------------------------------------------------------
# summary() fault scalars (ISSUE 8 satellite)
# ---------------------------------------------------------------------------


def test_summary_manual_failover_without_fault_event():
    # a manually driven failover/tear (no injected FaultEvent) must not
    # silently drop its scalars
    t = _tel([("ps_failover", 0.2, {"ps": 0, "step": 1, "n_hist": 1}),
              ("flow_torn", 0.3, {"worker": 1, "iteration": 2})])
    s = t.summary()
    assert s["n_failovers"] == 1
    assert s["n_flow_torn"] == 1
    assert "n_faults" not in s          # no injected fault happened
    assert "n_ps_lost" not in s         # nothing lost, key absent


def test_summary_fault_run_carries_full_key_set():
    # record-for-record parity with the pre-façade summary: a faulted
    # run emits every fault scalar, zeros included
    t = _tel([("fault", 0.1, {"fault": "worker_crash", "target": 0})])
    s = t.summary()
    assert s["n_faults"] == 1
    for key in ("n_flow_torn", "n_ps_lost", "n_failovers",
                "n_checkpoints"):
        assert s[key] == 0


def test_summary_zero_fault_run_has_no_fault_keys():
    t = _tel([("apply", 0.1, {"step": 0, "n_grads": 4, "staleness_max": 0,
                              "staleness_mean": 0.0, "loss": 1.0})])
    s = t.summary()
    assert not any(k in s for k in
                   ("n_faults", "n_flow_torn", "n_ps_lost",
                    "n_failovers", "n_checkpoints"))


# ---------------------------------------------------------------------------
# tracker sink
# ---------------------------------------------------------------------------


def test_record_forwards_to_tracker():
    mem = MemoryTracker()
    t = Telemetry(tracker=mem)
    t.record("apply", 0.1, step=0)
    t.record("block", 0.2, worker=1)
    assert [e["kind"] for e in mem.events] == ["apply", "block"]


def test_attach_replays_prefix():
    t = _tel([("apply", 0.1, {"step": 0}),
              ("block", 0.2, {"worker": 0})])
    mem = MemoryTracker()
    t.attach(mem)
    assert len(mem.events) == 2
    t.record("unblock", 0.3, worker=0)
    assert len(mem.events) == 3
