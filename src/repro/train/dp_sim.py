"""PSTrainer — the paper's W-worker/1-PS training loop on one host
device, now a thin façade over the event-driven cluster runtime
(DESIGN.md §8).

Per-worker gradients come from a ``vmap`` over the worker axis (identical
semantics to W data-parallel machines holding replicated weights). The
transport layer is pluggable:

  * protocol="ltp":      Early Close controller decides each iteration's
                         per-worker delivered fraction; non-critical packets
                         drop i.i.d.; bubbles are zero-filled; compensation
                         per LTPConfig. BST comes from the same controller.
  * protocol tcp-family: lossless sync (delivered=1); BST from the transport
                         model (or DES samples) — only wall-clock differs.

Engines:

  * ``engine="runtime"`` (default): delegates to
    ``repro.runtime.ClusterRuntime`` — the event-driven co-simulation.
    With the default bsp policy and deterministic compute this
    reproduces the legacy lockstep loop record-for-record (same fused
    step, same controller and mask RNG streams; pinned by
    tests/test_runtime.py), while opening the async/ssp aggregation
    policies, heterogeneous compute models, and the packet-level DES
    transport to the same API.
  * ``engine="lockstep"``: the original synchronous loop below. Also
    selected automatically when a precomputed trace (``bst_trace`` /
    ``delivered_trace`` / ``mask_trace``) is supplied, since traces are
    a lockstep-only feature.

Wall-clock per iteration = compute_time + BST, which is how throughput
(Fig 12), TTA (Fig 13) and BST (Fig 14) are all derived from one loop.

Delivery masks are drawn host-side each step — Bernoulli(frac) with
critical packets pinned, or, when ``mask_trace`` is given, the actual
per-(worker, packet) delivery masks a DES gather produced
(``train_iterations(...)["delivery_masks"]``) — and feed one fused
masked multi-worker reduction (``core.ltp_sync.reduce_packet_stream``).
``LTPConfig.sync_backend`` picks the aggregation backend: the jnp
reference ("python") or the Pallas dropfill/packet_reduce kernels
("pallas"); both agree to float tolerance.

Multi-PS (DESIGN.md §5): with ``n_ps > 1`` the model shards over n_ps
parameter servers, each behind its own trunk; Early Close runs one
controller per shard (``MultiPSEarlyClose``) and the iteration closes
when the slowest shard closes. Phase-aware loss tolerance (§3.3): when
``LTPConfig.phase_final_pct_threshold`` is set, controllers receive the
training progress each step and tighten the received-pct threshold as
training converges.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.core import packets as pk
from repro.core.early_close import (
    AnalyticIncastModel,
    MultiPSEarlyClose,
    broadcast_time,
)
from repro.models.api import ModelApi
from repro.net.topology import resolve_topology
from repro.optim import Optimizer, lr_at
from repro.runtime import ClusterRuntime
from repro.runtime import step as stp


def params_bytes(params) -> int:
    return sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))


class PSTrainer:
    def __init__(
        self,
        api: ModelApi,
        opt: Optimizer,
        train: TrainConfig,
        ltp: LTPConfig,
        net: NetConfig,
        n_workers: int = 8,
        protocol: str = "ltp",
        compute_time: float = 0.05,
        bst_trace: Optional[np.ndarray] = None,
        delivered_trace: Optional[np.ndarray] = None,
        mask_trace: Optional[np.ndarray] = None,
        seed: int = 0,
        n_ps: Optional[int] = None,
        engine: str = "runtime",
        policy="bsp",
        policy_kw: Optional[dict] = None,
        compute_model=None,
        transport: str = "analytic",
        topology=None,
        runtime_cfg=None,
    ):
        if engine not in ("runtime", "lockstep"):
            raise ValueError(f"unknown engine {engine!r}")
        topo = resolve_topology(topology, n_ps=n_ps, owner="PSTrainer")
        topo.validate_workers(n_workers, "PSTrainer")
        n_ps = topo.n_ps
        ltp = ltp.with_runtime(runtime_cfg)
        has_trace = (bst_trace is not None or delivered_trace is not None
                     or mask_trace is not None)
        if has_trace:
            engine = "lockstep"   # traces are a lockstep-only feature
        self.api = api
        self.opt = opt
        self.train_cfg = train
        self.ltp = ltp
        self.net = net
        self.w = n_workers
        self.protocol = protocol
        self.compute_time = compute_time
        self.bst_trace = bst_trace
        self.delivered_trace = delivered_trace
        self.mask_trace = (np.asarray(mask_trace, bool)
                           if mask_trace is not None else None)
        self.engine = engine
        self._rt: Optional[ClusterRuntime] = None
        if engine == "runtime":
            self._rt = ClusterRuntime(
                api, opt, train, ltp, net, n_workers=n_workers,
                protocol=protocol, policy=policy, policy_kw=policy_kw,
                compute_model=compute_model, compute_time=compute_time,
                topology=topo, seed=seed, transport=transport)
            # mirror the runtime's state so the public surface is stable
            self.params = self._rt.params
            self.opt_state = self._rt.opt_state
            self.plan = self._rt.plan
            self.residual = self._rt.residual
            self.model_bytes = self._rt.model_bytes
            self.controller = self._rt.controller
            self.gather_models = self._rt.gather_models
            self.telemetry = self._rt.tel
            self.n_ps = n_ps
            self.sim_time = 0.0
            self.step_idx = 0
            self.history: List[Dict] = self._rt.history
            return
        self._mask_rng = np.random.default_rng(seed + 23)
        key = jax.random.PRNGKey(seed)
        self.params = api.init(key)
        self.opt_state = opt.init(self.params)
        self.plan = pk.make_plan(
            self.params, ltp.packet_floats, ltp.critical_per_tensor
        )
        self.residual = (
            jnp.zeros((n_workers, self.plan.n_packets, self.plan.packet_floats))
            if ltp.error_feedback else None
        )
        self.model_bytes = self.plan.n_floats * 4
        self.n_ps = n_ps
        self.telemetry = None
        self.controller = MultiPSEarlyClose(ltp, net, n_workers,
                                            self.model_bytes, n_ps=n_ps)
        # one analytic incast per PS shard (independent tail draws)
        self.gather_models = [
            AnalyticIncastModel(net, n_workers, protocol=protocol,
                                seed=seed + 1 + 1000 * p)
            for p in range(n_ps)
        ]
        self.sim_time = 0.0
        self.step_idx = 0
        self.history: List[Dict] = []
        self._step_fn = stp.build_fused_step(api, opt, ltp, self.plan,
                                             n_workers, protocol)

    # ------------------------------------------------------------------
    def _delivery_masks(self, it: int, frac: np.ndarray) -> np.ndarray:
        """(W, n_packets) float32 per-(worker, packet) delivery mask."""
        return stp.draw_delivery_masks(self.plan, self.w, self._mask_rng,
                                       frac, mask_trace=self.mask_trace,
                                       it=it)

    # ------------------------------------------------------------------
    def _transport(self, it: int):
        """Returns (bst_seconds, delivered_frac (W,))."""
        if self.bst_trace is not None:
            bst = float(self.bst_trace[it % len(self.bst_trace)])
            if self.delivered_trace is not None:
                return bst, np.asarray(self.delivered_trace[it % len(self.delivered_trace)])
            return bst, np.ones(self.w)
        shard_bytes = self.model_bytes / self.n_ps
        samples = [m.sample(shard_bytes) for m in self.gather_models]
        if self.protocol == "ltp":
            # phase-aware threshold: feed training progress to the
            # per-shard controllers before the close decision
            total = max(1, self.train_cfg.steps)
            self.controller.set_progress(self.step_idx / total)
            close, frac = self.controller.step(samples)
            bst = close + broadcast_time(self.net, self.model_bytes,
                                         n_ps=self.n_ps)
        else:
            close = max(float(s.completion_times.max()) for s in samples)
            bst = close + broadcast_time(
                self.net, self.model_bytes, n_ps=self.n_ps
            ) * self.gather_models[0].loss_inflation()
            frac = np.ones(self.w)
        return bst, frac

    def run(self, batches, *, epoch_steps: int = 0, eval_fn=None,
            eval_every: int = 0, log_every: int = 0) -> List[Dict]:
        if self._rt is not None:
            out = self._rt.run(batches, epoch_steps=epoch_steps,
                               eval_fn=eval_fn, eval_every=eval_every,
                               log_every=log_every)
            self.params = self._rt.params
            self.opt_state = self._rt.opt_state
            self.residual = self._rt.residual
            self.sim_time = self._rt.sim_time
            self.step_idx = self._rt.step_idx
            self.history = self._rt.history
            return out
        for batch in batches:
            batch = jax.tree.map(
                lambda x: jnp.asarray(x).reshape(
                    (self.w, x.shape[0] // self.w) + x.shape[1:]
                ),
                batch,
            )
            bst, frac = self._transport(self.step_idx)
            masks = (self._delivery_masks(self.step_idx, frac)
                     if self.protocol == "ltp"
                     else np.ones((self.w, self.plan.n_packets), np.float32))
            lr = lr_at(self.train_cfg, self.step_idx, epoch_steps)
            (self.params, self.opt_state, self.residual, loss, realized) = \
                self._step_fn(self.params, self.opt_state, self.residual,
                              batch, jnp.asarray(masks),
                              jnp.asarray(frac, jnp.float32),
                              jnp.asarray(lr, jnp.float32))
            self.sim_time += self.compute_time + bst
            rec = {
                "step": self.step_idx,
                "loss": float(loss),
                "bst": bst,
                "delivered": float(realized),
                "sim_time": self.sim_time,
            }
            if epoch_steps and (self.step_idx + 1) % epoch_steps == 0:
                self.controller.new_epoch()
            if eval_fn is not None and eval_every and \
                    (self.step_idx + 1) % eval_every == 0:
                rec["eval"] = float(eval_fn(self.params))
            self.history.append(rec)
            if log_every and self.step_idx % log_every == 0:
                msg = f"step {self.step_idx:5d} loss {rec['loss']:.4f} " \
                      f"bst {bst*1e3:6.1f}ms delivered {rec['delivered']:.3f}"
                if "eval" in rec:
                    msg += f" eval {rec['eval']:.4f}"
                print(msg, flush=True)
            self.step_idx += 1
        return self.history

    # throughput in items/sec of simulated wall-clock
    def throughput(self, items_per_step: int) -> float:
        if self._rt is not None:
            return self._rt.throughput(items_per_step)
        if not self.history:
            return 0.0
        return items_per_step * len(self.history) / self.sim_time
