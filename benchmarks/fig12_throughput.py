"""Paper Fig 12: DML training throughput (images/sec) per protocol per
non-congestion loss rate, for the compute-bound (ResNet50-like, 98MB) and
communication-bound (VGG16-like, 528MB) operating points.

BST comes from the packet-level DES (scaled sizes, rescaled back — see
scale arg); compute time per batch is fixed at the paper's testbed-scale
values (T4-class GPU): 50 ms for the 98MB model, 90 ms for the 528MB one.
"""
from __future__ import annotations


from repro.config import NetConfig
from repro.net.scenarios import train_iterations

from benchmarks.common import emit

MODELS = {
    "resnet50_98MB": {"bytes": 98e6, "compute": 0.050, "batch": 256},
    "vgg16_528MB": {"bytes": 528e6, "compute": 0.090, "batch": 256},
}


def run(quick: bool = True):
    rows = []
    losses = [0.0, 0.001, 0.01] if quick else [0.0, 0.0001, 0.001, 0.005, 0.01]
    iters = 6 if quick else 12
    scale = 0.02 if quick else 0.05
    models = ["resnet50_98MB"] if quick else list(MODELS)
    for mname in models:
        m = MODELS[mname]
        for loss in losses:
            net = NetConfig(10, 1, loss, 4096)
            base_tput = {}
            for proto in ["ltp", "bbr", "cubic", "reno"]:
                r = train_iterations(proto, net, 8, m["bytes"], iters=iters,
                                     scale=scale, seed=21)
                step_time = m["compute"] + float(r["bst"].mean())
                tput = m["batch"] / step_time
                base_tput[proto] = tput
                rows.append({
                    "model": mname, "loss": loss, "protocol": proto,
                    "images_per_sec": round(tput, 1),
                    "bst_mean_s": round(float(r["bst"].mean()), 4),
                    "delivered": round(float(r["delivered"].mean()), 3),
                    "speedup_vs_proto": round(
                        base_tput["ltp"] / tput, 2) if proto != "ltp" else 1.0,
                })
    return emit(rows, "fig12_throughput")


if __name__ == "__main__":
    run(quick=False)
