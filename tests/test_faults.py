"""Elastic fault-injected clusters (DESIGN.md §10): zero-fault parity
with the fault-unaware runtime, chaos/soak invariants across
{bsp, async, ssp} x {analytic, DES}, worker churn, PS failover from
periodic snapshots, and generation fencing of dead nodes' traffic.

Invariants the chaos harness asserts on every run:

  * conservation — every grad_ready is applied, stale-dropped, torn
    (crash fencing) or lost (PS downtime); nothing vanishes silently
  * no partial history — every record carries its full schema with a
    finite loss, and bsp histories are step-contiguous
  * determinism — the same (seed, schedule) replays bitwise-identically
"""
import numpy as np
import pytest

from repro.config import FaultConfig, LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.net.simcore import Sim
from repro.net.topology import multi_ps
from repro.optim import make_optimizer
from repro.runtime import (
    ClusterRuntime,
    FaultEvent,
    FaultSchedule,
    ShardLedger,
    schedule_from_config,
)
from repro.runtime.transport import DESTransport

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

NET = NetConfig(10, 1, 0.001, 4096)
W = 4
STEPS = 6


@pytest.fixture(scope="module")
def api():
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    return build(cfg)


def _rt(api, policy="bsp", transport="analytic", steps=STEPS, w=W,
        protocol="ltp", ltp=None, **kw):
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
    return ClusterRuntime(api, make_optimizer(tc), tc, ltp or LTPConfig(),
                          NET, n_workers=w, protocol=protocol, policy=policy,
                          compute_time=0.05, seed=0, transport=transport,
                          **kw)


def _run(rt, steps=STEPS, w=W):
    return rt.run(batches(SyntheticCIFAR(seed=0), 4 * w, steps))


def _assert_conservation(rt):
    """Every grad_ready resolves exactly once (telemetry docstring)."""
    tel = rt.tel
    n_ready = len(tel.of("grad_ready"))
    applied = sum(e["n_grads"] for e in tel.of("apply"))
    n_stale = len(tel.of("stale_drop"))
    n_torn = len(tel.of("flow_torn"))
    n_lost = len(tel.of("ps_lost"))
    assert n_ready == applied + n_stale + n_torn + n_lost, (
        n_ready, applied, n_stale, n_torn, n_lost)


def _assert_complete_history(rt, policy):
    for r in rt.history:
        assert np.isfinite(r["loss"])
        assert {"step", "loss", "sim_time"} <= set(r)
    if policy == "bsp":
        # bsp commits are sequential: churn may degrade a round but can
        # never skip or duplicate an iteration
        assert [r["step"] for r in rt.history] == list(range(rt.steps))


# ---------------------------------------------------------------------------
# schedule / ledger units
# ---------------------------------------------------------------------------


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(0.1, "meteor")
    with pytest.raises(ValueError, match="must be >= 0"):
        FaultEvent(-1.0, "worker_crash")
    with pytest.raises(TypeError):
        FaultSchedule([("not", "an", "event")])


def test_fault_schedule_sorted_deterministic():
    evs = [FaultEvent(0.3, "worker_crash", 1),
           FaultEvent(0.1, "worker_leave", 0),
           FaultEvent(0.3, "worker_join", 1)]
    s = FaultSchedule(evs)
    assert [e.t for e in s] == [0.1, 0.3, 0.3]
    # stable: same-t events keep insertion order
    assert [e.kind for e in s] == ["worker_leave", "worker_crash",
                                   "worker_join"]
    a = FaultSchedule.random(8, 2.0, seed=5, crash_rate=1.0,
                             rejoin_after_s=0.2)
    b = FaultSchedule.random(8, 2.0, seed=5, crash_rate=1.0,
                             rejoin_after_s=0.2)
    assert a.events == b.events and len(a) > 0


def test_fault_schedule_respects_min_active():
    s = FaultSchedule.random(4, 5.0, seed=1, crash_rate=4.0,
                             leave_rate=2.0, min_active=2)
    active = set(range(4))
    for ev in s:
        if ev.kind in ("worker_crash", "worker_leave"):
            assert ev.target in active
            active.discard(ev.target)
        elif ev.kind == "worker_join":
            assert ev.target not in active
            active.add(ev.target)
        assert len(active) >= 2


def test_schedule_from_config_wires_fields():
    cfg = FaultConfig(crash_rate=2.0, rejoin_after_s=0.5, ps_fail_at=(1.0,),
                      ps_recovery_s=0.1, min_active=1, seed=9)
    s = schedule_from_config(cfg, 4, 3.0)
    kinds = {e.kind for e in s}
    assert "ps_fail" in kinds
    ps = [e for e in s if e.kind == "ps_fail"][0]
    assert ps.t == 1.0 and ps.recover_s == 0.1


def test_shard_ledger_failover_and_recover():
    led = ShardLedger(4)
    moves = led.fail(2)
    # survivors [0,1,3]: shard 2 re-homes round-robin to survivors[2 % 3]
    assert moves == [(2, 2, 3)]
    assert led.owner == [0, 1, 3, 3] and led.n_alive == 3
    assert led.fail(2) == []            # idempotent
    led.fail(0)
    assert all(o in {1, 3} for o in led.owner)
    back = led.recover(2)
    assert back == [(2, 3, 2)] and led.owner[2] == 2
    # shard 0 stays re-homed until PS 0 itself recovers
    assert led.owner[0] != 0
    led.recover(0)
    assert led.owner == [0, 1, 2, 3] and led.n_alive == 4


# ---------------------------------------------------------------------------
# acceptance: zero faults scheduled == today's runtime, record for record
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", ["analytic", "des"])
def test_zero_fault_run_is_record_identical(api, transport):
    """An armed-but-empty fault layer (schedule, snapshot grid, ledger,
    flight registry, epoch fences) must be a structural no-op: history
    and final params match the fault-unaware runtime bitwise."""
    base = _rt(api, policy="bsp", transport=transport)
    h0 = _run(base)
    rt = _rt(api, policy="bsp", transport=transport,
             faults=FaultSchedule([]), checkpoint_every_s=0.04)
    h1 = _run(rt)
    assert len(h0) == len(h1) == STEPS
    for a, b in zip(h0, h1):
        assert set(a) == set(b)
        for k in a:
            assert a[k] == b[k], (k, a[k], b[k])
    import jax
    for x, y in zip(jax.tree_util.tree_leaves(base.params),
                    jax.tree_util.tree_leaves(rt.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert len(rt.tel.of("checkpoint")) > 1      # the grid did run


# ---------------------------------------------------------------------------
# chaos/soak: randomized churn across policies x transports
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["bsp", "async", "ssp"])
@pytest.mark.parametrize("transport", ["analytic", "des"])
def test_chaos_churn_invariants(api, policy, transport):
    sched = FaultSchedule.random(W, 0.45, seed=3, crash_rate=2.0,
                                 rejoin_after_s=0.11, leave_rate=0.5,
                                 min_active=2)
    assert len(sched) > 0
    kw = {"policy_kw": {"staleness": 2}} if policy == "ssp" else {}
    rt = _rt(api, policy=policy, transport=transport, faults=sched,
             checkpoint_every_s=0.05, **kw)
    h = _run(rt)
    assert len(h) > 0
    _assert_complete_history(rt, policy)
    _assert_conservation(rt)
    # events past the finish time are skipped, never partially applied
    assert 1 <= rt.tel.summary()["n_faults"] <= len(sched)
    # lifecycle stream shows real churn
    states = {e["state"] for e in rt.tel.of("lifecycle")}
    assert "dead" in states


@pytest.mark.parametrize("policy", ["bsp", "ssp"])
def test_chaos_same_seed_bitwise_identical(api, policy):
    sched = FaultSchedule.random(W, 0.4, seed=11, crash_rate=2.5,
                                 rejoin_after_s=0.09, min_active=2)
    kw = {"policy_kw": {"staleness": 1}} if policy == "ssp" else {}
    runs = []
    for _ in range(2):
        rt = _rt(api, policy=policy, transport="des", faults=sched, **kw)
        runs.append((_run(rt), list(rt.tel.events)))
    h1, t1 = runs[0]
    h2, t2 = runs[1]
    assert h1 == h2                      # bitwise: same floats, same order
    assert t1 == t2                      # full telemetry stream replays


def test_bsp_crash_degrades_round_then_rebarriers(api):
    """A mid-round crash with no rejoin: that iteration commits over the
    survivors (weight W/n keeps the update an unbiased mean), later
    rounds re-barrier on the surviving set, and the run completes."""
    sched = FaultSchedule([FaultEvent(0.055, "worker_crash", target=2)])
    rt = _rt(api, policy="bsp", transport="analytic", faults=sched)
    h = _run(rt)
    _assert_complete_history(rt, "bsp")
    _assert_conservation(rt)
    degraded = [r for r in h if r.get("n_grads", W) < W]
    assert degraded and all(r["n_grads"] == W - 1 for r in degraded)
    assert len(rt.tel.of("flow_torn")) <= 1


def test_bsp_graceful_leave_never_tears_flows(api):
    sched = FaultSchedule([FaultEvent(0.06, "worker_leave", target=1)])
    rt = _rt(api, policy="bsp", transport="des", faults=sched)
    _run(rt)
    _assert_complete_history(rt, "bsp")
    _assert_conservation(rt)
    assert rt.tel.of("flow_torn") == []          # drain, don't tear
    leaves = [e for e in rt.tel.of("lifecycle") if e["state"] == "dead"]
    assert leaves and leaves[0]["reason"] == "leave"


def test_worker_rejoin_pays_warmup_penalty(api):
    from repro.runtime import DeterministicCompute
    sched = FaultSchedule([
        FaultEvent(0.055, "worker_crash", target=0),
        FaultEvent(0.12, "worker_join", target=0),
    ])
    compute = DeterministicCompute(W, base=0.05, rejoin_penalty_s=0.02)
    rt = _rt(api, policy="bsp", transport="analytic", faults=sched,
             compute_model=compute)
    _run(rt)
    _assert_complete_history(rt, "bsp")
    _assert_conservation(rt)
    joins = [e for e in rt.tel.of("lifecycle") if e["state"] == "joining"]
    assert len(joins) == 1
    # the joiner's first compute back carries the warm-up penalty
    post = [e for e in rt.tel.of("compute_start")
            if e["worker"] == 0 and e["t"] >= 0.12]
    assert post and abs(post[0]["dt"] - 0.07) < 1e-9


# ---------------------------------------------------------------------------
# PS failover from periodic snapshots
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", ["analytic", "des"])
def test_ps_failover_restores_and_completes(api, transport, tmp_path):
    sched = FaultSchedule([
        FaultEvent(0.16, "ps_fail", target=0, recover_s=0.05),
    ])
    rt = _rt(api, policy="bsp", transport=transport, faults=sched,
             checkpoint_every_s=0.05, checkpoint_dir=str(tmp_path))
    h = _run(rt)
    _assert_complete_history(rt, "bsp")
    _assert_conservation(rt)
    assert len(rt.tel.of("ps_failover")) == 1
    assert len(rt.tel.of("ps_lost")) > 0         # downtime really cost us
    assert (tmp_path / "runtime_ckpt.npz").exists()
    fo = rt.tel.of("ps_failover")[0]
    # history was truncated to the snapshot frontier and rebuilt
    assert fo["n_hist"] <= fo["step"] + 1
    assert [r["step"] for r in h] == list(range(STEPS))


def test_ps_failover_async_rolls_back_and_continues(api):
    sched = FaultSchedule([
        FaultEvent(0.15, "ps_fail", target=0, recover_s=0.04),
    ])
    rt = _rt(api, policy="async", transport="analytic", faults=sched,
             checkpoint_every_s=0.04)
    h = _run(rt)
    assert len(h) > 0 and all(np.isfinite(r["loss"]) for r in h)
    _assert_conservation(rt)
    assert len(rt.tel.of("ps_failover")) == 1
    # record stream stays step-monotonic across the rollback splice
    steps = [r["step"] for r in h]
    assert steps == sorted(steps)


def test_ps_fail_without_snapshot_raises(api):
    sched = FaultSchedule([FaultEvent(0.1, "ps_fail", recover_s=0.01)])
    rt = _rt(api, policy="bsp", faults=sched)
    rt._snap = None

    # defeat the automatic t=0 anchor to prove the guard exists
    orig = rt._take_snapshot
    rt._take_snapshot = lambda: None
    try:
        with pytest.raises(RuntimeError, match="no snapshot"):
            _run(rt)
    finally:
        rt._take_snapshot = orig


def test_crash_plus_failover_multi_ps_rebalances(api):
    sched = FaultSchedule([
        FaultEvent(0.055, "worker_crash", target=3),
        FaultEvent(0.17, "ps_fail", target=1, recover_s=0.05),
        FaultEvent(0.33, "ps_recover", target=1),
    ])
    rt = _rt(api, policy="bsp", transport="des", faults=sched,
             checkpoint_every_s=0.05, topology=multi_ps(2))
    h = _run(rt)
    _assert_complete_history(rt, "bsp")
    _assert_conservation(rt)
    reb = rt.tel.of("rebalance")
    assert len(reb) == 2                         # fail re-home + recover
    assert list(reb[0]["owner"]) == [0, 0]       # PS1's shard moved to PS0
    assert list(reb[1]["owner"]) == [0, 1]       # home again
    assert [r["step"] for r in h] == list(range(STEPS))


# ---------------------------------------------------------------------------
# generation fencing under churn (transport-level harness)
# ---------------------------------------------------------------------------


def _fence_harness(ops):
    """Interleave send / crash / time-advance against the pooled DES
    transport; the delivery callback asserts its flow is still live —
    a single late delivery from a torn flow fails the run."""
    sim = Sim()
    tr = DESTransport(sim, NET, LTPConfig(), "ltp", 2, 8192.0, seed=0)
    alive = {}
    fired = []
    seq = [0]

    def send(wkr):
        key = (wkr, seq[0])
        seq[0] += 1

        def cb(masks, frac, early, key=key):
            assert key in alive, f"torn flow {key} delivered"
            del alive[key]
            fired.append(key)

        alive[key] = True
        tr.send(wkr, cb)

    for op, arg in ops:
        if op == "send":
            send(arg % 2)
        elif op == "crash":
            wkr = arg % 2
            for key in [k for k in alive if k[0] == wkr]:
                del alive[key]
            tr.teardown_worker(wkr)
        elif op == "step":
            sim.run(until=sim.now + arg * 1e-4)
    # bounded drain (background sources free-run; 1 sim-second is orders
    # of magnitude past any surviving flow's deadline)
    sim.run(until=sim.now + 1.0)
    tr.stop()
    # whatever was not torn must have delivered: no lost live flows
    assert alive == {}, f"live flows never delivered: {alive}"
    return fired


def test_generation_fencing_deterministic_interleavings():
    """Seeded random crash/recycle interleavings (runs without
    hypothesis): a payload stamped with a dead generation is never
    delivered, and every surviving flow completes."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        ops = []
        for _ in range(24):
            r = rng.random()
            if r < 0.45:
                ops.append(("send", int(rng.integers(0, 2))))
            elif r < 0.65:
                ops.append(("crash", int(rng.integers(0, 2))))
            else:
                ops.append(("step", int(rng.integers(1, 40))))
        _fence_harness(ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(ops=st.lists(
        st.one_of(
            st.tuples(st.just("send"), st.integers(0, 1)),
            st.tuples(st.just("crash"), st.integers(0, 1)),
            st.tuples(st.just("step"), st.integers(1, 50)),
        ),
        min_size=1, max_size=30))
    def test_generation_fencing_property(ops):
        """Property form of the fencing invariant: for ANY interleaving
        of crash/recycle/advance, pooled senders/receivers never deliver
        a payload stamped with a dead generation."""
        _fence_harness(list(ops))
