"""Sender-side congestion-control state machines.

Reno / Cubic are order-preserving, cumulative-ACK, loss-as-congestion.
BBR is a simplified BDP prober (loss-agnostic rate control, reliable).
LTP (paper §III/§IV): out-of-order transmission, per-packet ACK,
3-OOO-ACK loss detection, CQ/NQ/RQ queues, BDP-based CC with approximate
pacing, and receiver-driven Early Close ("stop").

Packet trains (DESIGN.md §7): with ``train_len > 1`` and a train-aware
``deliver_train`` callback attached, LTP and the window-based TCP family
emit bursts as coalesced trains through ``Pipe.send_train`` and consume
batched ACK trains via ``on_ack_train`` — K packets per heap event in
both directions. BBR keeps its per-packet pacing clock (its control law
is the inter-send spacing itself) and ignores ``train_len``.

Flow pooling (DESIGN.md §9): every sender supports ``reset(gen)`` — it
restores cold-start state in place so the cluster runtime can reuse one
sender object per (worker, shard) across iterations instead of
reconstructing the whole flow graph each round. ``gen`` is a flow
generation stamped into every outgoing packet's ``meta["g"]`` and echoed
back in ACKs; state machines silently drop packets from another
generation, so deliveries still in flight when a flow is recycled cannot
leak into the next round. Un-pooled callers never pass ``gen`` and both
sides stay at generation 0.
"""
from __future__ import annotations

import collections
import math
from functools import partial
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.net.genfence import GEN_KEY, echo_stale, gen_of, is_stale
from repro.net.simcore import Packet, Pipe, Sim, TrainItems

MSS = 1460          # TCP payload bytes per packet
TCP_OVERHEAD = 40
LTP_PAYLOAD = 1435  # 1500 - 28 (UDP/IP) - 9 (LTP header) ≈ paper §IV-A
LTP_OVERHEAD = 37


#: protocol name -> sender class; scenario code goes through ``make_sender``
#: so new congestion controllers plug in without touching the scenarios.
SENDER_REGISTRY: Dict[str, type] = {}


def register_sender(name: str):
    def deco(cls):
        SENDER_REGISTRY[name] = cls
        return cls
    return deco


def make_sender(protocol: str, sim: "Sim", pipe, deliver, n_packets: int, *,
                flow: int = 0, rng=None, on_done=None, critical=None,
                train_len: int = 1):
    """Uniform sender construction over every registered protocol.

    ``pipe`` is anything with ``send(pkt, deliver)`` — a ``Pipe`` or a
    multi-hop ``Route``. LTP-specific knobs (``critical``, ``rng``) are
    ignored by the TCP family. ``train_len`` > 1 enables coalesced packet
    trains on senders that support them (callers must also attach a
    train-aware ``deliver_train``).
    """
    try:
        cls = SENDER_REGISTRY[protocol]
    except KeyError:
        raise ValueError(
            f"unknown protocol {protocol!r}; registered: "
            f"{sorted(SENDER_REGISTRY)}") from None
    if issubclass(cls, LTPSender):
        return cls(sim, pipe, deliver, n_packets, critical=critical,
                   flow=flow, rng=rng, on_done=on_done, train_len=train_len)
    return cls(sim, pipe, deliver, n_packets, flow=flow, on_done=on_done,
               train_len=train_len)


class RateEstimator:
    """BBR-style windowed max(delivery rate) + min(rtt)."""

    def __init__(self, sim: Sim):
        self.sim = sim
        self._acks: Deque[Tuple[float, int]] = collections.deque()
        self._bw_samples: Deque[Tuple[float, float]] = collections.deque()
        self.reset()

    def reset(self) -> None:
        """Cold-start state in place (flow pooling, DESIGN.md §9)."""
        self.rtprop = math.inf
        self._acks.clear()
        self._ack_bytes = 0
        self._bw_samples.clear()
        self._btlbw = 0.0

    def on_ack(self, nbytes: int, rtt: float):
        now = self.sim.now
        self.rtprop = min(self.rtprop, rtt)
        self._acks.append((now, nbytes))
        self._ack_bytes += nbytes
        horizon = max(self.rtprop * 2, 2e-3) if math.isfinite(self.rtprop) else 10e-3
        while self._acks and self._acks[0][0] < now - horizon:
            self._ack_bytes -= self._acks.popleft()[1]
        if len(self._acks) >= 2:
            dt = self._acks[-1][0] - self._acks[0][0]
            nb = self._ack_bytes - self._acks[0][1]
            if dt > 0:
                rate = nb * 8.0 / dt
                # monotonic deque: windowed max in O(1) amortized
                while self._bw_samples and self._bw_samples[-1][1] <= rate:
                    self._bw_samples.pop()
                self._bw_samples.append((now, rate))
        bw_horizon = max(self.rtprop * 10, 20e-3) if math.isfinite(self.rtprop) else 0.1
        while self._bw_samples and self._bw_samples[0][0] < now - bw_horizon:
            self._bw_samples.popleft()

    @property
    def btlbw(self) -> float:
        return self._bw_samples[0][1] if self._bw_samples else 0.0

    def bdp_pkts(self, mss: int) -> float:
        if not math.isfinite(self.rtprop) or self.btlbw <= 0:
            return 10.0
        return max(4.0, self.btlbw * self.rtprop / 8.0 / mss)


# ============================================================================
# Order-preserving TCP family
# ============================================================================


class TcpReceiver:
    """Cumulative-ACK receiver shared by Reno/Cubic/BBR."""

    def __init__(self, sim: Sim, send_ack: Callable[[Packet], None], flow: int):
        self.sim = sim
        self.send_ack = send_ack
        # transport wiring, attached once from outside; reset() keeps it
        self.send_ack_train: Optional[Callable[[List[Packet]], None]] = None  # replint: ok(pool-reset)
        self.flow = flow
        self.received: Set[int] = set()
        self.gen = 0
        self.reset()

    def reset(self, gen: Optional[int] = None,
              n_total: Optional[int] = None) -> None:
        """Cold-start receiver state in place (flow pooling)."""
        if gen is not None:
            self.gen = gen
        self.received.clear()
        self.next_expected = 0
        self.complete_time: Optional[float] = None
        self.n_total: Optional[int] = n_total

    def _stale(self, pkt: Packet) -> bool:
        g = gen_of(pkt.meta)
        return g is not None and g != self.gen

    # replint: hotpath
    def _ack_for(self, pkt: Packet) -> Packet:
        if pkt.kind == "reg":
            self.n_total = pkt.meta["n"]
        else:
            self.received.add(pkt.seq)
            while self.next_expected in self.received:
                self.next_expected += 1
        return Packet(self.flow, pkt.seq, TCP_OVERHEAD, kind="ack",
                      meta={"cum": self.next_expected, "echo": pkt.meta})

    def on_data(self, pkt: Packet):
        if self._stale(pkt):
            return
        self.send_ack(self._ack_for(pkt))
        if self.n_total is not None and self.next_expected >= self.n_total \
                and self.complete_time is None:
            self.complete_time = self.sim.now

    def on_data_train(self, items: TrainItems):
        """Process a coalesced train; the completion stamp uses the true
        per-packet arrival time, and the ACKs go back as one train."""
        acks = []
        for pkt, t in items:
            if self._stale(pkt):
                continue
            acks.append(self._ack_for(pkt))
            if self.n_total is not None and self.next_expected >= self.n_total \
                    and self.complete_time is None:
                self.complete_time = t
        if not acks:
            return
        if self.send_ack_train is not None:
            self.send_ack_train(acks)
        else:
            for a in acks:
                self.send_ack(a)


class _TcpBase:
    """Window-based reliable sender skeleton with SACK-style recovery
    (Linux-default behaviour). Reno/Cubic differ only in the cwnd law."""

    DUPTHRESH = 3

    def __init__(self, sim: Sim, pipe: Pipe, deliver: Callable, n_packets: int,
                 flow: int = 0, mss: int = MSS, on_done: Optional[Callable] = None,
                 train_len: int = 1):
        self.sim = sim
        self.pipe = pipe
        self.deliver = deliver
        # transport wiring, attached once from outside; reset() keeps it
        self.deliver_train: Optional[Callable[[TrainItems], None]] = None  # replint: ok(pool-reset)
        self.train_len = max(1, int(train_len))
        self.n = n_packets
        self.flow = flow
        self.mss = mss
        self.on_done = on_done
        self.inflight: Set[int] = set()
        self.sacked: Set[int] = set()
        self.retx: collections.deque = collections.deque()
        self.sent_time: Dict[int, float] = {}
        self.gen = 0
        self.rto_event: Optional[int] = None
        self.reset()

    def reset(self, gen: Optional[int] = None) -> None:
        """Restore cold-start sender state in place (flow pooling).

        ``gen`` bumps the flow generation: stale ACKs from a previous
        life of this sender (echoing an older ``meta["g"]``) are dropped
        on arrival instead of corrupting the fresh state machine.
        """
        if gen is not None:
            self.gen = gen
        self._train_buf = None
        self._in_ack_train = False
        self._rto_dirty = False
        self.cwnd = 10.0
        self.ssthresh = math.inf
        self.next_new = 0
        self.cum = 0
        self.dup = 0
        self.recover = -1
        self.inflight.clear()
        self.sacked.clear()
        self.retx.clear()
        self.marked: Set[int] = set()   # lost-marked this recovery episode
        self._scan_hi = 0               # scoreboard scan high-water mark
        self.sent_time.clear()
        self.srtt: Optional[float] = None
        self.rttvar = 0.0
        if self.rto_event is not None:
            self.sim.cancel(self.rto_event)
        self.rto_event = None
        self.done = False
        self.start_time: Optional[float] = None
        self.bytes_acked = 0

    def kill(self) -> None:
        """Hard-stop this sender (node death / pooled teardown): no
        completion callback, no further transmissions, all timers
        cancelled. In-flight ACKs fall on ``done`` and are ignored; the
        pooled sender revives through ``reset(gen=...)``."""
        self.done = True
        if self.rto_event is not None:
            self.sim.cancel(self.rto_event)
        self.rto_event = None
        pt = getattr(self, "pacing_timer", None)
        if pt is not None:
            self.sim.cancel(pt)
            self.pacing_timer = None

    # --- cwnd law hooks -----------------------------------------------------
    def on_ack_growth(self, newly: int):
        if self.cwnd < self.ssthresh:
            self.cwnd += newly
        else:
            self.cwnd += newly / self.cwnd

    def on_loss_cut(self):
        self.ssthresh = max(2.0, self.cwnd / 2.0)
        self.cwnd = self.ssthresh

    # -------------------------------------------------------------------------
    def start(self):
        self.start_time = self.sim.now
        self._arm_rto()
        self._pump()

    @property
    def rto(self) -> float:
        if self.srtt is None:
            return 0.2
        return max(0.01, self.srtt + 4 * self.rttvar)

    def _arm_rto(self):
        if self._in_ack_train:       # one re-arm per ack train, at its end
            self._rto_dirty = True
            return
        if self.rto_event is not None:
            self.sim.cancel(self.rto_event)
        self.tlp_armed = True
        delay = max(2 * (self.srtt or 0.05), 0.002)
        self.rto_event = self.sim.after(min(delay, self.rto), self._on_tlp)

    def _on_tlp(self):
        """Tail-loss probe: retransmit the head once before a full RTO."""
        if self.done:
            return
        self._prune_inflight()
        if self.cum < self.next_new and self.cum not in self.sacked:
            self._send(self.cum)
        self.rto_event = self.sim.after(self.rto, self._on_rto)
        self._pump()

    def _on_rto(self):
        if self.done:
            return
        self.ssthresh = max(2.0, self.cwnd / 2.0)
        self.cwnd = 1.0
        self.dup = 0
        self.recover = -1
        self.inflight.clear()
        self.retx.clear()
        self.marked = set()
        self._scan_hi = self.cum
        for s in range(self.cum, self.next_new):
            if s not in self.sacked:
                self.marked.add(s)
                self.retx.append(s)
        self._arm_rto()
        self._pump()

    def _mark_lost(self, s: int):
        if s in self.marked or s in self.sacked or s < self.cum:
            return
        self.marked.add(s)
        self.inflight.discard(s)
        self.retx.append(s)

    # replint: hotpath
    def _send(self, seq: int):
        pkt = Packet(self.flow, seq, self.mss, kind="data",
                     meta={"t": self.sim.now, GEN_KEY: self.gen})
        self.inflight.add(seq)
        self.sent_time[seq] = self.sim.now
        if self._train_buf is not None:
            self._train_buf.append(pkt)
        else:
            self.pipe.send(pkt, self.deliver)

    def _prune_inflight(self):
        """Expire inflight entries older than RTO (silent queue drops would
        otherwise pin the window shut)."""
        cutoff = self.sim.now - self.rto
        # sorted so the retransmit queue fills in seq order, not set-hash
        # order (bitwise same-seed replay must not depend on set history)
        stale = sorted(s for s in self.inflight
                       if self.sent_time.get(s, 0) < cutoff)
        for s in stale:
            self.inflight.discard(s)
            if s >= self.cum and s not in self.sacked and s not in self.retx:
                self.retx.append(s)

    def _pump(self):
        if self._in_ack_train:       # one pump per ack train, at its end
            return
        if self.train_len > 1 and self.deliver_train is not None:
            self._train_buf = []
            try:
                self._pump_window()
            finally:
                buf, self._train_buf = self._train_buf, None
            for i in range(0, len(buf), self.train_len):
                self.pipe.send_train(buf[i:i + self.train_len],
                                     self.deliver_train)
            return
        self._pump_window()

    # replint: hotpath
    def _pump_window(self):
        while len(self.inflight) < int(self.cwnd):
            if self.retx:
                seq = self.retx.popleft()
                if seq >= self.cum and seq not in self.sacked:
                    self._send(seq)
                continue
            if self.next_new < self.n:
                self._send(self.next_new)
                self.next_new += 1
            else:
                break

    def on_ack(self, pkt: Packet):
        if self.done:
            return
        echo = pkt.meta.get("echo") or {}
        if echo_stale(echo, self.gen):
            return          # ACK for a previous life of this pooled flow
        cum = pkt.meta["cum"]
        if "t" in echo:
            rtt = self.sim.now - echo["t"]
            if self.srtt is None:
                self.srtt, self.rttvar = rtt, rtt / 2
            else:
                self.rttvar = 0.75 * self.rttvar + 0.25 * abs(self.srtt - rtt)
                self.srtt = 0.875 * self.srtt + 0.125 * rtt
        # SACK: the data seq this ACK acknowledges
        if pkt.seq >= self.cum:
            if pkt.seq not in self.sacked:
                self.sacked.add(pkt.seq)
                self._arm_rto()   # any forward progress re-arms the timer
            self.inflight.discard(pkt.seq)
        if cum > self.cum:
            newly = cum - self.cum
            self.bytes_acked += newly * self.mss
            for s in range(self.cum, cum):
                self.inflight.discard(s)
                self.sacked.discard(s)
            self.cum = cum
            self.dup = 0
            if self.recover >= 0 and cum > self.recover:
                self.recover = -1
            elif self.recover >= 0 and cum < self.next_new and \
                    cum not in self.sacked:
                self._mark_lost(cum)   # NewReno partial-ACK retransmit
            self.on_ack_growth(newly)
            self._arm_rto()
        elif cum == self.cum and cum < self.n:
            self.dup += 1
            if self.dup >= self.DUPTHRESH and self.sacked:
                # SACK scoreboard: unSACKed seqs DUPTHRESH below the highest
                # SACKed seq are lost. Rate cut once per recovery episode;
                # ``marked`` + the scan pointer keep this O(1) amortized.
                hs = max(self.sacked)
                if self.recover < 0:
                    self.recover = self.next_new
                    self.on_loss_cut()
                    self.marked = set()
                    self._scan_hi = self.cum
                for s in range(self._scan_hi, max(self._scan_hi, hs - self.DUPTHRESH + 1)):
                    if s not in self.sacked:
                        self._mark_lost(s)
                self._scan_hi = max(self._scan_hi, hs - self.DUPTHRESH + 1)
                if self.cum not in self.sacked:
                    self._mark_lost(self.cum)
        if self.cum >= self.n:
            self.done = True
            if self.rto_event is not None:
                self.sim.cancel(self.rto_event)
            if self.on_done:
                self.on_done(self)
            return
        self._pump()

    def on_ack_train(self, items: TrainItems):
        """Consume a batched ACK train: per-ack cwnd/SACK bookkeeping runs
        unchanged, but the RTO re-arm and the send pump fire once for the
        whole train instead of once per ack."""
        if self.done:
            return
        self._in_ack_train = True
        self._rto_dirty = False
        try:
            for pkt, _t in items:
                self.on_ack(pkt)
                if self.done:
                    return
        finally:
            self._in_ack_train = False
        if self._rto_dirty:
            self._arm_rto()
        self._pump()


@register_sender("reno")
class RenoSender(_TcpBase):
    pass


@register_sender("cubic")
class CubicSender(_TcpBase):
    C = 0.4
    BETA = 0.7

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.wmax = 0.0
        self.epoch: Optional[float] = None

    def reset(self, gen: Optional[int] = None) -> None:
        super().reset(gen)
        self.wmax = 0.0
        self.epoch = None

    def on_loss_cut(self):
        self.wmax = self.cwnd
        self.cwnd = max(2.0, self.cwnd * self.BETA)
        self.ssthresh = self.cwnd
        self.epoch = None

    def on_ack_growth(self, newly: int):
        if self.cwnd < self.ssthresh:
            self.cwnd += newly
            return
        if self.epoch is None:
            self.epoch = self.sim.now
            self.k = (self.wmax * (1 - self.BETA) / self.C) ** (1.0 / 3.0)
        t = self.sim.now - self.epoch
        target = self.C * (t - self.k) ** 3 + self.wmax
        if target > self.cwnd:
            self.cwnd = min(target, self.cwnd + newly)
        else:
            self.cwnd += 0.01 * newly


@register_sender("bbr")
class BBRSender(_TcpBase):
    """Paced BDP sender; loss does not cut the rate (reliable via retx)."""

    GAINS = [1.25, 0.75, 1, 1, 1, 1, 1, 1]

    def __init__(self, *a, **kw):
        self.est = None
        super().__init__(*a, **kw)

    def reset(self, gen: Optional[int] = None) -> None:
        super().reset(gen)
        if self.est is None:
            self.est = RateEstimator(self.sim)
        else:
            self.est.reset()
        self.phase = 0
        self.phase_start = 0.0
        self.startup = True
        self.full_bw = 0.0
        self.full_cnt = 0
        self.next_send_time = 0.0
        if getattr(self, "pacing_timer", None) is not None:
            self.sim.cancel(self.pacing_timer)
        self.pacing_timer: Optional[int] = None
        self.round_end_seq = 0  # cum level that closes the current round

    def on_loss_cut(self):  # loss is not a congestion signal
        pass

    def on_ack_growth(self, newly: int):
        pass

    def _gain(self) -> float:
        if self.startup:
            return 2.885
        if math.isfinite(self.est.rtprop) and \
                self.sim.now - self.phase_start > self.est.rtprop:
            self.phase = (self.phase + 1) % len(self.GAINS)
            self.phase_start = self.sim.now
        return self.GAINS[self.phase]

    def _cap(self) -> float:
        return 2.0 * self.est.bdp_pkts(self.mss) if not self.startup else \
            max(10.0, 3.0 * self.est.bdp_pkts(self.mss))

    def _pump(self):
        if self.done:
            return
        if len(self.inflight) >= self._cap():
            return
        rate = self.est.btlbw * self._gain()
        if rate <= 0:
            rate = float("inf")  # no estimate yet: blast the initial window
        if self.sim.now < self.next_send_time:
            if self.pacing_timer is None:
                def fire():
                    self.pacing_timer = None
                    self._pump()
                self.pacing_timer = self.sim.at(self.next_send_time, fire)
            return
        seq = None
        while self.retx and seq is None:
            seq = self.retx.popleft()
            if seq < self.cum or seq in self.sacked:
                seq = None
        if seq is None and self.next_new < self.n:
            seq = self.next_new
            self.next_new += 1
        if seq is None:
            return
        self._send(seq)
        self.next_send_time = self.sim.now + self.mss * 8.0 / rate
        g = self.gen
        self.sim.at(self.next_send_time,
                    lambda: self.gen == g and self._pump())

    def on_ack(self, pkt: Packet):
        echo = pkt.meta.get("echo") or {}
        if echo_stale(echo, self.gen):
            return          # ACK for a previous life of this pooled flow
        if "t" in echo:
            self.est.on_ack(self.mss, self.sim.now - echo["t"])
        if self.startup and pkt.meta["cum"] >= self.round_end_seq:
            # once per round-trip of data: has btlbw plateaued?
            self.round_end_seq = self.next_new
            bw = self.est.btlbw
            if bw > self.full_bw * 1.25:
                self.full_bw = bw
                self.full_cnt = 0
            else:
                self.full_cnt += 1
                if self.full_cnt >= 3:
                    self.startup = False
        super().on_ack(pkt)


# ============================================================================
# LTP sender (paper §III-D, §IV-B)
# ============================================================================


@register_sender("ltp")
class LTPSender:
    """Out-of-order sender with CQ/NQ/RQ queues and BDP-based CC.

    Self-healing (DESIGN.md §14): when ``heal`` is armed by the
    transport (only while a network fault plane is active — the default
    keeps healthy-run timing bitwise identical), consecutive watchdog
    RTOs with zero ACK progress escalate the retransmission timer
    exponentially up to ``RTO_BACKOFF_CAP``x, and ``BLACKHOLE_RTOS``
    consecutive RTOs abort the flow as dead-path: ``on_flow_dead(flow)``
    signals up to the transport instead of retransmitting forever into
    a blackhole. Registration retries ride the same backoff.
    """

    OOO_THRESH = 3
    RTO_BACKOFF_CAP = 16.0   # max multiplier on the watchdog delay
    BLACKHOLE_RTOS = 6       # consecutive silent RTOs -> path is dead

    def __init__(self, sim: Sim, pipe: Pipe, deliver: Callable, n_packets: int,
                 critical: Optional[np.ndarray] = None, flow: int = 0,
                 payload: int = LTP_PAYLOAD, rng: Optional[np.random.Generator] = None,
                 on_done: Optional[Callable] = None, train_len: int = 1):
        self.sim = sim
        self.pipe = pipe
        self.deliver = deliver
        # transport wiring, attached once from outside; reset() keeps it
        self.deliver_train: Optional[Callable[[TrainItems], None]] = None  # replint: ok(pool-reset)
        self.train_len = max(1, int(train_len))
        self.n = n_packets
        self.flow = flow
        self.payload = payload
        self.rng = rng or np.random.default_rng(0)
        self.on_done = on_done
        crit = critical if critical is not None else np.zeros(n_packets, bool)
        if n_packets > 0:   # paper: first/last bytes of the stream are critical
            crit = crit.copy()
            crit[0] = crit[-1] = True
        self.critical = crit
        # queue seeds, computed once — reset() rebuilds the deques from
        # these instead of re-running flatnonzero every iteration
        self._cq0 = np.flatnonzero(crit).tolist()
        self._nq0 = np.flatnonzero(~crit).tolist()
        self.cq: Deque[int] = collections.deque(self._cq0)
        self.nq: Deque[int] = collections.deque(self._nq0)
        self.est = RateEstimator(sim)
        self.send_order: Dict[int, int] = {}
        self.outstanding: Deque[Tuple[int, int]] = collections.deque()  # (order, seq)
        self.acked: Set[int] = set()
        self.gen = 0
        self.watchdog: Optional[int] = None
        self.pacing_timer: Optional[int] = None
        # observability counters (DESIGN.md §12) — cumulative across the
        # pooled flow's lives: initialized here, NOT cleared by reset()
        self.n_retx = 0         # replint: ok(pool-reset)
        self.n_ack_trains = 0   # replint: ok(pool-reset)
        self.n_gen_fenced = 0   # replint: ok(pool-reset)
        # self-healing (DESIGN.md §14): transport wiring + cumulative
        # counter survive pooled resets; the per-life backoff state is
        # re-initialized by reset()
        self.heal = False       # replint: ok(pool-reset)
        self.on_flow_dead: Optional[Callable[[int], None]] = None  # replint: ok(pool-reset)
        self.n_flow_dead = 0    # replint: ok(pool-reset)
        self.reset()

    def reset(self, gen: Optional[int] = None) -> None:
        """Restore cold-start state in place (flow pooling, DESIGN.md §9).

        Pending timers are cancelled and the flow generation bumps so
        stale deliveries/ACKs from the previous life are dropped.
        """
        if gen is not None:
            self.gen = gen
        self.cq.clear()
        self.cq.extend(self._cq0)
        self.nq.clear()
        self.nq.extend(self._nq0)
        self.rq: List[int] = []
        self.est.reset()
        self.send_order.clear()
        self.order_ctr = 0
        self.outstanding.clear()
        self.acked.clear()
        self.highest_acked_order = -1
        self.stopped = False
        self.done = False
        self.reg_acked = False
        self.startup = True
        self.full_bw = 0.0
        self.full_cnt = 0
        self.next_send_time = 0.0
        self.total_sent = 0
        self.start_time: Optional[float] = None
        self._phase = 0
        self._phase_start = 0.0
        self._last_check = -1.0
        self.rto_backoff = 1.0
        self.n_consec_rto = 0
        if self.watchdog is not None:
            self.sim.cancel(self.watchdog)
        self.watchdog = None
        if self.pacing_timer is not None:
            self.sim.cancel(self.pacing_timer)
        self.pacing_timer = None

    def kill(self) -> None:
        """Hard-stop (node death / pooled teardown): the flow goes
        permanently silent — no stop handshake, no callbacks, timers
        cancelled. Any traffic still in flight falls on ``done``/stale
        generation checks. ``reset(gen=...)`` revives the pooled flow."""
        self.stopped = True
        self.done = True
        if self.watchdog is not None:
            self.sim.cancel(self.watchdog)
        self.watchdog = None
        if self.pacing_timer is not None:
            self.sim.cancel(self.pacing_timer)
        self.pacing_timer = None

    def start(self):
        self.start_time = self.sim.now
        self.reg_acked = False
        self._send_reg(self.gen)
        self._pump()
        self._arm_watchdog()

    def _send_reg(self, gen: Optional[int] = None):
        """Registration carries the flow metadata — critical, so it is
        retried until acknowledged (paper §III-E: critical = 100%)."""
        if gen is not None and gen != self.gen:
            return          # retry chain from a previous life of the flow
        if self.reg_acked or self.done:
            return
        reg = Packet(self.flow, -1, 64, kind="reg",
                     meta={"n": self.n, "t": self.sim.now,
                           GEN_KEY: self.gen,
                           "critical": self.critical})
        self.pipe.send(reg, self.deliver)
        delay = (max(3 * self.est.rtprop, 5e-3)
                 if math.isfinite(self.est.rtprop) else 20e-3)
        if self.heal:
            # reg retries ride the RTO backoff (DESIGN.md §14): a dead
            # path must not be hammered at the base retry rate forever
            delay *= self.rto_backoff
        self.sim.after(delay, partial(self._send_reg, self.gen))

    def _arm_watchdog(self):
        if self.watchdog is not None:
            self.sim.cancel(self.watchdog)
        # per-packet retransmission timer: a few RTTs (ack losses must not
        # stall the flow — there is no cumulative-ACK recovery in LTP)
        delay = max(3 * self.est.rtprop, 3e-3) if math.isfinite(self.est.rtprop) else 0.2
        if self.heal:
            delay *= self.rto_backoff
        self.watchdog = self.sim.after(delay, self._on_watchdog)

    def _on_watchdog(self):
        """Stall recovery: treat all outstanding as lost (per-packet RTO).

        With healing armed, consecutive silent RTOs escalate the backoff
        and eventually declare the path dead (DESIGN.md §14)."""
        if self.done or self.stopped:
            return
        if self.heal:
            self.n_consec_rto += 1
            if self.n_consec_rto >= self.BLACKHOLE_RTOS:
                self._abort_blackhole()
                return
            self.rto_backoff = min(self.rto_backoff * 2.0,
                                   self.RTO_BACKOFF_CAP)
        while self.outstanding:
            _, seq = self.outstanding.popleft()
            if seq not in self.acked:
                self._requeue_lost(seq)
        self._arm_watchdog()
        self._pump()

    def _abort_blackhole(self):
        """``BLACKHOLE_RTOS`` consecutive RTOs with zero ACK progress:
        the path is dead (DESIGN.md §14). The flow aborts — permanently
        silent, no completion callback — and ``on_flow_dead`` signals up
        to the transport, which tears the worker's flows exactly like
        the node-death ``flow_torn`` path."""
        self.n_flow_dead += 1
        self.stopped = True
        self.done = True
        if self.watchdog is not None:
            self.sim.cancel(self.watchdog)
        self.watchdog = None
        if self.pacing_timer is not None:
            self.sim.cancel(self.pacing_timer)
        self.pacing_timer = None
        if self.on_flow_dead is not None:
            self.on_flow_dead(self.flow)

    def _requeue_lost(self, seq: int):
        self.n_retx += 1
        if self.critical[seq]:
            self.cq.append(seq)
        else:
            # random-in, first-out; scalar random() is ~2x cheaper than
            # integers() and this runs once per detected loss
            pos = int(self.rng.random() * (len(self.rq) + 1))
            self.rq.insert(pos, seq)

    def _next_seq(self) -> Optional[int]:
        while self.cq:
            s = self.cq.popleft()
            if s not in self.acked:
                return s
        while self.nq:
            s = self.nq.popleft()
            if s not in self.acked:
                return s
        while self.rq:
            s = self.rq.pop(0)
            if s not in self.acked:
                return s
        return None

    GAINS = [1.25, 0.75, 1, 1, 1, 1, 1, 1]  # BBR-style probe cycle (§III-D)

    def _cap(self) -> float:
        # BDP-based inflight bound (paper §III-D); 2x headroom mirrors BBR's
        # cwnd_gain so LTP holds its share next to BBR (paper Fig 15)
        bdp = self.est.bdp_pkts(self.payload)
        return max(10.0, 2.0 * bdp)

    def _gain(self) -> float:
        if self.startup:
            return 2.885
        if math.isfinite(self.est.rtprop) and \
                self.sim.now - getattr(self, "_phase_start", 0.0) > self.est.rtprop:
            self._phase = (getattr(self, "_phase", 0) + 1) % len(self.GAINS)
            self._phase_start = self.sim.now
        return self.GAINS[getattr(self, "_phase", 0)]

    # replint: hotpath
    def _next_packet(self) -> Optional[Packet]:
        seq = self._next_seq()
        if seq is None:
            return None
        order = self.order_ctr
        self.order_ctr += 1
        self.send_order[seq] = order
        self.outstanding.append((order, seq))
        self.total_sent += 1
        return Packet(self.flow, seq, self.payload, kind="data",
                      critical=bool(self.critical[seq]),
                      meta={"t": self.sim.now, "order": order,
                            GEN_KEY: self.gen})

    def _pump(self):
        if self.done or self.stopped:
            return
        coalesce = self.train_len > 1 and self.deliver_train is not None
        while len(self.outstanding) < self._cap():
            if self.sim.now < self.next_send_time:
                if self.pacing_timer is None:
                    def fire():
                        self.pacing_timer = None
                        self._pump()
                    self.pacing_timer = self.sim.at(self.next_send_time, fire)
                return
            if coalesce:
                # per-packet admission is `while len(outstanding) < cap`, so a
                # fractional BDP cap still admits up to ceil(cap) — flooring
                # here would stall one packet short of the reference path
                room = math.ceil(self._cap()) - len(self.outstanding)
                batch = []
                while len(batch) < min(self.train_len, room):
                    pkt = self._next_packet()
                    if pkt is None:
                        break
                    batch.append(pkt)
                if not batch:
                    return
                self.pipe.send_train(batch, self.deliver_train)
                n_sent = len(batch)
            else:
                pkt = self._next_packet()
                if pkt is None:
                    return
                self.pipe.send(pkt, self.deliver)
                n_sent = 1
            # approximate pacing (paper §III-D): rate-limit bursts above 20
            # packets at the BBR-computed pacing rate (a whole train pays
            # its K packets' worth of pacing budget at once)
            rate = self.est.btlbw * self._gain()
            if rate > 0 and len(self.outstanding) > 20:
                self.next_send_time = self.sim.now + \
                    n_sent * self.payload * 8.0 / rate

    def on_ack(self, pkt: Packet):
        if self.done:
            return
        if pkt.kind == "stop":
            if is_stale(pkt.meta, self.gen):
                self.n_gen_fenced += 1
                return      # stop aimed at a previous life of this flow
            self.stopped = True
            self.done = True
            if self.watchdog is not None:
                self.sim.cancel(self.watchdog)
            if self.on_done:
                self.on_done(self)
            return
        seq = pkt.seq
        if seq == -1:           # registration ack
            if is_stale(pkt.meta, self.gen):
                self.n_gen_fenced += 1
                return
            self.reg_acked = True
            self.n_consec_rto = 0   # the path answered: not a blackhole
            self.rto_backoff = 1.0
            if len(self.acked) >= self.n:
                self._finish()  # data completed while the reg was in flight
            return
        echo = pkt.meta.get("echo") or {}
        if echo_stale(echo, self.gen):
            self.n_gen_fenced += 1
            return          # ACK for a previous life of this pooled flow
        if "t" in echo:
            self.est.on_ack(self.payload, self.sim.now - echo["t"])
        self._startup_check()
        self.acked.add(seq)
        order = pkt.meta.get("order", self.send_order.get(seq, -1))
        self.highest_acked_order = max(self.highest_acked_order, order)
        self.n_consec_rto = 0   # ACK progress: the path is alive
        self.rto_backoff = 1.0
        self._arm_watchdog()
        self._scan_outstanding()
        # the flow is only complete once the registration is acked too:
        # the reg carries the critical metadata (n, critical set) the
        # receiver's close rule depends on, so a sender that goes silent
        # with the reg lost in flight would deadlock the gather
        if self.reg_acked and len(self.acked) >= self.n:
            self._finish()
            return
        self._pump()

    def _startup_check(self):
        """BBR-style startup exit: btlbw plateau over ~3 rtprop rounds."""
        if not self.startup:
            return
        if math.isfinite(self.est.rtprop) and \
                self.sim.now - getattr(self, "_last_check", -1.0) <= self.est.rtprop:
            return
        self._last_check = self.sim.now
        bw = self.est.btlbw
        if bw > self.full_bw * 1.25:
            self.full_bw = bw
            self.full_cnt = 0
        else:
            self.full_cnt += 1
            if self.full_cnt >= 3:
                self.startup = False

    def _finish(self):
        self.done = True
        if self.watchdog is not None:
            self.sim.cancel(self.watchdog)
        if self.on_done:
            self.on_done(self)

    def _scan_outstanding(self):
        """3-OOO-ACK loss detection over the outgoing order queue."""
        while self.outstanding:
            o, s = self.outstanding[0]
            if s in self.acked:
                self.outstanding.popleft()
            elif self.highest_acked_order - o >= self.OOO_THRESH:
                self.outstanding.popleft()
                self._requeue_lost(s)
            else:
                break

    def on_ack_train(self, items: TrainItems):
        """Consume a batched ACK train: per-ack bookkeeping is a tight
        loop; the rate estimator takes ONE aggregated sample for the train
        (stretch-ack semantics: total acked bytes, min RTT), and the OOO
        scan / watchdog / pump each run once."""
        if self.done:
            return
        self.n_ack_trains += 1
        rtts = []
        for pkt, _t in items:
            if pkt.kind == "stop":
                self.on_ack(pkt)        # terminal: fires on_done
                if self.done:
                    return
                continue                # stale stop: keep consuming
            if pkt.seq == -1:
                if is_stale(pkt.meta, self.gen):
                    self.n_gen_fenced += 1
                    continue
                self.reg_acked = True
                continue
            echo = pkt.meta.get("echo") or {}
            if echo_stale(echo, self.gen):
                self.n_gen_fenced += 1
                continue    # ACK for a previous life of this pooled flow
            if "t" in echo:
                rtts.append(self.sim.now - echo["t"])
            self.acked.add(pkt.seq)
            order = pkt.meta.get("order", self.send_order.get(pkt.seq, -1))
            if order > self.highest_acked_order:
                self.highest_acked_order = order
        if rtts:
            self.est.on_ack(self.payload * len(rtts), min(rtts))
        self._startup_check()
        self.n_consec_rto = 0   # ACK progress: the path is alive
        self.rto_backoff = 1.0
        self._arm_watchdog()
        self._scan_outstanding()
        if self.reg_acked and len(self.acked) >= self.n:
            self._finish()
            return
        self._pump()

    def stats(self) -> Dict[str, int]:
        """Cumulative per-flow counters across pooled lives
        (DESIGN.md §12)."""
        return {"n_retx": self.n_retx,
                "n_ack_trains": self.n_ack_trains,
                "n_gen_fenced": self.n_gen_fenced,
                "n_flow_dead": self.n_flow_dead}
