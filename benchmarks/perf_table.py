"""Render the README perf table from the committed BENCH records.

  PYTHONPATH=src python -m benchmarks.perf_table \
      [BENCH_netsim.json [BENCH_runtime.json [BENCH_faults.json]]]

Prints a GitHub-flavored markdown table; the README "Performance" section
is this script's output, regenerated whenever the baselines are
refreshed. Netsim rows come from ``BENCH_netsim.json``; the runtime DES
rows (the §9 fast-path acceptance metrics) from ``BENCH_runtime.json``;
the fault-tolerance acceptance row (§10) from ``BENCH_faults.json``.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.sweep_scenarios import REPO_ROOT


def _metrics(path: str) -> dict:
    with open(path) as f:
        return json.load(f).get("metrics", {})


def render(path: str, runtime_path: str = None,
           faults_path: str = None) -> str:
    m = _metrics(path)
    k = m.get("grid64_coalesce", "?")
    lines = [
        "| cell (64 workers, 2 MB model) | wall s | sim packet-events/s |",
        "|---|---:|---:|",
    ]
    for proto in ("ltp", "cubic"):
        for n_ps in (1, 4):
            wall = m.get(f"grid64_{proto}_ps{n_ps}_wall_s")
            eps = m.get(f"grid64_{proto}_ps{n_ps}_events_per_sec")
            if wall is None:
                continue
            lines.append(f"| {proto} x {n_ps} PS (trains of {k}) "
                         f"| {wall:g} | {eps:,.0f} |")
    ref = m.get("grid64_ref_per_packet_events_per_sec")
    twin = m.get("grid64_ref_coalesced_events_per_sec")
    if ref and twin:
        lines.append(f"| 64x4 reference: per-packet -> trains of {k} "
                     f"| — | {ref:,.0f} -> {twin:,.0f} "
                     f"({m.get('grid64_coalesce_speedup', '?')}x) |")
    combo = m.get("rack512_combo_speedup_vs_best_single")
    if combo is not None:
        eps = m.get("rack512_ltp_agg_events_per_sec")
        eps_s = f"{eps:,.0f}" if eps else "—"
        lines.append(
            f"| rack512: 16x32 rack/spine, 8:1 oversub — LTP + ToR "
            f"aggregation, {combo}x vs best single mechanism "
            f"| {m.get('rack512_wall_s', '?'):g} | {eps_s} |")
    sweep = m.get("sweep_small_wall_s")
    if sweep is not None:
        lines.append(f"| small scenario grid (4 protocols x 7 cells) "
                     f"| {sweep:g} | — |")
    if runtime_path and os.path.exists(runtime_path):
        r = _metrics(runtime_path)
        des = r.get("runtime_des_events_per_sec")
        cold = r.get("runtime_des_cold_events_per_sec")
        if des:
            cold_s = f", cold {cold:,.0f}" if cold else ""
            lines.append(f"| runtime DES co-sim, 8 workers bsp/ltp (warm"
                         f"{cold_s}) | — | {des:,.0f} |")
        des64 = r.get("runtime_des64_events_per_sec")
        if des64:
            k64 = r.get("runtime_des64_coalesce", "?")
            lines.append(f"| runtime DES co-sim, 64 workers bsp/ltp "
                         f"(trains of {k64}) | — | {des64:,.0f} |")
        jsonl = r.get("runtime_des_jsonl_events_per_sec")
        ratio = r.get("telemetry_overhead_ratio")
        if des and jsonl:
            ratio_s = f"{ratio:g}x" if ratio is not None else "?"
            lines.append(
                f"| observability: same cell, tracker off -> JSONL "
                f"(overhead {ratio_s}, ceiling 1.05) "
                f"| — | {des:,.0f} -> {jsonl:,.0f} |")
    if faults_path and os.path.exists(faults_path):
        fm = _metrics(faults_path)
        ratio = fm.get("fault_des16_final_loss_ratio")
        if ratio is not None:
            over = fm.get("fault_des16_sim_overhead", "?")
            lines.append(
                f"| fault des16: 2 crashes + PS failover, final-loss "
                f"ratio {ratio:g} (ceiling 1.10), sim overhead {over}x "
                f"| — | — |")
    return "\n".join(lines)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else os.path.join(REPO_ROOT, "BENCH_netsim.json")
    runtime_path = argv[1] if len(argv) > 1 else os.path.join(
        REPO_ROOT, "BENCH_runtime.json")
    faults_path = argv[2] if len(argv) > 2 else os.path.join(
        REPO_ROOT, "BENCH_faults.json")
    print(render(path, runtime_path, faults_path))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
