"""Roofline analysis (deliverable g): per (arch x shape x mesh), the three
terms from the compiled dry-run artifact:

  compute_s    = per-device HLO dot/conv FLOPs / 197 TFLOP/s   (v5e bf16)
  memory_s     = per-device HLO bytes accessed / 819 GB/s
  collective_s = per-device collective bytes / 50 GB/s ICI

(The SPMD module is the per-device program, so walker numbers are already
per-chip; multiplying by chips and dividing by chips*peak cancels.)

MODEL_FLOPS = 6*N(active)*tokens for train, 2*N(active)*tokens for
inference — the "useful work"; the ratio MODEL_FLOPS / (chips * HLO_FLOPs)
exposes remat/recompute/dispatch waste.

Requires the dry-run sweep to have run (benchmarks/dryrun_results/*.json).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import build
from repro.shapes import get_shape

from benchmarks.common import DRYRUN_DIR, emit

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def param_counts(arch: str) -> Dict[str, float]:
    """Total and active (per-token) parameter counts."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    cfg = get_config(arch)
    api = build(cfg)
    sds = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    total = routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(sds)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        if any("experts_" in str(getattr(p, "key", "")) for p in path):
            routed += n
    active = total - routed
    if cfg.n_experts > 0 and routed:
        active += routed * cfg.top_k / cfg.n_experts
    out = {"total": float(total), "active": float(active)}
    _PARAM_CACHE[arch] = out
    return out


def _attn_flops_per_seq(cfg, s: int, decode: bool) -> float:
    """Forward attention-score+PV FLOPs per sequence (excluded from 2ND)."""
    total = 0.0
    for code in cfg.pattern_layers:
        if code in ("A", "W", "L"):
            hd = (cfg.qk_nope_dim + cfg.qk_rope_dim) if code == "L" else cfg.hd
            h = cfg.n_heads
            if decode:
                kv = s  # one token vs full cache
                total += 4.0 * h * hd * kv
            else:
                kv_avg = (s + 1) / 2.0
                if code == "W" and cfg.window > 0:
                    kv_avg = min(kv_avg, float(cfg.window))
                total += 4.0 * h * hd * s * kv_avg
    if cfg.shared_attn_every > 0:  # zamba2 shared block applications
        napp = len(cfg.pattern_layers) // cfg.shared_attn_every
        per = 4.0 * cfg.n_heads * cfg.hd * (s if decode else s * (s + 1) / 2.0)
        total += napp * per
    if cfg.family == "audio":  # encoder self + decoder cross attention
        f = cfg.encoder_frames
        total += cfg.encoder_layers * 4.0 * cfg.n_heads * cfg.hd * f * f
        total += cfg.n_layers * 4.0 * cfg.n_heads * cfg.hd * (1 if decode else s) * f
    return total


def model_flops(arch: str, shape_name: str) -> float:
    """Useful FLOPs: 2*N_active per token (x3 for train) + attention."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = param_counts(arch)["active"]
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return (6.0 * n * s + 3.0 * _attn_flops_per_seq(cfg, s, False)) * b
    if shape.kind == "prefill":
        return (2.0 * n * s + _attn_flops_per_seq(cfg, s, False)) * b
    return (2.0 * n + _attn_flops_per_seq(cfg, s, True)) * b


def analyze_record(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok") or "skipped" in rec or "walker" not in rec:
        return None
    w = rec["walker"]
    n_chips = 512 if rec["mesh"] == "2x16x16" else 256
    compute_s = w["flops"] / PEAK_FLOPS_BF16
    memory_s = w["bytes"] / HBM_BW
    collective_s = w["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = w["flops"] * n_chips
    ratio = mf / hlo_global if hlo_global else 0.0
    hints = {
        "compute": "at the compute roof — raise MFU via larger per-chip "
                   "tiles or drop remat on cheap layers",
        "memory": "HBM-bound — fuse elementwise chains, keep activations "
                  "bf16, shrink attention transients",
        "collective": "ICI-bound — reshard to cut all-gathers (head/expert "
                      "parallel), overlap collectives with compute",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "step": rec["step"], "ltp": rec.get("ltp", False),
        "compute_s": round(compute_s, 6),
        "memory_s": round(memory_s, 6),
        "collective_s": round(collective_s, 6),
        "dominant": dominant,
        "model_flops": f"{mf:.3e}",
        "hlo_flops_global": f"{hlo_global:.3e}",
        "useful_ratio": round(ratio, 3),
        "temp_gib": round(
            rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30, 2),
        "hint": hints[dominant],
    }


def run(quick: bool = True):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(f))
        row = analyze_record(rec)
        if row:
            rows.append(row)
    if not rows:
        rows = [{"note": "no dryrun results found — run "
                         "python -m repro.launch.dryrun --all first"}]
    return emit(rows, "roofline")


if __name__ == "__main__":
    run(quick=False)
