"""Kernel microbench — the PS-side hot loop as Pallas tiles (DESIGN.md §7).

Times ``kernels.dropfill`` (bubble-fill + compensation gate) and
``kernels.packet_reduce`` (fused masked multi-worker reduction) through
the ``ops.py`` padding wrappers, plus the end-to-end sync step
(``core.ltp_sync.reduce_packet_stream``) under the python, pallas, AND
auto backends at two stream sizes.

The auto contract (DESIGN.md §9) is asserted in-run: at BOTH bench
sizes ``sync_backend="auto"`` must land within ``AUTO_TOLERANCE`` (1.1x)
of the better of python/pallas — the kernel path is never a regression.
The record also carries ``sync_crossover_elems``, the stream size at
which auto switches to pallas (0 when pallas never wins at the probed
sizes — the interpret-mode/CPU situation).

On CPU the kernels run in interpret mode, so the GB/s figures are the
*interpreter's* — a stable regression baseline for CI, not hardware
numbers; on a real TPU pass ``interpret=False`` for roofline rates.

Writes ``BENCH_kernels.json`` at the repo root (consumed by
``benchmarks.check_regression``) and the usual rows under results/.

  PYTHONPATH=src python -m benchmarks.run --only kernel_bench
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LTPConfig
from repro.core import ltp_sync as ls
from repro.core.ltp_sync import reduce_packet_stream
from repro.kernels import ops

from benchmarks.common import emit
from benchmarks.sweep_scenarios import write_bench

#: auto may cost at most this factor over min(python, pallas) per size
AUTO_TOLERANCE = 1.1


def _time(fn, *args, reps: int = 3, **kw) -> float:
    """Best-of-reps wall seconds, after one compile/warmup call."""
    jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.time() - t0)
    return best


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    w = 8
    n = 1024 if quick else 8192
    n_small = max(64, n // 4)     # second size: the auto gate needs two
    p = 360                       # non-lane-aligned: exercises ops padding
    pkts_w = jnp.asarray(rng.normal(size=(w, n, p)).astype(np.float32))
    masks_w = jnp.asarray((rng.random((w, n)) < 0.8).astype(np.float32))
    pkts = pkts_w[0]
    mask = masks_w[0]
    scale = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))

    rows = []
    metrics = {}

    t = _time(ops.ltp_dropfill, pkts, mask, scale)
    gb = 2 * n * p * 4 / 1e9      # one read + one write of the stream
    rows.append({"kernel": "dropfill", "shape": f"({n},{p})",
                 "wall_s": round(t, 4), "gbps": round(gb / t, 3)})
    metrics["dropfill_wall_s"] = round(t, 4)
    metrics["dropfill_gbps"] = round(gb / t, 3)

    t = _time(ops.ltp_packet_reduce, pkts_w, masks_w)
    gb = (w + 1) * n * p * 4 / 1e9    # W reads + one write per output tile
    rows.append({"kernel": "packet_reduce", "shape": f"({w},{n},{p})",
                 "wall_s": round(t, 4), "gbps": round(gb / t, 3)})
    metrics["packet_reduce_wall_s"] = round(t, 4)
    metrics["packet_reduce_gbps"] = round(gb / t, 3)

    ltp = LTPConfig(compensation="count")
    crossover = 0
    # small size first: the recorded crossover must be the SMALLEST
    # probed stream size where pallas wins, not whichever won first
    for size_tag, nn in (("_small", n_small), ("", n)):
        pw, mw = pkts_w[:, :nn], masks_w[:, :nn]
        fns = {}
        for backend in ("python", "pallas", "auto"):
            fn = jax.jit(lambda a, b, be=backend: reduce_packet_stream(
                a, b, ltp, w, backend=be))
            jax.block_until_ready(fn(pw, mw))       # compile/warm
            fns[backend] = fn
        # interleaved best-of-reps: a noisy-neighbor slowdown on a
        # shared runner hits every backend's samples alike, so the
        # auto-vs-best comparison below measures dispatch, not load.
        # The 1.1x contract is re-measured up to 3 times before failing:
        # CPU-frequency jitter can make two runs of the IDENTICAL
        # computation differ >10%, while a genuinely wrong auto dispatch
        # (the pallas interpreter, ~5-10x here) fails every attempt.
        for attempt in range(3):
            walls = {b: float("inf") for b in fns}
            for _ in range(5):
                for backend, fn in fns.items():
                    t0 = time.time()
                    jax.block_until_ready(fn(pw, mw))
                    walls[backend] = min(walls[backend], time.time() - t0)
            best = min(walls["python"], walls["pallas"])
            if walls["auto"] <= best * AUTO_TOLERANCE + 2e-3:
                break
        assert walls["auto"] <= best * AUTO_TOLERANCE + 2e-3, (
            f"sync_backend='auto' regressed at n={nn}: "
            f"{walls['auto']:.4f}s vs best backend {best:.4f}s "
            f"(budget {AUTO_TOLERANCE}x + 2ms, 3 attempts) — "
            f"auto must never lose")
        for backend, t in walls.items():
            rows.append({"kernel": f"sync_{backend}{size_tag}",
                         "shape": f"({w},{nn},{p})", "wall_s": round(t, 4)})
            metrics[f"sync_{backend}{size_tag}_wall_s"] = round(t, 4)
        if walls["pallas"] < walls["python"] and crossover == 0:
            crossover = w * nn * p
    # 0 = pallas never won at the probed sizes (interpret mode / CPU);
    # on a compiled-kernel backend this records the measured switch point
    # that calibrates ltp_sync.AUTO_CROSSOVER_ELEMS
    metrics["sync_crossover_elems"] = crossover
    metrics["sync_auto_resolves_interpret"] = (
        1 if ls.resolve_backend("auto", w * n * p, True) == "python" else 0)

    write_bench(metrics, quick, "BENCH_kernels.json")
    emit(rows, "kernel_bench")
    return rows


if __name__ == "__main__":
    run(quick=True)
