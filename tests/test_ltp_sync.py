"""LTP gradient-sync semantics: shard_map v1 (packet-local), leafwise v2,
PSTrainer vmapped path — equivalences and compensation properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import LTPConfig
from repro.core import make_ltp_sync
from repro.core import ltp_sync as ls
from repro.core import packets as pk

N_DEV = jax.device_count()


from repro import compat


def _mesh(shape, axes):
    return compat.make_mesh(shape, axes)


@pytest.fixture(scope="module")
def mesh1():
    return _mesh((1, 1), ("data", "model"))


def _grads():
    return {
        "w": jnp.arange(512, dtype=jnp.float32).reshape(32, 16) / 100,
        "b": jnp.linspace(-1, 1, 24),
    }


def test_full_delivery_is_identity(mesh1):
    grads = _grads()
    specs = {"w": P(), "b": P()}
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    sync = make_ltp_sync(shapes, mesh1, LTPConfig(packet_floats=8), specs)
    out, _, stats = sync(grads, jnp.ones((1,)), jax.random.PRNGKey(0))
    np.testing.assert_allclose(out["w"], grads["w"], rtol=1e-6)
    np.testing.assert_allclose(out["b"], grads["b"], rtol=1e-6)
    assert float(stats["delivered_frac"]) == 1.0


def test_zero_delivery_keeps_critical_only(mesh1):
    grads = _grads()
    specs = {"w": P(), "b": P()}
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    ltp = LTPConfig(packet_floats=8)
    sync = make_ltp_sync(shapes, mesh1, ltp, specs)
    out, _, _ = sync(grads, jnp.zeros((1,)), jax.random.PRNGKey(0))
    flat_in = pk.flatten(sync.plan, grads)
    flat_out = pk.flatten(sync.plan, out)
    crit = sync.plan.critical
    np.testing.assert_allclose(flat_out[crit], flat_in[crit], rtol=1e-6)
    assert np.all(np.asarray(flat_out)[~crit] == 0)


def test_error_feedback_conserves_gradient(mesh1):
    """sent + residual == grads (+ previous residual) exactly."""
    grads = _grads()
    specs = {"w": P(), "b": P()}
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads)
    ltp = LTPConfig(packet_floats=8, error_feedback=True)
    sync = make_ltp_sync(shapes, mesh1, ltp, specs)
    res0 = sync.init_residual()
    out, res1, _ = sync(grads, jnp.full((1,), 0.5), jax.random.PRNGKey(3), res0)
    flat_in = np.asarray(pk.flatten(sync.plan, grads))
    flat_out = np.asarray(pk.flatten(sync.plan, out))  # W=1 -> mean == sent
    np.testing.assert_allclose(flat_out + np.asarray(res1)[0, 0], flat_in,
                               rtol=1e-6, atol=1e-7)


# ----------------------------------------------------------------------------
# leafwise (v2) masking
# ----------------------------------------------------------------------------


def test_leafwise_masks_packet_structure():
    grads = {"w": jnp.ones((10, 7)), "b": jnp.ones((5,))}
    ltp = LTPConfig(packet_floats=8)
    masks, pkt_masks = ls.leafwise_packet_masks(
        grads, jax.random.PRNGKey(0), 0.5, ltp
    )
    flat = np.asarray(masks["w"]).ravel()
    # within a packet the mask is constant
    for p in range(len(flat) // 8):
        seg = flat[p * 8:(p + 1) * 8]
        assert np.all(seg == seg[0])
    # critical first/last packet always delivered
    assert flat[0] == 1.0 and flat[-1] == 1.0
    assert np.asarray(masks["b"]).all()  # 1 packet -> critical -> delivered


def test_leafwise_sync_full_delivery_identity():
    mesh = _mesh((1, 1), ("data", "model"))
    grads = _grads()
    ltp = LTPConfig(packet_floats=8)

    def inner(g, frac, key):
        return ls.masked_psum_leafwise(g, key, frac, ltp, ("data",), 1)

    out, realized = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), grads), P(), P()),
        out_specs=(jax.tree.map(lambda _: P(), grads), P()),
        axis_names={"data"}, check=True,
    )(grads, jnp.ones((1,)), jax.random.PRNGKey(0))
    np.testing.assert_allclose(out["w"], grads["w"], rtol=1e-6)
    assert float(realized) == 1.0


# ----------------------------------------------------------------------------
# PSTrainer-path compensation statistics
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("comp,expect_unbiased", [("paper", False),
                                                  ("count", True)])
def test_compensation_bias(comp, expect_unbiased):
    """With identical grads across workers, count-compensation reproduces
    the true mean exactly on delivered packets; paper-mode shrinks toward 0
    by E[frac]."""
    w, n, p = 8, 200, 8
    grads = {"g": jnp.ones((n * p,))}
    plan = pk.make_plan(grads, packet_floats=p)
    flat = pk.flatten(plan, grads)
    flat_w = jnp.broadcast_to(flat, (w,) + flat.shape)
    keys = jax.random.split(jax.random.PRNGKey(1), w)
    frac = 0.6
    masks = jax.vmap(lambda k: pk.delivery_mask(plan, k, frac))(keys)
    sent = flat_w * masks[:, :, None]
    tot = jnp.sum(sent, axis=0)
    if comp == "count":
        cnt = jnp.maximum(jnp.sum(masks, axis=0), 1.0)
        mean = tot / cnt[:, None]
        # every packet delivered by >=1 worker gives exact mean 1.0
        got = np.asarray(mean)[np.asarray(jnp.sum(masks, 0)) > 0]
        np.testing.assert_allclose(got, 1.0, rtol=1e-6)
    else:
        mean = tot / w
        m = float(jnp.mean(mean))
        assert abs(m - frac) < 0.08   # shrunk toward E[frac]
