"""Fixture tests for the replint invariant linter (DESIGN.md §13).

Each rule gets a fires-on-violation / silent-on-fix fixture pair, plus
CLI contract tests (rule selection, pragma allowlisting, JSON schema,
exit codes) and a repo-wide sweep asserting the tree stays clean.
The final section pins the two determinism bugs the linter's first
sweep found in the shipped transports.
"""
import json
import os
import textwrap

import pytest

from repro.devtools.replint import lint_file, lint_paths, rule_names
from repro.devtools.replint.__main__ import main

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _lint(tmp_path, rel, source, select=None, design=None):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return lint_file(str(p), select=select, design=design)


def _rules(findings):
    return [f.rule for f in findings]


# --------------------------------------------------------------------------
# determinism


def test_determinism_flags_wallclock_and_global_rng(tmp_path):
    fs = _lint(tmp_path, "repro/net/mod.py", """\
        import time
        import random
        import numpy as np

        def f():
            t = time.time()
            x = random.random()
            v = np.random.rand(3)
            k = id(t)
            return t, x, v, k
        """, select=["determinism"])
    assert _rules(fs) == ["determinism"] * 4
    msgs = " | ".join(f.message for f in fs)
    assert "wall-clock" in msgs and "random.random" in msgs
    assert "np.random.rand" in msgs and "id()" in msgs


def test_determinism_unseeded_default_rng(tmp_path):
    fs = _lint(tmp_path, "repro/runtime/mod.py", """\
        from numpy.random import default_rng

        bad = default_rng()
        good = default_rng(42)
        """, select=["determinism"])
    assert len(fs) == 1 and "unseeded" in fs[0].message
    assert fs[0].line == 3


def test_determinism_set_iteration(tmp_path):
    fs = _lint(tmp_path, "repro/net/mod.py", """\
        def f(xs):
            s = set(xs)
            for x in s:
                print(x)
            return [y for y in {1, 2, 3}]
        """, select=["determinism"])
    assert _rules(fs) == ["determinism"] * 2


def test_determinism_sorted_set_iteration_is_clean(tmp_path):
    fs = _lint(tmp_path, "repro/net/mod.py", """\
        def f(xs):
            s = set(xs)
            lo = min(x for x in s)
            return sorted(y for y in s), lo
        """, select=["determinism"])
    assert fs == []


def test_determinism_inherited_set_attr(tmp_path):
    fs = _lint(tmp_path, "repro/runtime/mod.py", """\
        class Base:
            def __init__(self):
                self.alive = set()

        class Sub(Base):
            def drain(self):
                for w in self.alive:
                    print(w)
        """, select=["determinism"])
    assert len(fs) == 1 and "self.alive" in fs[0].message


def test_determinism_scoped_to_net_and_runtime(tmp_path):
    fs = _lint(tmp_path, "repro/bench/mod.py", """\
        import time
        t = time.time()
        """, select=["determinism"])
    assert fs == []


# --------------------------------------------------------------------------
# pool-reset


def test_pool_reset_flags_leaked_state(tmp_path):
    fs = _lint(tmp_path, "mod.py", """\
        class Flow:
            def __init__(self, sim):
                self.sim = sim        # wiring: from a param, not flagged
                self.buf = []
                self.seen = set()

            def reset(self, gen=None):
                self.seen = set()
        """, select=["pool-reset"])
    assert len(fs) == 1
    assert "self.buf" in fs[0].message and "Flow" in fs[0].message


def test_pool_reset_mutator_and_helper_coverage(tmp_path):
    fs = _lint(tmp_path, "mod.py", """\
        class Flow:
            def __init__(self):
                self.buf = []
                self.count = 0

            def reset(self, gen=None):
                self.buf.clear()
                self._rearm()

            def _rearm(self):
                self.count = 0
        """, select=["pool-reset"])
    assert fs == []


def test_pool_reset_ignores_classes_without_protocol(tmp_path):
    fs = _lint(tmp_path, "mod.py", """\
        class NotPooled:
            def __init__(self):
                self.buf = []
        """, select=["pool-reset"])
    assert fs == []


# --------------------------------------------------------------------------
# gen-fence


def test_gen_fence_flags_raw_g_key(tmp_path):
    fs = _lint(tmp_path, "repro/net/mod.py", """\
        def stale(meta, gen):
            return meta["g"] != gen

        def mark(meta, gen):
            meta = {"g": gen}
            return meta
        """, select=["gen-fence"])
    assert _rules(fs) == ["gen-fence"] * 2
    assert all("genfence" in f.message for f in fs)


def test_gen_fence_ignores_fstring_format_specs(tmp_path):
    fs = _lint(tmp_path, "repro/net/mod.py", """\
        def label(x):
            return f"os{x:g}"
        """, select=["gen-fence"])
    assert fs == []


def test_gen_fence_exempts_the_helper_module_itself(tmp_path):
    fs = _lint(tmp_path, "repro/net/genfence.py", """\
        GEN_KEY = "g"
        """, select=["gen-fence"])
    assert fs == []


def test_gen_fence_unguarded_sim_callback(tmp_path):
    fs = _lint(tmp_path, "repro/runtime/mod.py", """\
        class R:
            def arm(self, t):
                def cb():
                    self.count += 1
                    self.apply()
                self.sim.at(t, cb)
        """, select=["gen-fence"])
    assert len(fs) == 1 and "'cb'" in fs[0].message


def test_gen_fence_guarded_and_delegating_callbacks_pass(tmp_path):
    fs = _lint(tmp_path, "repro/runtime/mod.py", """\
        class R:
            def arm(self, t):
                def cb():
                    if self.closed:
                        return
                    self.apply()
                self.sim.at(t, cb)
                self.sim.after(t, lambda: self.tick())

            def launch(self, worker, it):
                def done():
                    if self._flight.pop((worker, it), None) is None:
                        return
                    self.apply()
                self.sim.after(1.0, done)
        """, select=["gen-fence"])
    assert fs == []


# --------------------------------------------------------------------------
# hotpath


def test_hotpath_flags_allocations_in_marked_function(tmp_path):
    fs = _lint(tmp_path, "mod.py", """\
        # replint: hotpath
        def hot(xs):
            ys = [x + 1 for x in xs]
            cb = lambda: None
            return f"{ys}", cb
        """, select=["hotpath"])
    assert _rules(fs) == ["hotpath"] * 3
    msgs = " | ".join(f.message for f in fs)
    assert "comprehension" in msgs and "lambda" in msgs and "f-string" in msgs


def test_hotpath_unmarked_functions_are_ignored(tmp_path):
    fs = _lint(tmp_path, "mod.py", """\
        def cold(xs):
            return [x + 1 for x in xs]
        """, select=["hotpath"])
    assert fs == []


def test_hotpath_tracker_arm_is_exempt(tmp_path):
    fs = _lint(tmp_path, "mod.py", """\
        # replint: hotpath
        def hot(self, v):
            self.total += v
            if self._h_observe is not None:
                self._h_observe(f"v={v}")
            else:
                bad = [v for _ in range(2)]
        """, select=["hotpath"])
    # the else-arm still counts: only the tracker arm itself is exempt
    assert len(fs) == 1 and "comprehension" in fs[0].message


# --------------------------------------------------------------------------
# frozen-config


def test_frozen_config_flags_unhashable_fields(tmp_path):
    fs = _lint(tmp_path, "repro/config.py", """\
        import dataclasses
        from typing import List, Tuple

        @dataclasses.dataclass(frozen=True)
        class Cfg:
            racks: List[int]
            sizes: "List[float]"
            shape: Tuple[int, ...] = ()
        """, select=["frozen-config"])
    assert _rules(fs) == ["frozen-config"] * 2
    assert {"racks", "sizes"} == {f.message.split("Cfg.")[1].split()[0]
                                  for f in fs}


def test_frozen_config_only_applies_to_config_py(tmp_path):
    src = """\
        import dataclasses
        from typing import List

        @dataclasses.dataclass(frozen=True)
        class Cfg:
            racks: List[int]
        """
    assert _lint(tmp_path, "repro/other.py", src,
                 select=["frozen-config"]) == []


def test_frozen_config_ignores_unfrozen_dataclasses(tmp_path):
    fs = _lint(tmp_path, "repro/config.py", """\
        import dataclasses
        from typing import List

        @dataclasses.dataclass
        class Mutable:
            racks: List[int]
        """, select=["frozen-config"])
    assert fs == []


# --------------------------------------------------------------------------
# design-ref


def test_design_ref_resolution(tmp_path):
    (tmp_path / "DESIGN.md").write_text("# Design\n\n## §3 Close rule\n")
    fs = _lint(tmp_path, "repro/mod.py", """\
        # the close rule (DESIGN.md §3) applies here
        # but this one is stale: DESIGN.md §99
        """, select=["design-ref"])
    assert len(fs) == 1 and "§99" in fs[0].message


def test_design_ref_explicit_design_path(tmp_path):
    d = tmp_path / "docs.md"
    d.write_text("## §7 Trains\n")
    fs = _lint(tmp_path, "deep/mod.py", "# see DESIGN.md §7 and DESIGN.md §8\n",
               select=["design-ref"], design=str(d))
    assert len(fs) == 1 and "§8" in fs[0].message


def test_design_ref_silent_without_a_design_file(tmp_path):
    fs = _lint(tmp_path, "repro/mod.py", "# cites DESIGN.md §42\n",
               select=["design-ref"])
    assert fs == []


# --------------------------------------------------------------------------
# pragmas and pseudo-rules


def test_pragma_suppresses_trailing_and_own_line(tmp_path):
    fs = _lint(tmp_path, "repro/net/mod.py", """\
        import time

        def f():
            a = time.time()  # replint: ok(determinism)
            # replint: ok(determinism)
            b = time.time()
            c = time.time()
            return a, b, c
        """, select=["determinism"])
    assert len(fs) == 1 and fs[0].line == 7


def test_pragma_hygiene_unknown_rule_and_malformed(tmp_path):
    fs = _lint(tmp_path, "mod.py", """\
        x = 1  # replint: ok(no-such-rule)
        y = 2  # replint: wibble
        z = 3  # replint: ok()
        """)
    assert _rules(fs) == ["pragma"] * 3
    msgs = " | ".join(f.message for f in fs)
    assert "unknown rule" in msgs and "unrecognized pragma" in msgs \
        and "names no rule" in msgs


def test_pragma_unused_reported_only_on_full_runs(tmp_path):
    src = """\
        x = 1  # replint: ok(determinism)
        """
    full = _lint(tmp_path, "a/mod.py", src)
    assert _rules(full) == ["pragma"] and "unused" in full[0].message
    partial = _lint(tmp_path, "b/mod.py", src, select=["pool-reset"])
    assert partial == []


def test_parse_error_is_a_finding_not_a_crash(tmp_path):
    fs = _lint(tmp_path, "mod.py", "def broken(:\n")
    assert _rules(fs) == ["parse"] and "syntax error" in fs[0].message


# --------------------------------------------------------------------------
# CLI contract


@pytest.fixture
def bad_tree(tmp_path):
    p = tmp_path / "repro" / "net" / "mod.py"
    p.parent.mkdir(parents=True)
    p.write_text("import time\nt = time.time()\n")
    return tmp_path


def test_cli_exit_codes(bad_tree, tmp_path, capsys):
    clean = tmp_path / "clean"
    clean.mkdir()
    (clean / "ok.py").write_text("x = 1\n")
    assert main([str(clean)]) == 0
    assert main([str(bad_tree)]) == 1
    assert main([]) == 2
    assert main(["--select", "no-such-rule", str(clean)]) == 2
    out = capsys.readouterr()
    assert "replint: clean" in out.out
    assert "no paths given" in out.err and "unknown rule(s)" in out.err


def test_cli_rule_selection(bad_tree, capsys):
    assert main(["--select", "pool-reset", str(bad_tree)]) == 0
    assert main(["--select", "determinism", str(bad_tree)]) == 1
    out = capsys.readouterr().out
    assert "[determinism]" in out and "determinism: 1" in out


def test_cli_json_schema(bad_tree, capsys):
    assert main(["--json", str(bad_tree)]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert set(doc) == {"findings", "counts", "files_scanned"}
    assert doc["files_scanned"] == 1
    assert doc["counts"] == {"determinism": 1}
    (f,) = doc["findings"]
    assert set(f) == {"rule", "path", "line", "col", "message"}
    assert f["rule"] == "determinism" and f["line"] == 2


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for name in ("determinism", "pool-reset", "gen-fence", "hotpath",
                 "frozen-config", "design-ref"):
        assert name in out


def test_rule_registry_is_complete():
    assert rule_names() == ["determinism", "pool-reset", "gen-fence",
                            "hotpath", "frozen-config", "design-ref"]


# --------------------------------------------------------------------------
# the tree itself stays clean


def test_repo_sweep_is_clean():
    findings, n_files = lint_paths([REPO_SRC])
    assert findings == [], "\n".join(f.render() for f in findings)
    assert n_files > 50


# --------------------------------------------------------------------------
# regressions pinned by the linter's first sweep (real determinism bugs)


def test_tcp_prune_inflight_fills_retx_in_seq_order():
    """_prune_inflight used to iterate the inflight *set* directly, so the
    retransmit queue refilled in hash order — same-seed replays could
    schedule retransmissions differently across set histories."""
    from repro.net.senders import RenoSender
    from repro.net.simcore import Pipe, Sim

    sim = Sim()
    pipe = Pipe(sim, rate_bps=1e9, delay=0.001)
    snd = RenoSender(sim, pipe, deliver=lambda p: None, n_packets=100)
    seqs = [37, 5, 91, 12, 60, 3]
    snd.inflight = set(seqs)
    for s in seqs:
        snd.sent_time[s] = -1e9       # far older than any RTO cutoff
    snd.retx.clear()
    snd._prune_inflight()
    assert list(snd.retx) == sorted(seqs)
    assert snd.inflight == set()


def test_ps_gather_stop_resends_in_flow_order():
    """The post-close stop-resend loop used to iterate a set of flow ids;
    stop packets now go out in sorted flow order so the event sequence
    is identical across replays."""
    from repro.net.ltp_receiver import PSGatherReceiver
    from repro.net.simcore import Packet, Sim

    sim = Sim()
    stops = []
    rx = PSGatherReceiver(sim, flows=[3, 1, 2], lt_threshold=1.0,
                          deadline=2.0, pct_threshold=0.8,
                          send_stop=stops.append)
    rx.closed = True
    items = [(Packet(f, 0, 100, kind="data"), 0.0) for f in (3, 1, 3, 2)]
    rx.on_data_train(items)
    assert stops == [1, 2, 3]
    assert rx.n_stop_resends == 3
