"""Falcon-Mamba-7B — attention-free Mamba-1 SSM [arXiv:2410.05355]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=65024,
    block_pattern=("M",),   # mamba1 mixer, no attention anywhere
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    pos_type="none",
    source="arXiv:2410.05355",
)

REDUCED = CONFIG.replace(
    name="falcon-mamba-7b-reduced",
    n_layers=2,
    d_model=256,
    vocab=512,
    ssm_state=8,
)
