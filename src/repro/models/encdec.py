"""Encoder-decoder transformer (whisper backbone, arXiv:2212.04356).

The mel+conv frontend is a STUB per the assignment: inputs carry
precomputed frame embeddings (B, encoder_frames, d_model). Positions are
sinusoidal (whisper's encoder is sinusoidal; we use sinusoids on the
decoder too so position tables never bound the decode length — the
assigned decode_32k far exceeds whisper's deployed 448-token window,
a shape-fidelity caveat noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    Params,
    apply_mlp,
    apply_norm,
    cross_entropy,
    embed_params,
    embed_tokens,
    mlp_params,
    norm_params,
    split_keys,
    unembed,
)
from repro.models.sharding import ShardCtx, NULL_CTX


def sinusoid(positions, d: int, dtype):
    """positions: (...,) -> (..., d) sinusoidal embedding."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _enc_layer_params(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": norm_params(cfg, cfg.d_model),
        "attn": attn.attn_params(k1, cfg, dtype),
        "norm2": norm_params(cfg, cfg.d_model),
        "mlp": mlp_params(k2, cfg, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_params(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = split_keys(key, 3)
    return {
        "norm1": norm_params(cfg, cfg.d_model),
        "self_attn": attn.attn_params(k1, cfg, dtype),
        "norm_x": norm_params(cfg, cfg.d_model),
        "cross_attn": attn.cross_attn_params(k2, cfg, dtype),
        "norm2": norm_params(cfg, cfg.d_model),
        "mlp": mlp_params(k3, cfg, cfg.d_model, cfg.d_ff, dtype),
    }


def init(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ke, kenc, kdec = split_keys(key, 3)
    enc = [
        _enc_layer_params(k, cfg, dtype)
        for k in split_keys(kenc, cfg.encoder_layers)
    ]
    dec = [
        _dec_layer_params(k, cfg, dtype) for k in split_keys(kdec, cfg.n_layers)
    ]
    return {
        "embed": embed_params(ke, cfg, dtype),
        "enc_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": norm_params(cfg, cfg.d_model),
        "final_norm": norm_params(cfg, cfg.d_model),
    }


def encode(cfg: ModelConfig, params: Params, frames, *, ctx: ShardCtx = NULL_CTX,
           remat: bool = True):
    """frames: (B, F, d) stubbed frontend output -> (B, F, d)."""
    b, f, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + sinusoid(
        jnp.arange(f), cfg.d_model, jnp.dtype(cfg.dtype)
    )
    x = ctx.batch_seq_hidden(x)
    positions = jnp.broadcast_to(jnp.arange(f), (b, f))

    def body(x, p):
        h = apply_norm(cfg, p["norm1"], x)
        x = x + attn.self_attention(cfg, p["attn"], h, positions, causal=False, ctx=ctx)
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        return ctx.batch_seq_hidden(x), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_stack"])
    return apply_norm(cfg, params["enc_norm"], x)


def _cross_kv(cfg: ModelConfig, p: Params, enc_out):
    b, f, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(b, f, cfg.n_kv, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(b, f, cfg.n_kv, cfg.hd)
    return k, v


def decode_train(cfg: ModelConfig, params: Params, tokens, enc_out, *,
                 ctx: ShardCtx = NULL_CTX, remat: bool = True, last_only=False):
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = x + sinusoid(jnp.arange(s), cfg.d_model, x.dtype)
    x = ctx.batch_seq_hidden(x)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, p):
        h = apply_norm(cfg, p["norm1"], x)
        x = x + attn.self_attention(cfg, p["self_attn"], h, positions, ctx=ctx)
        h = apply_norm(cfg, p["norm_x"], x)
        kv = _cross_kv(cfg, p["cross_attn"], enc_out)
        x = x + attn.cross_attention(cfg, p["cross_attn"], h, kv)
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        return ctx.batch_seq_hidden(x), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_stack"])
    x = apply_norm(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    return unembed(params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, Any], *,
            ctx: ShardCtx = NULL_CTX, remat: bool = True):
    enc_out = encode(cfg, params, batch["frames"], ctx=ctx, remat=remat)
    logits = decode_train(cfg, params, batch["tokens"], enc_out, ctx=ctx, remat=remat)
    return cross_entropy(logits, batch["labels"], cfg.vocab)


# ----------------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Self KV per decoder layer + precomputed cross KV per layer."""
    nl = cfg.n_layers
    self_shp = (nl, batch, max_seq, cfg.n_kv, cfg.hd)
    cross_shp = (nl, batch, cfg.encoder_frames, cfg.n_kv, cfg.hd)
    return {
        "self_k": jnp.zeros(self_shp, dtype),
        "self_v": jnp.zeros(self_shp, dtype),
        "cross_k": jnp.zeros(cross_shp, dtype),
        "cross_v": jnp.zeros(cross_shp, dtype),
    }


def prefill(cfg: ModelConfig, params: Params, inputs, *, ctx: ShardCtx = NULL_CTX):
    """Runs the encoder and fills cross-KV; returns (first logits, cache)."""
    frames, tokens = inputs["frames"], inputs["tokens"]
    enc_out = encode(cfg, params, frames, ctx=ctx, remat=False)
    logits = decode_train(cfg, params, tokens, enc_out, ctx=ctx, remat=False,
                          last_only=True)
    b, s = tokens.shape
    cache = init_cache(cfg, b, s, jnp.dtype(cfg.dtype))

    def fill(i, c):
        p = jax.tree.map(lambda x: x[i], params["dec_stack"])
        k, v = _cross_kv(cfg, p["cross_attn"], enc_out)
        c["cross_k"] = c["cross_k"].at[i].set(k.astype(c["cross_k"].dtype))
        c["cross_v"] = c["cross_v"].at[i].set(v.astype(c["cross_v"].dtype))
        return c

    for i in range(cfg.n_layers):
        cache = fill(i, cache)
    return logits[:, 0], cache


def decode_step(cfg: ModelConfig, params: Params, cache, token, pos, *,
                ctx: ShardCtx = NULL_CTX):
    """One decoder token. token: (B,). Returns (logits, new_cache)."""
    b = token.shape[0]
    x = embed_tokens(params["embed"], token[:, None]).astype(jnp.dtype(cfg.dtype))
    x = x + sinusoid(jnp.full((1,), pos), cfg.d_model, x.dtype)
    x = ctx.batch_only(x)
    nk, nv = [], []
    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda t: t[i], params["dec_stack"])
        h = apply_norm(cfg, p["norm1"], x)
        out, k_i, v_i = attn.self_attention_decode(
            cfg, p["self_attn"], h, cache["self_k"][i], cache["self_v"][i], pos
        )
        nk.append(k_i)
        nv.append(v_i)
        x = x + out
        h = apply_norm(cfg, p["norm_x"], x)
        x = x + attn.cross_attention(
            cfg, p["cross_attn"], h, (cache["cross_k"][i], cache["cross_v"][i])
        )
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x)[:, 0]
    new_cache = dict(cache)
    new_cache["self_k"] = jnp.stack(nk)
    new_cache["self_v"] = jnp.stack(nv)
    return logits, new_cache
