"""DeepSeek-V2-236B — MLA (kv_lora=512) + MoE: 2 shared + 160 routed, top-6
[arXiv:2405.04434].

The assigned ``d_ff=1536`` is the per-routed-expert intermediate size; the
first layer is a dense FFN (intermediate 12288) per the DeepSeek-V2 design.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv=128,                # MLA: logical kv == heads; real cache is kv_lora
    d_ff=12288,              # dense FFN (first layer)
    moe_d_ff=1536,           # per-expert intermediate
    vocab=102400,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    block_pattern=("L",),    # MLA attention
    kv_lora=512,
    q_lora=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=1e4,
    source="arXiv:2405.04434",
)

REDUCED = CONFIG.replace(
    name="deepseek-v2-236b-reduced",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv=4,
    d_ff=512,
    moe_d_ff=128,
    vocab=512,
    n_experts=4,
    n_shared_experts=1,
    top_k=2,
    first_dense_layers=1,
    kv_lora=64,
    q_lora=128,
    qk_nope_dim=32,
    qk_rope_dim=16,
    v_head_dim=32,
)
