"""Protocol-level demo: watch Early Close cut the incast tail, then
watch in-network aggregation cut the rack-uplink bytes.

Part 1 runs the packet-level DES for an 8-to-1 gather with stragglers,
for LTP and cubic, and prints per-iteration close decisions. Part 2
builds a rack/spine fabric with the topology API (DESIGN.md §11) and
compares the same gather with ToR aggregation on and off.

  PYTHONPATH=src python examples/netsim_demo.py [--loss 0.005]
"""
import argparse

import numpy as np

from repro.config import NetConfig
from repro.net.scenarios import incast_gather, topology_gather
from repro.net.topology import rack_spine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--loss", type=float, default=0.005)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--size-mb", type=float, default=2.0)
    args = ap.parse_args()

    net = NetConfig(10, 1, args.loss, 4096)
    size = args.size_mb * 1e6
    for proto in ["ltp", "bbr", "cubic"]:
        rs = incast_gather(proto, net, 8, size, iters=args.iters, seed=1,
                           straggler_prob=0.3, straggler_scale=1.0)
        bst = np.array([r.bst_gather for r in rs]) * 1e3
        dl = np.array([r.delivered.mean() for r in rs])
        print(f"\n{proto}: BST per iteration (ms):")
        print("  " + " ".join(f"{b:7.1f}" for b in bst))
        print(f"  delivered: " + " ".join(f"{d:7.2f}" for d in dl))
        print(f"  mean {bst.mean():.1f}ms  p95 {np.percentile(bst,95):.1f}ms")

    # part 2: the same gather on a 4x16 rack/spine fabric with 8:1
    # oversubscribed ToR uplinks — in-network aggregation merges each
    # rack's packets into one wire flow per shard at the ToR
    print("\nrack/spine 4x16, oversub 8:1 (LTP):")
    for agg in (False, True):
        topo = rack_spine(4, 16, oversub=8.0, agg=agg)
        rs = topology_gather("ltp", net, topo.n_workers, size,
                             topology=topo, iters=max(2, args.iters // 2),
                             seed=1, coalesce=16)
        bst = np.array([r.bst_gather for r in rs]) * 1e3
        label = "ToR aggregation" if agg else "no aggregation "
        extra = ""
        if agg and rs[-1].agg_stats:
            extra = (f"  ({rs[-1].agg_stats['n_merged']} packets merged "
                     f"into {rs[-1].agg_stats['n_envelopes']} envelopes)")
        print(f"  {label}: BST mean {bst.mean():7.1f}ms "
              f"p95 {np.percentile(bst, 95):7.1f}ms{extra}")


if __name__ == "__main__":
    main()
