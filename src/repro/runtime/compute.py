"""Per-worker compute-time models (DESIGN.md §8).

The cluster runtime couples these with the network DES on one shared
``Sim`` clock: a worker's iteration is compute (sampled here) followed
by its transport leg. Three models cover the paper's evaluation axes:

  deterministic  fixed per-worker times (optionally heterogeneous) —
                 the legacy ``compute_time`` scalar is the uniform case.
  lognormal      unit-mean lognormal jitter x occasional straggler
                 multiplier — the long-tail host stragglers (GC pauses,
                 CPU contention) behind the paper's Fig-3 starved flows.
  trace          replay measured per-(iteration, worker) times.

Samples are deterministic in (seed, worker, iteration) — independent of
event-loop interleaving — so a run reproduces exactly across policies.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Union

import numpy as np


class ComputeModel:
    """Interface: seconds of gradient-computation time per (worker,
    iteration)."""

    #: extra seconds charged to the first iteration after a worker
    #: (re)joins mid-run — cold caches, params re-fetch, JIT re-warm.
    #: The fault layer (runtime/faults.py) reads this; 0 = free rejoin.
    rejoin_penalty_s: float = 0.0

    def sample(self, worker: int, iteration: int) -> float:
        raise NotImplementedError


#: name -> class; ``make_compute_model`` dispatches through this table.
COMPUTE_MODELS: Dict[str, type] = {}


def register_compute(name: str):
    def deco(cls):
        COMPUTE_MODELS[name] = cls
        cls.name = name
        return cls
    return deco


@register_compute("deterministic")
class DeterministicCompute(ComputeModel):
    """Fixed times: ``base`` seconds, optionally scaled per worker by
    ``mults`` — heterogeneous-but-stable hardware."""

    def __init__(self, n_workers: int, base: float = 0.05,
                 mults: Optional[np.ndarray] = None, seed: int = 0,
                 rejoin_penalty_s: float = 0.0):
        self.base = float(base)
        self.rejoin_penalty_s = float(rejoin_penalty_s)
        self.mults = (np.ones(n_workers) if mults is None
                      else np.asarray(mults, float))
        if len(self.mults) != n_workers:
            raise ValueError(
                f"mults has {len(self.mults)} entries for {n_workers} workers")

    def sample(self, worker: int, iteration: int) -> float:
        return self.base * float(self.mults[worker])


@register_compute("lognormal")
class LognormalStragglerCompute(ComputeModel):
    """base * LogNormal(-sigma^2/2, sigma) jitter (unit mean), with
    probability ``straggler_prob`` additionally multiplied by
    ``straggler_mult`` — the occasional worker that falls off a cliff.
    Each (worker, iteration) draw is seeded independently, so samples do
    not depend on the order the event loop asks for them."""

    def __init__(self, n_workers: int, base: float = 0.05,
                 sigma: float = 0.2, straggler_prob: float = 0.1,
                 straggler_mult: float = 4.0, seed: int = 0,
                 rejoin_penalty_s: float = 0.0):
        self.base = float(base)
        self.rejoin_penalty_s = float(rejoin_penalty_s)
        self.sigma = float(sigma)
        self.straggler_prob = float(straggler_prob)
        self.straggler_mult = float(straggler_mult)
        self.seed = int(seed)

    def sample(self, worker: int, iteration: int) -> float:
        rng = np.random.default_rng((self.seed, worker, iteration))
        t = self.base * math.exp(
            rng.normal(-0.5 * self.sigma ** 2, self.sigma))
        if rng.random() < self.straggler_prob:
            t *= self.straggler_mult
        return t


@register_compute("trace")
class TraceCompute(ComputeModel):
    """Replay a measured (iters, W) compute-time trace, tiled over
    iterations. A 1-D trace broadcasts the same per-iteration time to
    every worker."""

    def __init__(self, n_workers: int, trace: np.ndarray, base: float = 1.0,
                 seed: int = 0, rejoin_penalty_s: float = 0.0):
        self.rejoin_penalty_s = float(rejoin_penalty_s)
        t = np.asarray(trace, float)
        if t.ndim == 1:
            t = np.tile(t[:, None], (1, n_workers))
        if t.ndim != 2 or t.shape[1] != n_workers:
            raise ValueError(
                f"trace shape {t.shape} incompatible with {n_workers} workers")
        if not len(t):
            raise ValueError("empty compute trace")
        self.trace = t * float(base)

    def sample(self, worker: int, iteration: int) -> float:
        return float(self.trace[iteration % len(self.trace), worker])


def make_compute_model(spec: Union[None, str, ComputeModel], n_workers: int,
                       base: float = 0.05, seed: int = 0,
                       **kw) -> ComputeModel:
    """Resolve a compute model from an instance, a registered name, or
    None (-> deterministic at ``base`` — the legacy scalar)."""
    if isinstance(spec, ComputeModel):
        return spec
    if spec is None:
        return DeterministicCompute(n_workers, base=base)
    try:
        cls = COMPUTE_MODELS[spec]
    except KeyError:
        raise ValueError(
            f"unknown compute model {spec!r}; registered: "
            f"{sorted(COMPUTE_MODELS)} (or pass a ComputeModel "
            f"instance)") from None
    return cls(n_workers, base=base, seed=seed, **kw)
