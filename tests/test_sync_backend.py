"""Kernel-backed sync path: backend="pallas" (fused dropfill/packet_reduce
via the ops.py padding wrappers) vs backend="python" (jnp reference) —
agreement to float tolerance on real papernet gradients under lossy masks,
all compensation modes, non-lane-aligned payloads (DESIGN.md §7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.core import ltp_sync as ls
from repro.core import make_ltp_sync
from repro.core import packets as pk
from repro.models import build


@pytest.fixture(scope="module")
def papernet_grads():
    """Per-worker papernet gradients, packetized with a NON-lane-aligned
    payload (360 % 128 != 0 — exercises the ops.py padding)."""
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    w = 4
    imgs = jax.random.normal(k, (w, 8, 32, 32, 3))
    labels = jax.random.randint(k, (w, 8), 0, 10)

    def one(img, lab):
        return jax.grad(
            lambda p: api.loss_fn(p, {"images": img, "labels": lab}))(params)

    grads_w = jax.vmap(one)(imgs, labels)
    plan = pk.make_plan(params, packet_floats=360)
    flat_w = jax.vmap(lambda g: pk.flatten(plan, g))(grads_w)   # (W, n, 360)
    return plan, flat_w, w


@pytest.mark.parametrize("comp", ["paper", "count", "expected"])
def test_reduce_packet_stream_backends_agree(papernet_grads, comp):
    plan, flat_w, w = papernet_grads
    rng = np.random.default_rng(3)
    masks = (rng.random((w, plan.n_packets)) < 0.6).astype(np.float32)
    masks[:, plan.critical] = 1.0
    ltp = LTPConfig(compensation=comp)
    frac = jnp.full((w,), 0.6)
    ref = ls.reduce_packet_stream(jnp.asarray(flat_w), jnp.asarray(masks),
                                  ltp, w, expected_frac=frac,
                                  backend="python")
    ker = ls.reduce_packet_stream(jnp.asarray(flat_w), jnp.asarray(masks),
                                  ltp, w, expected_frac=frac,
                                  backend="pallas")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("payload", [7, 100, 360, 384])
def test_apply_delivery_backends_agree_any_geometry(payload):
    """Padding wrappers: arbitrary (n_packets, payload), lane-aligned or
    not, must round-trip exactly through the kernel tiles."""
    rng = np.random.default_rng(0)
    n = 77
    pkts = jnp.asarray(rng.normal(size=(n, payload)).astype(np.float32))
    mask = jnp.asarray((rng.random(n) < 0.5).astype(np.float32))
    scale = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    ref = ls.apply_delivery(pkts, mask, scale, backend="python")
    ker = ls.apply_delivery(pkts, mask, scale, backend="pallas")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("comp", ["paper", "count"])
def test_ltp_sync_shard_map_backends_agree(comp):
    """The shard_map-wrapped LTPSync path (bubble-fill + compensation gates
    through dropfill under "pallas") matches the reference."""
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    grads = {"w": jnp.arange(512, dtype=jnp.float32).reshape(32, 16) / 100,
             "b": jnp.linspace(-1, 1, 24)}
    specs = {"w": P(), "b": P()}
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          grads)
    outs = {}
    for be in ("python", "pallas"):
        ltp = LTPConfig(packet_floats=8, compensation=comp, sync_backend=be)
        sync = make_ltp_sync(shapes, mesh, ltp, specs)
        out, _, stats = sync(grads, jnp.full((1,), 0.5),
                             jax.random.PRNGKey(0))
        outs[be] = out
        assert 0.0 < float(stats["delivered_frac"]) <= 1.0
    for k in grads:
        np.testing.assert_allclose(np.asarray(outs["python"][k]),
                                   np.asarray(outs["pallas"][k]),
                                   rtol=1e-5, atol=1e-7)


def test_pstrainer_backends_agree_end_to_end():
    """Full PSTrainer steps on papernet: identical parameter trajectories
    under lossy masks for both backends (count compensation, residual
    error feedback exercises the dropfill path too)."""
    from repro.data.synthetic import SyntheticCIFAR, batches
    from repro.optim import sgd_momentum
    from repro.train.dp_sim import PSTrainer

    cfg = get_config("papernet").replace(d_model=8, n_layers=2)
    api = build(cfg)
    tc = TrainConfig(batch=32, lr=0.1, steps=3)
    data = SyntheticCIFAR(seed=1)
    params = {}
    for be in ("python", "pallas"):
        ltp = LTPConfig(sync_backend=be, compensation="count",
                        error_feedback=True, data_pct_threshold=0.6)
        tr = PSTrainer(api, sgd_momentum(), tc, ltp,
                       NetConfig(10, 1, 0.01, 4096), n_workers=4,
                       protocol="ltp", compute_time=0.01, seed=0)
        hist = tr.run(batches(data, tc.batch, tc.steps))
        assert all(0.0 < h["delivered"] <= 1.0 for h in hist)
        params[be] = tr.params
    for a, b in zip(jax.tree.leaves(params["python"]),
                    jax.tree.leaves(params["pallas"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_mask_trace_feeds_sync():
    """DES delivery masks (net/scenarios) drive the fused reduction: the
    realized delivered fraction reported by the trainer equals the trace's
    mean (with criticals pinned)."""
    from repro.data.synthetic import SyntheticCIFAR, batches
    from repro.net.scenarios import train_iterations
    from repro.optim import sgd_momentum
    from repro.train.dp_sim import PSTrainer

    cfg = get_config("papernet").replace(d_model=8, n_layers=2)
    api = build(cfg)
    tc = TrainConfig(batch=32, lr=0.05, steps=2)
    net = NetConfig(10, 1, 0.002, 4096)
    ltp = LTPConfig(data_pct_threshold=0.6)
    out = train_iterations("ltp", net, 4, 3e5, iters=2, seed=7, ltp=ltp,
                           straggler_prob=0.5, straggler_scale=1.0,
                           coalesce=8)
    mt = out["delivery_masks"]
    assert mt is not None and mt.shape[:2] == (2, 4)
    tr = PSTrainer(api, sgd_momentum(), tc, ltp, net, n_workers=4,
                   protocol="ltp", compute_time=0.01, seed=0,
                   bst_trace=out["bst"], mask_trace=mt)
    hist = tr.run(batches(SyntheticCIFAR(seed=1), tc.batch, tc.steps))
    for h in hist:
        assert 0.0 < h["delivered"] <= 1.0


# ---------------------------------------------------------------------------
# sync_backend="auto" (DESIGN.md §9): never a regression, always valid
# ---------------------------------------------------------------------------


def test_resolve_backend_rules():
    """auto -> python in interpret mode and below the crossover; pallas
    only for compiled kernels on large streams. Explicit backends pass
    through untouched."""
    assert ls.resolve_backend("python", 10**9, False) == "python"
    assert ls.resolve_backend("pallas", 1, True) == "pallas"
    assert ls.resolve_backend("auto", 10**12, True) == "python"
    assert ls.resolve_backend("auto", ls.AUTO_CROSSOVER_ELEMS - 1,
                              False) == "python"
    assert ls.resolve_backend("auto", ls.AUTO_CROSSOVER_ELEMS,
                              False) == "pallas"


@pytest.mark.parametrize("comp", ["paper", "count", "expected"])
def test_reduce_packet_stream_auto_matches_python(papernet_grads, comp):
    """In interpret mode auto IS the python backend — bitwise."""
    plan, flat_w, w = papernet_grads
    rng = np.random.default_rng(9)
    masks = (rng.random((w, plan.n_packets)) < 0.7).astype(np.float32)
    ltp = LTPConfig(compensation=comp, sync_backend="auto")
    got = ls.reduce_packet_stream(jnp.asarray(flat_w), jnp.asarray(masks),
                                  ltp, w, expected_frac=0.7)
    ref = ls.reduce_packet_stream(jnp.asarray(flat_w), jnp.asarray(masks),
                                  ltp, w, expected_frac=0.7,
                                  backend="python")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_apply_delivery_auto_matches_python():
    rng = np.random.default_rng(4)
    pkts = jnp.asarray(rng.normal(size=(37, 250)).astype(np.float32))
    mask = jnp.asarray((rng.random(37) < 0.5).astype(np.float32))
    auto = ls.apply_delivery(pkts, mask, backend="auto")
    ref = ls.apply_delivery(pkts, mask, backend="python")
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))


def test_pstrainer_auto_backend_end_to_end():
    """PSTrainer under sync_backend='auto' matches the python trajectory
    exactly on CPU (interpret mode resolves auto -> python)."""
    from repro.data.synthetic import SyntheticCIFAR, batches
    from repro.optim import sgd_momentum
    from repro.train.dp_sim import PSTrainer

    cfg = get_config("papernet").replace(d_model=8, n_layers=2)
    api = build(cfg)
    tc = TrainConfig(batch=32, lr=0.1, steps=2)
    data = SyntheticCIFAR(seed=1)
    params = {}
    for be in ("python", "auto"):
        ltp = LTPConfig(sync_backend=be, compensation="count",
                        data_pct_threshold=0.6)
        tr = PSTrainer(api, sgd_momentum(), tc, ltp,
                       NetConfig(10, 1, 0.01, 4096), n_workers=4,
                       protocol="ltp", compute_time=0.01, seed=0)
        tr.run(batches(data, tc.batch, tc.steps))
        params[be] = tr.params
    for a, b in zip(jax.tree.leaves(params["python"]),
                    jax.tree.leaves(params["auto"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
