"""Pallas TPU kernel: PS-side masked multi-worker packet reduction.

Aggregates W workers' packetized gradients with per-(worker, packet)
delivery masks and bubble-fill compensation:

    paper:  out[p] = sum_w g[w,p] * m[w,p] / W
    count:  out[p] = sum_w g[w,p] * m[w,p] / max(sum_w m[w,p], 1)

The worker dimension is accumulated *inside* the kernel (static unroll over
W — typically 8..64), so each (BLOCK_P, payload) output tile is written once
and each input tile is read once: one HBM pass, the roofline optimum for
this memory-bound reduction. This is the TPU adaptation of the paper's PS
aggregation hot loop (their C++ server thread).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 128


def _reduce_kernel(pkts_ref, mask_ref, out_ref, *, n_workers: int,
                   compensation: str):
    """pkts: (W, BLOCK_P, payload); mask: (W, BLOCK_P, 1)."""
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    cnt = jnp.zeros((out_ref.shape[0], 1), jnp.float32)
    for w in range(n_workers):          # static unroll
        m = mask_ref[w]
        acc = acc + pkts_ref[w].astype(jnp.float32) * m
        cnt = cnt + m
    if compensation == "count":
        out_ref[...] = (acc / jnp.maximum(cnt, 1.0)).astype(out_ref.dtype)
    else:
        out_ref[...] = (acc / n_workers).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("compensation", "interpret"))
def packet_reduce(packets, mask, *, compensation: str = "paper",
                  interpret: bool = True):
    """packets: (W, n_packets, payload) f32; mask: (W, n_packets) f32.

    Requires payload % 128 == 0, n_packets % BLOCK_P == 0. Returns
    (n_packets, payload) float32.
    """
    w, n, p = packets.shape
    assert p % 128 == 0 and n % BLOCK_P == 0, (w, n, p)
    mask3 = mask[..., None].astype(jnp.float32)
    grid = (n // BLOCK_P,)
    kernel = functools.partial(
        _reduce_kernel, n_workers=w, compensation=compensation
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, BLOCK_P, p), lambda i: (0, i, 0)),
            pl.BlockSpec((w, BLOCK_P, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_P, p), lambda i: (i, 0)),
        interpret=interpret,
    )(packets, mask3)
