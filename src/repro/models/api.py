"""Unified model API over all families + dry-run input specs.

``build(cfg)`` returns a ModelApi with the same callable surface for every
architecture; ``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins
(weak-type-correct, zero allocation) for each step kind:

  train   -> loss_fn(params, batch)
  prefill -> prefill(params, inputs)          (last-token logits + cache)
  decode  -> decode_step(params, cache, token, pos)   (ONE token)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import cnn, encdec, transformer
from repro.models.sharding import NULL_CTX
from repro.shapes import InputShape

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable        # (params, batch, *, ctx, remat) -> scalar
    forward: Optional[Callable]
    prefill: Optional[Callable]      # (params, inputs, *, ctx) -> (logits, cache)
    decode_step: Optional[Callable]  # (params, cache, token, pos, *, ctx)
    init_cache: Optional[Callable]   # (batch, max_seq, dtype) -> cache pytree


def _tf_prefill(cfg):
    def prefill(params, inputs, *, ctx=NULL_CTX):
        logits, _, caches = transformer.forward(
            cfg, params, inputs, ctx=ctx, collect_cache=True, remat=False,
            last_only=True,
        )
        return logits[:, 0], caches

    return prefill


def build(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "cnn":
        return ModelApi(
            cfg=cfg,
            init=functools.partial(cnn.init, cfg=cfg),
            loss_fn=functools.partial(cnn.loss_fn, cfg),
            forward=functools.partial(cnn.forward, cfg),
            prefill=None,
            decode_step=None,
            init_cache=None,
        )
    if cfg.family == "audio":
        return ModelApi(
            cfg=cfg,
            init=functools.partial(encdec.init, cfg=cfg),
            loss_fn=functools.partial(encdec.loss_fn, cfg),
            forward=None,
            prefill=functools.partial(encdec.prefill, cfg),
            decode_step=functools.partial(encdec.decode_step, cfg),
            init_cache=functools.partial(encdec.init_cache, cfg),
        )
    return ModelApi(
        cfg=cfg,
        init=functools.partial(transformer.init, cfg=cfg),
        loss_fn=functools.partial(transformer.loss_fn, cfg),
        forward=functools.partial(transformer.forward, cfg),
        prefill=_tf_prefill(cfg),
        decode_step=functools.partial(transformer.decode_step, cfg),
        init_cache=functools.partial(transformer.init_cache, cfg),
    )


# ----------------------------------------------------------------------------
# Shape support (DESIGN.md §long_500k / decode skips)
# ----------------------------------------------------------------------------


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if cfg.family == "cnn":
        if shape.kind == "train":
            return True, ""
        return False, "papernet is the paper's train-only CIFAR workload"
    if shape.name == "long_500k":
        has_ssm = any(c in ("M", "M2") for c in cfg.pattern_layers)
        if has_ssm or cfg.window > 0:
            return True, ""
        return (
            False,
            "pure full-attention arch: 524k decode requires sub-quadratic "
            "attention (DESIGN.md §long_500k skips)",
        )
    return True, ""


# ----------------------------------------------------------------------------
# Input specs (dry-run stand-ins)
# ----------------------------------------------------------------------------


def _token_split(cfg: ModelConfig, seq_len: int) -> Tuple[int, int]:
    """(n_stub_positions, n_text_tokens) summing to seq_len."""
    if cfg.family == "vlm":
        p = min(cfg.vision_patches, seq_len // 2)
        return p, seq_len - p
    return 0, seq_len


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStructs for the step selected by ``shape.kind``."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    if cfg.family == "cnn":
        return {
            "images": SDS((b, 32, 32, 3), jnp.float32),
            "labels": SDS((b,), jnp.int32),
        }

    if shape.kind == "decode":
        api = build(cfg)
        cache = jax.eval_shape(
            lambda: api.init_cache(b, s, dt)
        )
        return {
            "cache": cache,
            "token": SDS((b,), jnp.int32),
            "pos": SDS((), jnp.int32),
        }

    if cfg.family == "audio":
        specs = {
            "frames": SDS((b, cfg.encoder_frames, cfg.d_model), dt),
            "tokens": SDS((b, s), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = SDS((b, s), jnp.int32)
        return specs

    n_patch, n_text = _token_split(cfg, s)
    specs: Dict[str, Any] = {"tokens": SDS((b, n_text), jnp.int32)}
    if cfg.family == "vlm":
        specs["patch_embeds"] = SDS((b, n_patch, cfg.d_model), dt)
        specs["positions3"] = SDS((3, b, s), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = SDS((b, s), jnp.int32)
    return specs


def demo_inputs(cfg: ModelConfig, shape: InputShape, key) -> Dict[str, Any]:
    """Concrete random inputs matching input_specs (for smoke tests)."""
    specs = input_specs(cfg, shape)
    counter = iter(range(10_000))

    def materialize(path, sds):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        k = jax.random.fold_in(key, next(counter))
        if jnp.issubdtype(sds.dtype, jnp.integer):
            hi = cfg.vocab if "token" in str(name) or "label" in str(name) else max(
                2, shape.seq_len
            )
            return jax.random.randint(k, sds.shape, 0, hi, sds.dtype)
        return jax.random.normal(k, sds.shape).astype(sds.dtype) * 0.02

    return jax.tree_util.tree_map_with_path(materialize, specs)
