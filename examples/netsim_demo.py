"""Protocol-level demo: watch Early Close cut the incast tail.

Runs the packet-level DES for an 8-to-1 gather with stragglers, for LTP
and cubic, and prints per-iteration close decisions.

  PYTHONPATH=src python examples/netsim_demo.py [--loss 0.005]
"""
import argparse

import numpy as np

from repro.config import NetConfig
from repro.net.scenarios import incast_gather


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--loss", type=float, default=0.005)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--size-mb", type=float, default=2.0)
    args = ap.parse_args()

    net = NetConfig(10, 1, args.loss, 4096)
    size = args.size_mb * 1e6
    for proto in ["ltp", "bbr", "cubic"]:
        rs = incast_gather(proto, net, 8, size, iters=args.iters, seed=1,
                           straggler_prob=0.3, straggler_scale=1.0)
        bst = np.array([r.bst_gather for r in rs]) * 1e3
        dl = np.array([r.delivered.mean() for r in rs])
        print(f"\n{proto}: BST per iteration (ms):")
        print("  " + " ".join(f"{b:7.1f}" for b in bst))
        print(f"  delivered: " + " ".join(f"{d:7.2f}" for d in dl))
        print(f"  mean {bst.mean():.1f}ms  p95 {np.percentile(bst,95):.1f}ms")


if __name__ == "__main__":
    main()
