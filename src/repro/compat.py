"""Version shims for jax APIs that moved between releases.

The repo targets current jax (``jax.shard_map``, ``jax.sharding.AxisType``)
but must also run on the 0.4.x line baked into the CPU container, where
shard_map lives in ``jax.experimental`` with slightly different kwargs:

  new                         old (0.4.x)
  ``jax.shard_map``           ``jax.experimental.shard_map.shard_map``
  ``check_vma=``              ``check_rep=``
  ``axis_names={...}``        ``auto=frozenset(all_axes) - {...}``
  ``jax.make_mesh(axis_types=...)``   (no axis_types kwarg)
"""
from __future__ import annotations

from typing import Optional, Set

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None, check: bool = False):
    """``jax.shard_map`` with the new-API surface on any supported jax."""
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    # check_rep has no replication rule for several primitives we use
    # (sharding_constraint) on 0.4.x — always disable there.
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        # partial-auto shard_map is jit-only on the 0.4.x line
        return jax.jit(_sm(f, **kw))
    return _sm(f, **kw)


def axis_size(name):
    """``jax.lax.axis_size`` (absent on 0.4.x; psum(1) is the classic spelling)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def set_mesh(mesh):
    """Ambient-mesh context manager (``jax.set_mesh`` post-0.5; the Mesh
    object itself is the context manager before that)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
