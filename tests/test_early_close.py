"""Early Close controller (paper §III-B) properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import LTPConfig, NetConfig
from repro.core.early_close import (
    AnalyticIncastModel, EarlyCloseController, GatherSample, broadcast_time,
)


def _ctrl(pct=0.8, c_ms=30.0, w=8, size=10e6):
    net = NetConfig(bandwidth_gbps=10, rtprop_ms=1, loss_rate=0.0)
    ltp = LTPConfig(data_pct_threshold=pct, deadline_c_ms=c_ms)
    return EarlyCloseController(ltp, net, w, size), net


def test_lt_init_formula():
    ctrl, net = _ctrl()
    rt = net.rtprop_ms * 1e-3
    share = net.bandwidth_gbps * 1e9 / 8 / 8
    np.testing.assert_allclose(ctrl.lt, 1.5 * rt + 10e6 / share, rtol=1e-9)


def test_fast_iteration_closes_at_completion():
    ctrl, _ = _ctrl()
    lt = float(ctrl.lt.max())
    s = GatherSample(completion_times=np.full(8, lt * 0.5),
                     first_arrival=np.full(8, 1e-3))
    close, frac = ctrl.step(s)
    np.testing.assert_allclose(close, lt * 0.5)
    np.testing.assert_allclose(frac, 1.0)


def test_straggler_cut_between_thresholds():
    ctrl, _ = _ctrl(pct=0.8)
    lt = float(ctrl.lt.max())
    tf = np.full(8, lt * 0.9)
    tf[0] = lt * 5.0   # one starved flow
    s = GatherSample(tf, np.full(8, 1e-3))
    close, frac = ctrl.step(s)
    assert lt <= close <= ctrl.deadline + 1e-9
    assert frac[1:].min() == 1.0       # fast flows complete
    assert frac[0] < 0.5               # straggler cut
    assert np.mean(frac) >= 0.8 - 1e-6


def test_deadline_unconditional():
    ctrl, _ = _ctrl(pct=0.99)
    lt = float(ctrl.lt.max())
    s = GatherSample(np.full(8, lt * 50), np.full(8, 1e-3))
    close, frac = ctrl.step(s)
    np.testing.assert_allclose(close, ctrl.deadline)
    assert frac.mean() < 0.99


def test_epoch_update_takes_best_full_time():
    ctrl, _ = _ctrl()
    lt0 = ctrl.lt.copy()
    fast = lt0 * 0.6
    ctrl.step(GatherSample(fast, np.full(8, 1e-3)))
    ctrl.new_epoch()
    np.testing.assert_allclose(ctrl.lt, fast, rtol=1e-9)


@settings(max_examples=30, deadline=None)
@given(st.floats(0.5, 0.95), st.integers(0, 1000))
def test_close_respects_threshold_property(pct, seed):
    """Invariant: close time in [0, deadline]; if close < deadline then
    either everything arrived or mean pct >= threshold."""
    ctrl, _ = _ctrl(pct=pct)
    rng = np.random.default_rng(seed)
    lt = float(ctrl.lt.max())
    tf = lt * rng.uniform(0.3, 3.0, 8)
    s = GatherSample(tf, np.full(8, 1e-3))
    close, frac = ctrl.step(s)
    assert 0 < close <= ctrl.deadline + 1e-9
    if close < ctrl.deadline - 1e-9:
        assert (tf.max() <= close + 1e-9) or (frac.mean() >= pct - 1e-6)


def test_analytic_model_loss_response():
    """TCP-family completion inflates sharply with loss; BDP-based doesn't."""
    w = 8
    base = {}
    for proto in ["cubic", "ltp"]:
        nets = [NetConfig(10, 1, p, 256) for p in (0.0, 0.01)]
        times = []
        for net in nets:
            m = AnalyticIncastModel(net, w, protocol=proto, seed=1)
            times.append(np.mean([m.sample(10e6).completion_times.mean()
                                  for _ in range(20)]))
        base[proto] = times[1] / times[0]
    assert base["cubic"] > 5 * base["ltp"]


def test_broadcast_time_scales_with_size():
    net = NetConfig(10, 1, 0.0)
    assert broadcast_time(net, 2e7) > broadcast_time(net, 1e7)
