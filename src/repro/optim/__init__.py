from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    lr_at,
    make_optimizer,
    sgd_momentum,
)
