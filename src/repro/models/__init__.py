"""Model substrate: pure-JAX (pytree-params) definitions of every assigned
architecture plus the paper's own CNN workload.

Public API (see ``api.py``):
    build(cfg)         -> ModelApi with init / loss_fn / prefill / decode_step
    input_specs(...)   -> ShapeDtypeStruct stand-ins for the dry-run
"""
from repro.models.api import ModelApi, build  # noqa: F401
