"""Pluggable ``Tracker`` backends (DESIGN.md §12).

A ``Tracker`` is the one-way sink the runtime's observability layer
emits into — the levanter-style split between *producing* telemetry
(``runtime.telemetry.Telemetry``, the metrics registry) and *shipping*
it somewhere a human or dashboard can read it. The contract is
deliberately narrow so a backend is ~30 lines:

  ``log_event(event)``      one structured runtime event (the §8 schema:
                            ``kind``, ``t``, payload fields). Called on
                            the DES hot path — implementations MUST be
                            O(1) per call (append to a buffer; never
                            serialize, flush, or walk state inline).
  ``log_metrics(m, step=)`` a dict of scalar series points (loss, bst,
                            delivered ... per training step).
  ``log_summary(m)``        end-of-run scalars (``Telemetry.summary()``
                            plus the metrics-registry snapshot).
  ``finish()``              serialize + release resources. The runtime
                            calls it once, AFTER the event loop drained
                            and lazy jax scalars were forced — the only
                            point where file I/O is allowed to block.

Backends: ``MemoryTracker`` (lists, for tests/notebooks),
``JsonlTracker`` (one JSON object per line), ``CsvTracker``
(union-of-keys header, written at finish), ``CompositeTracker``
(fan-out), ``TensorBoardTracker`` (optional — raises a clear error
when no tensorboard writer package is installed), and ``NullTracker``
(explicit no-op). ``make_tracker`` builds any of them from an
``ObservabilityConfig``; ``tracker="none"`` resolves to ``None`` so
the hot path keeps a single ``is not None`` branch and nothing else.
"""
from __future__ import annotations

import abc
import csv
import io
import json
import os
from typing import TYPE_CHECKING, Any, Dict, List, Mapping, Optional, Sequence

if TYPE_CHECKING:
    from repro.config import ObservabilityConfig

#: backend names ``make_tracker`` accepts (comma-compose for fan-out).
TRACKER_BACKENDS = ("none", "memory", "jsonl", "csv", "tensorboard")


def _json_default(v: Any) -> Any:
    """Last-resort encoder for event payloads: numpy/jax scalars become
    floats, everything else a string — serialization must never throw
    after a run completed."""
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class Tracker(abc.ABC):
    """Abstract telemetry sink; see the module docstring for the
    contract. Context-manager use guarantees ``finish``."""

    name: str = "abstract"

    @abc.abstractmethod
    def log_event(self, event: Mapping[str, Any]) -> None:
        """Record one structured runtime event (O(1), hot path)."""

    @abc.abstractmethod
    def log_metrics(self, metrics: Mapping[str, Any], *,
                    step: Optional[int] = None) -> None:
        """Record a point of per-step scalar series."""

    @abc.abstractmethod
    def log_summary(self, metrics: Mapping[str, Any]) -> None:
        """Record end-of-run scalars."""

    def finish(self) -> None:
        """Flush/close. Idempotent; the only call allowed to block."""

    def __enter__(self) -> "Tracker":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.finish()


class NullTracker(Tracker):
    """Explicit no-op sink (API completeness; the runtime maps
    ``tracker='none'`` to ``None`` instead so the hot path pays a single
    branch, not a virtual call)."""

    name = "none"

    def log_event(self, event: Mapping[str, Any]) -> None:
        pass

    def log_metrics(self, metrics: Mapping[str, Any], *,
                    step: Optional[int] = None) -> None:
        pass

    def log_summary(self, metrics: Mapping[str, Any]) -> None:
        pass


class MemoryTracker(Tracker):
    """Keep everything in lists — tests and notebooks."""

    name = "memory"

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []
        self.metrics: List[Dict[str, Any]] = []
        self.summary: Dict[str, Any] = {}
        self.finished = False

    def log_event(self, event: Mapping[str, Any]) -> None:
        self.events.append(dict(event))

    def log_metrics(self, metrics: Mapping[str, Any], *,
                    step: Optional[int] = None) -> None:
        row = dict(metrics)
        if step is not None:
            row["step"] = step
        self.metrics.append(row)

    def log_summary(self, metrics: Mapping[str, Any]) -> None:
        self.summary.update(metrics)

    def finish(self) -> None:
        self.finished = True


class _BufferedFileTracker(Tracker):
    """Shared buffering discipline for the file backends: ``log_*`` is
    an O(1) append; serialization happens in ``finish`` (or an explicit
    ``flush``), after the runtime forced its lazy jax scalars — a
    mid-run flush would both block the event loop and serialize
    unforced device values (DESIGN.md §9/§12)."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._events: List[Mapping[str, Any]] = []
        self._metrics: List[Dict[str, Any]] = []
        self._summary: Dict[str, Any] = {}
        self._finished = False

    def log_event(self, event: Mapping[str, Any]) -> None:
        self._events.append(event)

    def log_metrics(self, metrics: Mapping[str, Any], *,
                    step: Optional[int] = None) -> None:
        row = dict(metrics)
        if step is not None:
            row["step"] = step
        self._metrics.append(row)

    def log_summary(self, metrics: Mapping[str, Any]) -> None:
        self._summary.update(metrics)

    def finish(self) -> None:
        if self._finished:
            return
        self._finished = True
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        self._write()

    def _write(self) -> None:
        raise NotImplementedError


class JsonlTracker(_BufferedFileTracker):
    """One JSON object per line: events as-is (``{"kind": ..., "t": ...,
    ...}``), metric points as ``{"kind": "metrics", ...}``, the summary
    as one ``{"kind": "summary", ...}`` tail record."""

    name = "jsonl"

    def _write(self) -> None:
        with open(self.path, "w") as f:
            for e in self._events:
                f.write(json.dumps(e, default=_json_default) + "\n")
            for m in self._metrics:
                f.write(json.dumps({"kind": "metrics", **m},
                                   default=_json_default) + "\n")
            if self._summary:
                f.write(json.dumps({"kind": "summary", **self._summary},
                                   default=_json_default) + "\n")


class CsvTracker(_BufferedFileTracker):
    """Events as one CSV with the union-of-keys header (the §8 event
    kinds carry different payloads; absent fields are empty cells). The
    summary lands next to it as ``<path>.summary.json``."""

    name = "csv"

    def _write(self) -> None:
        keys: List[str] = []
        seen = set()
        for e in list(self._events) + self._metrics:
            for k in e:
                if k not in seen:
                    seen.add(k)
                    keys.append(k)
        with open(self.path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys, restval="")
            w.writeheader()
            for e in self._events:
                w.writerow({k: e.get(k, "") for k in keys})
            for m in self._metrics:
                w.writerow({k: m.get(k, "") for k in keys})
        if self._summary:
            with open(self.path + ".summary.json", "w") as f:
                json.dump(self._summary, f, indent=1,
                          default=_json_default)


class CompositeTracker(Tracker):
    """Fan every call out to child trackers in order."""

    name = "composite"

    def __init__(self, children: Sequence[Tracker]) -> None:
        self.children = list(children)

    def log_event(self, event: Mapping[str, Any]) -> None:
        for c in self.children:
            c.log_event(event)

    def log_metrics(self, metrics: Mapping[str, Any], *,
                    step: Optional[int] = None) -> None:
        for c in self.children:
            c.log_metrics(metrics, step=step)

    def log_summary(self, metrics: Mapping[str, Any]) -> None:
        for c in self.children:
            c.log_summary(metrics)

    def finish(self) -> None:
        for c in self.children:
            c.finish()


class TensorBoardTracker(Tracker):
    """Scalar series into a TensorBoard event file. Optional: imports
    ``tensorboardX`` or ``torch.utils.tensorboard`` lazily and raises
    an actionable ``ImportError`` when neither is installed (the
    container does not bake one in; tests importorskip)."""

    name = "tensorboard"

    def __init__(self, log_dir: str) -> None:
        writer_cls = None
        for mod, attr in (("tensorboardX", "SummaryWriter"),
                          ("torch.utils.tensorboard", "SummaryWriter")):
            try:
                writer_cls = getattr(__import__(mod, fromlist=[attr]), attr)
                break
            except ImportError:
                continue
        if writer_cls is None:
            raise ImportError(
                "TensorBoardTracker needs tensorboardX or torch installed; "
                "use tracker='jsonl' (or 'csv') on this machine")
        self._writer = writer_cls(log_dir=log_dir)
        self._n_events = 0

    def log_event(self, event: Mapping[str, Any]) -> None:
        self._n_events += 1  # event streams don't map to TB scalars

    def log_metrics(self, metrics: Mapping[str, Any], *,
                    step: Optional[int] = None) -> None:
        step = 0 if step is None else int(step)
        for k, v in metrics.items():
            if isinstance(v, (int, float)):
                self._writer.add_scalar(k, v, global_step=step)

    def log_summary(self, metrics: Mapping[str, Any]) -> None:
        for k, v in metrics.items():
            if isinstance(v, (int, float)):
                self._writer.add_scalar(f"summary/{k}", v, global_step=0)

    def finish(self) -> None:
        self._writer.close()


def make_tracker(cfg: "ObservabilityConfig",
                 run_name: str = "run") -> Optional[Tracker]:
    """Build the tracker an ``ObservabilityConfig`` selects.

    ``cfg.tracker`` is a backend name or a comma-separated list (the
    composite). ``"none"``/empty resolves to ``None`` — the runtime's
    zero-overhead path. File backends write to ``cfg.path`` when given,
    else ``<cfg.out_dir>/<run_name>.<ext>``.
    """
    names = [n.strip() for n in (cfg.tracker or "none").split(",")
             if n.strip() and n.strip() != "none"]
    if not names:
        return None

    def one(name: str) -> Tracker:
        if name == "memory":
            return MemoryTracker()
        if name == "jsonl":
            return JsonlTracker(
                cfg.path or os.path.join(cfg.out_dir, f"{run_name}.jsonl"))
        if name == "csv":
            return CsvTracker(
                cfg.path or os.path.join(cfg.out_dir, f"{run_name}.csv"))
        if name == "tensorboard":
            return TensorBoardTracker(os.path.join(cfg.out_dir, run_name))
        raise ValueError(f"unknown tracker backend {name!r}; expected one "
                         f"of {TRACKER_BACKENDS} (comma-compose for "
                         f"fan-out)")

    if len(names) == 1:
        return one(names[0])
    return CompositeTracker([one(n) for n in names])


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a ``JsonlTracker`` file back into a list of dicts (tests,
    ad-hoc analysis)."""
    out: List[Dict[str, Any]] = []
    with io.open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
