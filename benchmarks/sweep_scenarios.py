"""Scenario-grid sweep over the topology engine (DESIGN.md §5, §7).

Runs every gather scenario in the registry grid over protocol x knob:

  multi_ps_gather   n_ps in {1, 2, 4[, 8]}          (sharded-PS scaling)
  straggler_gather  slow_rate_mult in {0.5, 0.25[, 0.1]}
  cross_traffic     bg_load in {0.0, 0.5[, 0.8]}

plus the paper-scale **grid64** (64 workers x {1, 4} PS shards, coalesced
packet trains) that the per-packet engine could not fit into quick mode,
and the DC-scale **rack512** cell (512 workers, 16 racks x 32 behind 8:1
oversubscribed uplinks) comparing LTP + in-network aggregation against
each mechanism alone (DESIGN.md §11).

Emits one row per (scenario, protocol, knob): mean/p99 gather BST, mean
delivered fraction, and LTP's speedup over the same cell's cubic run.
Transfer sizes are scaled (2 MB quick / 5 MB full per model) so the whole
grid finishes in seconds on CPU; trends — not absolute seconds — are the
output.

The run also writes the machine-readable perf record ``BENCH_netsim.json``
at the repo root — wall-clocks and simulator events/sec (packet deliveries
per wall second; one heap event carries a train of up to K) — which the CI
perf-smoke job diffs against the committed baseline
(``benchmarks.check_regression``).

  PYTHONPATH=src python -m benchmarks.run --only scenario_sweep
  PYTHONPATH=src python -m benchmarks.sweep_scenarios          # standalone
"""
from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

from repro.config import NetConfig
from repro.net import simcore
from repro.net.scenarios import PROTOCOLS, run_scenario

from benchmarks.common import emit

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: packet-train length for the paper-scale cells (DESIGN.md §7)
GRID64_COALESCE = 32


def _cells(quick: bool):
    n_ps = [1, 2, 4] if quick else [1, 2, 4, 8]
    slow = [0.5, 0.25] if quick else [0.5, 0.25, 0.1]
    load = [0.0, 0.5] if quick else [0.0, 0.5, 0.8]
    for v in n_ps:
        yield "multi_ps_gather", {"n_ps": v}, f"n_ps={v}"
    for v in slow:
        yield "straggler_gather", {"slow_rate_mult": v}, f"slow_mult={v}"
    for v in load:
        yield "cross_traffic", {"bg_load": v}, f"bg_load={v}"


def _timed_cell(proto: str, net: NetConfig, *, size: float, iters: int,
                coalesce: int, seed: int = 13,
                scenario: str = "multi_ps_gather", **scenario_kw):
    """One measured gather cell -> (results, perf dict)."""
    simcore.PERF.reset()
    t0 = time.time()
    rs = run_scenario(scenario, proto, net, size_bytes=size,
                      iters=iters, seed=seed, coalesce=coalesce,
                      **scenario_kw)
    wall = time.time() - t0
    return rs, {
        "wall_s": round(wall, 3),
        "events_per_sec": round(simcore.PERF.packets / max(wall, 1e-9)),
        "heap_events": simcore.PERF.events,
        "packets": simcore.PERF.packets,
        "bst_mean_ms": round(float(np.mean([r.bst_gather for r in rs])) * 1e3,
                             2),
    }


def grid64(quick: bool = True):
    """Paper-scale sweep: 64 workers x {1, 4} PS, coalesced trains — plus a
    per-packet reference cell and its coalesced twin (identical workload)
    so the recorded speedup is apples-to-apples."""
    net = NetConfig(10, 1, 0.001, 4096)
    size = 2e6 if quick else 5e6
    iters = 2 if quick else 4
    rows = []
    metrics = {"grid64_coalesce": GRID64_COALESCE}
    for proto in ("ltp", "cubic"):
        for n_ps in (1, 4):
            _, perf = _timed_cell(proto, net, w=64, size=size, n_ps=n_ps,
                                  iters=iters, coalesce=GRID64_COALESCE)
            rows.append({"scenario": "grid64", "knob": f"n_ps={n_ps}",
                         "protocol": proto, **perf})
            metrics[f"grid64_{proto}_ps{n_ps}_wall_s"] = perf["wall_s"]
            metrics[f"grid64_{proto}_ps{n_ps}_events_per_sec"] = \
                perf["events_per_sec"]
    # apples-to-apples speedup: the per-packet engine on the SAME 64x4 cell
    # (same model size — per-packet throughput degrades with flow length,
    # so a smaller ref would flatter the old engine); one round keeps the
    # quick run bounded (~12s)
    _, ref = _timed_cell("ltp", net, w=64, size=size, n_ps=4,
                         iters=1 if quick else 2, coalesce=1)
    twin_eps = metrics["grid64_ltp_ps4_events_per_sec"]
    metrics["grid64_ref_per_packet_events_per_sec"] = ref["events_per_sec"]
    metrics["grid64_ref_coalesced_events_per_sec"] = twin_eps
    metrics["grid64_coalesce_speedup"] = round(
        twin_eps / max(ref["events_per_sec"], 1), 2)
    rows.append({"scenario": "grid64_ref", "knob": "coalesce=1",
                 "protocol": "ltp", **ref})
    return rows, metrics


#: the DC-scale rack/spine grid (DESIGN.md §11): 16 racks x 32 workers
#: behind 8:1 oversubscribed ToR uplinks
RACK512 = dict(racks=16, workers_per_rack=32, oversub=8.0)


def rack512(quick: bool = True):
    """The 512-worker rack/spine acceptance cell (DESIGN.md §11).

    Three arms of the same coalesced gather, all on the oversubscribed
    rack grid, isolate what each mechanism buys and what only the combo
    delivers:

      ltp_agg    LTP Early Close + in-network aggregation at the ToR
      ltp_only   LTP on the same grid, aggregation off — every worker's
                 packets individually cross the 8:1 trunk
      agg_only   in-network aggregation with Early Close disabled
                 (pct threshold 1.0, deadline pushed out) — the switch
                 merges, but every loss stalls the gather to full
                 delivery

    The gated claims: ``rack512_combo_speedup_vs_best_single`` >= 1
    (the combo beats either mechanism alone), the cell sustains an
    absolute events/sec floor, and ``rack512_wall_s`` stays under the
    absolute ceiling — DC-scale gathers must remain a routine CI cell,
    not an overnight job (check_regression FLOORS / WALL_CEILINGS).
    """
    from repro.config import LTPConfig

    net = NetConfig(10, 1, 0.001, 4096)
    size = 5e5 if quick else 1e6
    iters = 1 if quick else 2
    no_ec = LTPConfig(data_pct_threshold=1.0, deadline_c_ms=1e6)
    arms = (("ltp_agg", True, None),
            ("ltp_only", False, None),
            ("agg_only", True, no_ec))
    rows, metrics = [], {}
    t0_all = time.time()
    for name, agg, ltp in arms:
        rs, perf = _timed_cell(
            "ltp", net, size=size, iters=iters, coalesce=GRID64_COALESCE,
            scenario="rack_spine_gather", agg=agg, ltp=ltp, **RACK512)
        delivered = round(float(np.mean([r.delivered.mean() for r in rs])), 4)
        rows.append({"scenario": "rack512", "knob": name, "protocol": "ltp",
                     "delivered": delivered, **perf})
        metrics[f"rack512_bst_{name}_ms"] = perf["bst_mean_ms"]
        if name == "ltp_agg":
            metrics["rack512_ltp_agg_events_per_sec"] = perf["events_per_sec"]
            metrics["rack512_delivered_ltp_agg"] = delivered
            stats = rs[-1].agg_stats or {}
            metrics["rack512_n_merged"] = stats.get("n_merged", 0)
            metrics["rack512_n_envelopes"] = stats.get("n_envelopes", 0)
    metrics["rack512_combo_speedup_vs_best_single"] = round(
        min(metrics["rack512_bst_ltp_only_ms"],
            metrics["rack512_bst_agg_only_ms"])
        / metrics["rack512_bst_ltp_agg_ms"], 3)
    metrics["rack512_wall_s"] = round(time.time() - t0_all, 3)
    return rows, metrics


def run(quick: bool = True):
    rows = []
    iters = 4 if quick else 10
    size = 2e6 if quick else 5e6
    w = 8
    net = NetConfig(10, 1, 0.001, 4096)
    t0 = time.time()
    for scenario, kw, knob in _cells(quick):
        cell = {}
        for proto in PROTOCOLS:
            rs = run_scenario(scenario, proto, net, w=w, size_bytes=size,
                              iters=iters, seed=13, **kw)
            bst = np.array([r.bst_gather for r in rs])
            cell[proto] = bst.mean()
            rows.append({
                "scenario": scenario, "knob": knob, "protocol": proto,
                "bst_mean_ms": round(float(bst.mean()) * 1e3, 2),
                "bst_p99_ms": round(float(np.percentile(bst, 99)) * 1e3, 2),
                "delivered": round(float(np.mean([r.delivered.mean()
                                                  for r in rs])), 4),
            })
        for r in rows[-len(PROTOCOLS):]:
            r["ltp_speedup_vs_cubic"] = round(cell["cubic"] / cell["ltp"], 2)
    sweep_wall = time.time() - t0
    g_rows, metrics = grid64(quick)
    rows.extend(g_rows)
    r_rows, r_metrics = rack512(quick)
    rows.extend(r_rows)
    metrics.update(r_metrics)
    metrics["sweep_small_wall_s"] = round(sweep_wall, 3)
    write_bench(metrics, quick, "BENCH_netsim.json")
    emit(rows, "sweep_scenarios")
    return rows


def write_bench(metrics: dict, quick: bool, name: str) -> str:
    """Write a machine-readable perf record at the repo root."""
    path = os.path.join(REPO_ROOT, name)
    with open(path, "w") as f:
        json.dump({
            "schema": 1,
            "quick": quick,
            "host": {"python": platform.python_version(),
                     "machine": platform.machine()},
            "metrics": metrics,
        }, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", flush=True)
    return path


if __name__ == "__main__":
    run(quick=True)
