"""Pallas TPU kernel: Random-k gradient sparsification (paper §II-C).

Keeps each element where a precomputed uniform draw falls under ``k_frac``
(threshold-controlled Random-k — the sparsifier whose semantics LTP's
packet loss emulates, paper Fig 5). Uniforms are generated outside the
kernel (jax.random) and streamed in; the kernel is a pure select, one HBM
pass — the point of the kernel is fusing select+scale so the sparsified
tensor is never materialized twice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 512


def _randomk_kernel(x_ref, u_ref, k_ref, out_ref):
    k = k_ref[0, 0]
    out_ref[...] = jnp.where(u_ref[...] < k, x_ref[...],
                             jnp.zeros_like(x_ref[...]))


@functools.partial(jax.jit, static_argnames=("interpret",))
def randomk(x, u, k_frac, *, interpret: bool = True):
    """x, u: (rows, cols) with rows % BLOCK_R == 0, cols % BLOCK_C == 0;
    k_frac: scalar in [0,1]. Returns x sparsified."""
    r, c = x.shape
    assert r % BLOCK_R == 0 and c % BLOCK_C == 0, (r, c)
    k = jnp.full((1, 1), k_frac, jnp.float32)
    grid = (r // BLOCK_R, c // BLOCK_C)
    return pl.pallas_call(
        _randomk_kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
        interpret=interpret,
    )(x, u, k)
