"""Train-step builders.

``make_plain_train_step``  — GSPMD/fsdp baseline (lossless sync, the TCP/
                             BBR-transport analogue at the numerics level).
``make_ltp_train_step``    — LTP as a first-class feature at scale: the
                             whole fwd/bwd runs inside a shard_map that is
                             MANUAL over the worker axes (pod and/or data)
                             and AUTO over the rest, so per-worker gradient
                             contributions exist explicitly and are
                             packet-masked before the psum (paper §III).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.config import LTPConfig
from repro.core import ltp_sync as ls
from repro.models.api import ModelApi
from repro.models.sharding import ShardCtx
from repro.optim import Optimizer


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_state(api: ModelApi, opt: Optimizer, key) -> TrainState:
    params = api.init(key)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_plain_train_step(api: ModelApi, opt: Optimizer,
                          mesh=None) -> Callable:
    """Global-loss pjit step; gradient sync is GSPMD's exact all-reduce."""
    ctx = ShardCtx(mesh)

    def step(state: TrainState, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, ctx=ctx)
        )(state.params)
        updates, opt_state = opt.update(grads, state.opt_state, state.params, lr)
        params = jax.tree.map(lambda p, u: p + u, state.params, updates)
        return (
            TrainState(params, opt_state, state.step + 1),
            {"loss": loss},
        )

    return step


def make_ltp_train_step(api: ModelApi, opt: Optimizer, mesh,
                        ltp: LTPConfig, worker_axes: Tuple[str, ...],
                        batch_specs) -> Callable:
    """LTP-synced step (sharded, v2 leafwise-packet masking).

    worker_axes: the mesh axes along which the model is REPLICATED and
    whose members act as the paper's workers — ('pod',) for cross-DC LTP
    (the flagship multi-pod config: ICI inside a pod is lossless, the
    pod-to-pod DCN link is where loss tolerance pays), or ('data',) /
    ('pod','data') for classic PS emulation.

    batch_specs: pytree of PartitionSpecs for the batch (full specs are
    fine — they are restricted to the manual worker axes here; the auto
    axes are constrained inside via ShardCtx).
    """
    n_workers = 1
    for a in worker_axes:
        n_workers *= mesh.shape[a]
    ctx = ShardCtx(mesh, exclude=worker_axes)

    def restrict(spec: P) -> P:
        out = []
        for entry in spec:
            if entry is None:
                out.append(None)
                continue
            names = (entry,) if isinstance(entry, str) else tuple(entry)
            keep = tuple(n for n in names if n in worker_axes)
            out.append(keep[0] if len(keep) == 1 else (keep or None))
        return P(*out)

    batch_specs = jax.tree.map(restrict, batch_specs,
                               is_leaf=lambda x: isinstance(x, P))

    def inner(params, opt_state, mstep, batch, frac, key, lr):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, ctx=ctx)
        )(params)
        synced, realized = ls.masked_psum_leafwise(
            grads, key, frac, ltp, worker_axes, n_workers
        )
        updates, opt_state = opt.update(synced, opt_state, params, lr)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        loss_g = jax.lax.pmean(loss, worker_axes)
        return params, opt_state, mstep + 1, loss_g, realized

    def inner_zero(params, m_pkts, mstep, batch, frac, key, lr):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, ctx=ctx)
        )(params)
        deltas, m_pkts, realized = ls.masked_rs_update_leafwise(
            grads, params, m_pkts, key, frac, ltp, worker_axes, n_workers, lr
        )
        loss_g = jax.lax.pmean(loss, worker_axes)
        return deltas, m_pkts, mstep + 1, loss_g, realized

    worker_spec = (worker_axes if len(worker_axes) > 1 else worker_axes[0])

    def _zero_step(state: TrainState, batch, frac, key, lr):
        n_leaves = len(state.opt_state["m_pkts"])
        m_specs = [P(worker_spec, None)] * n_leaves
        deltas, m_pkts, mstep, loss, realized = compat.shard_map(
            inner_zero,
            mesh=mesh,
            in_specs=(rep, m_specs, rep, batch_specs, rep, rep, rep),
            out_specs=(m_specs, m_specs, rep, rep, rep),
            axis_names=set(worker_axes),
            check=True,
        )(state.params, state.opt_state["m_pkts"], state.step, batch, frac,
          key, lr)
        # apply the worker-sharded packet deltas in auto land (GSPMD
        # all-gathers the bf16 buffers — the cheap leg of RS+AG)
        p_leaves, treedef = jax.tree_util.tree_flatten(state.params)
        new_leaves = [
            p + ls._from_packets(d.astype(jnp.float32), p.shape, p.dtype)
            for p, d in zip(p_leaves, deltas)
        ]
        params = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return (
            TrainState(params, {"m_pkts": m_pkts}, mstep),
            {"loss": loss, "delivered_frac": realized},
        )

    rep = P()  # replicated w.r.t. the manual worker axes

    def step(state: TrainState, batch, frac, key, lr):
        if isinstance(state.opt_state, dict) and "m_pkts" in state.opt_state:
            return _zero_step(state, batch, frac, key, lr)
        params, opt_state, mstep, loss, realized = compat.shard_map(
            inner,
            mesh=mesh,
            in_specs=(rep, rep, rep, batch_specs, rep, rep, rep),
            out_specs=(rep, rep, rep, rep, rep),
            axis_names=set(worker_axes),
            check=True,
        )(state.params, state.opt_state, state.step, batch, frac, key, lr)
        return (
            TrainState(params, opt_state, mstep),
            {"loss": loss, "delivered_frac": realized},
        )

    return step
