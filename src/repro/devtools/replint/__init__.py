"""replint — AST-based invariant linter for this repo (DESIGN.md §13).

The simulator's correctness rests on hand-enforced invariants: bitwise
same-seed replay, generation-fenced pooled flows, tracker-off hot-path
parity, hashable frozen configs. ``replint`` mechanizes them as six
static checks over ``src/``:

  determinism     no wall clocks, global RNG, ``id()`` keys, or
                  set-iteration-order dependence in net/ and runtime/
  pool-reset      classes implementing the pooling ``reset()`` protocol
                  must reset every mutable attribute ``__init__`` makes
  gen-fence       ``meta["g"]`` only through ``repro.net.genfence``;
                  sim-registered closures in runtime/ carry a staleness
                  guard
  hotpath         functions marked ``# replint: hotpath`` allocate no
                  closures / comprehensions / f-strings off-tracker
  frozen-config   frozen dataclasses in config.py stay hashable
  design-ref      §N citations into DESIGN.md resolve to real sections

Findings are suppressed per line with ``# replint: ok(<rule>)`` — the
rule name is mandatory, and unused or malformed pragmas are themselves
findings. CLI: ``python -m repro.devtools.replint src/``.

Stdlib only; importing this package never touches the sim modules.
"""
from repro.devtools.replint.core import (
    Finding,
    RULES,
    iter_python_files,
    lint_file,
    lint_paths,
    rule_names,
)

__all__ = [
    "Finding",
    "RULES",
    "iter_python_files",
    "lint_file",
    "lint_paths",
    "rule_names",
]
