"""Qwen3-14B — dense with per-head QK-RMSNorm and GQA [hf:Qwen/Qwen3-8B]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    block_pattern=("A",),
    rope_theta=1e6,
    qk_norm=True,
    source="hf:Qwen/Qwen3-8B",
)

REDUCED = CONFIG.replace(
    name="qwen3-14b-reduced",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv=4,
    head_dim=32,
    d_ff=512,
    vocab=512,
)
