"""Config system for the LTP reproduction framework.

Plain dataclasses (no external deps). Every assigned architecture is described
by a ``ModelConfig``; the transport/protocol knobs live in ``NetConfig`` and
``LTPConfig``; training in ``TrainConfig``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``block_pattern`` drives the per-layer mixer choice; it is tiled to
    ``n_layers``.  Codes: 'A' full attention, 'W' sliding-window attention,
    'M' mamba1, 'M2' mamba2, 'L' MLA (deepseek latent attention).
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    block_pattern: Tuple[str, ...] = ("A",)
    window: int = 0                  # sliding window size for 'W' layers
    rope_theta: float = 1e4
    qk_norm: bool = False
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden (d_ff used if 0)
    first_dense_layers: int = 0      # leading dense layers before MoE starts
    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0               # mamba2 heads (d_inner // head size)
    # --- MLA (deepseek) ---
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- hybrid (zamba2): shared attention block every N mixer layers ---
    shared_attn_every: int = 0
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 0          # stubbed conv-frontend output length
    # --- vlm (qwen2-vl) ---
    vision_patches: int = 0          # stubbed ViT output length
    mrope_sections: Tuple[int, ...] = ()
    # --- misc ---
    norm_type: str = "rms"           # rms | ln
    mlp_type: str = "swiglu"         # swiglu | gelu
    pos_type: str = "rope"           # rope | mrope | learned | none
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""                 # citation

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, 128)

    @property
    def pattern_layers(self) -> Tuple[str, ...]:
        """Per-layer mixer codes, length n_layers."""
        reps = (self.n_layers + len(self.block_pattern) - 1) // len(self.block_pattern)
        return (self.block_pattern * reps)[: self.n_layers]

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class ObservabilityConfig:
    """Where runtime telemetry goes (DESIGN.md §12).

    ``tracker`` selects the sink backend by name — one of
    ``repro.obs.TRACKER_BACKENDS`` (``"none"``, ``"memory"``,
    ``"jsonl"``, ``"csv"``, ``"tensorboard"``) or a comma-separated
    list for fan-out. ``"none"`` is the zero-overhead default: the
    runtime holds no tracker object at all and runs are bitwise
    identical to a build without the observability layer.

    Frozen + hashable on purpose: this config rides inside
    ``LTPConfig``, which is part of the jit-cache key in
    ``runtime/step.py``.
    """

    tracker: str = "none"
    # file backends write to ``path`` when set, else
    # ``<out_dir>/<run_name>.<ext>``
    out_dir: str = "runs"
    path: Optional[str] = None
    run_name: str = "run"
    # histogram reservoir size for the metrics registry (Algorithm R)
    reservoir: int = 1024
    # sample per-trunk queue depths on the ``Sim.every`` grid (feeds the
    # per-trunk counter tracks in the Chrome trace). Only read when a
    # tracker is active — with ``tracker="none"`` the queue events stay
    # exactly as before.
    sample_trunks: bool = True


@dataclass(frozen=True)
class LTPConfig:
    """Paper knobs (§III). Defaults follow the paper where it gives numbers."""

    enabled: bool = True
    mtu_bytes: int = 1500
    header_bytes: int = 9            # LTP adds ~9B (68 bit) header over UDP
    udp_ip_overhead: int = 28
    packet_floats: int = 360         # payload floats, float-aligned (padding bubble)
    data_pct_threshold: float = 0.8  # Early Close received-data percentage
    lt_init_rtprop_mult: float = 1.5 # LTThreshold_init = 1.5*RTprop + Size/BtlBw
    deadline_c_ms: float = 30.0      # C: 30ms DCN / 100ms WAN
    compensation: str = "paper"      # paper | count | expected
    # Phase-aware loss tolerance (beyond-paper, DESIGN.md §3.3): the
    # effective received-pct threshold ramps linearly from
    # ``data_pct_threshold`` at training progress 0 to this value at
    # progress 1 (late training tolerates less gradient loss). None
    # disables the ramp — the paper's fixed threshold.
    phase_final_pct_threshold: Optional[float] = None
    # Staleness-aware compensation weighting (beyond-paper, DESIGN.md §8):
    # under async / bounded-staleness aggregation a worker's contribution
    # to the PS reduction is damped by 1 / (1 + staleness_comp * s) where
    # s is the gradient's staleness in iterations. 0 disables damping
    # (every admitted gradient weighs 1, the classic SSP reduction).
    staleness_comp: float = 0.0
    error_feedback: bool = False     # beyond-paper
    critical_per_tensor: int = 1     # first/last packet(s) of each tensor marked critical
    # PS-side aggregation backend (DESIGN.md §7/§9): "python" is the jnp
    # reference; "pallas" routes the bubble-fill + masked multi-worker
    # reduction through the fused kernels in ``repro.kernels``; "auto"
    # picks per call site — python below the measured crossover stream
    # size (``ltp_sync.AUTO_CROSSOVER_ELEMS``), pallas above it, and
    # always python in interpret mode — so the kernel path can never be
    # a regression.
    sync_backend: str = "python"     # python | pallas | auto
    # Pallas interpret mode: True executes kernel bodies in the Python
    # interpreter (the only option on CPU); set False on a real TPU to
    # compile the fused tiles.
    kernel_interpret: bool = True
    seed: int = 0
    # telemetry sink selection (DESIGN.md §12); None == all defaults
    # (tracker "none", zero overhead)
    obs: Optional[ObservabilityConfig] = None

    def runtime(self) -> "RuntimeConfig":
        """The runtime/cluster half of this config as a ``RuntimeConfig``."""
        return RuntimeConfig(**{f.name: getattr(self, f.name)
                                for f in dataclasses.fields(RuntimeConfig)})

    def with_runtime(self, rc: Optional["RuntimeConfig"]) -> "LTPConfig":
        """Overlay a ``RuntimeConfig`` onto this protocol config.

        The back-compat bridge for the LTPConfig split (DESIGN.md §11):
        entry points taking the new ``runtime_cfg=`` fold it in here, so
        every downstream read of ``ltp.staleness_comp`` /
        ``ltp.sync_backend`` / ... keeps working unchanged whether the
        caller used the old combined config or the new split one."""
        if rc is None:
            return self
        return dataclasses.replace(
            self, **{f.name: getattr(rc, f.name)
                     for f in dataclasses.fields(RuntimeConfig)})


@dataclass(frozen=True)
class RuntimeConfig:
    """Runtime/cluster-side knobs split out of ``LTPConfig`` (DESIGN.md
    §11): how the PS aggregates and the trainer syncs — none of these
    change a byte on the wire. ``LTPConfig`` keeps the same-named fields
    as the back-compat combined surface; pass a ``RuntimeConfig`` via
    ``runtime_cfg=`` to ``ClusterRuntime`` / ``PSTrainer`` to override
    them (``LTPConfig.with_runtime``)."""

    # staleness-damped async/SSP reduction weighting (DESIGN.md §8)
    staleness_comp: float = 0.0
    error_feedback: bool = False
    # PS aggregation backend: python | pallas | auto (DESIGN.md §7/§9)
    sync_backend: str = "python"
    kernel_interpret: bool = True
    seed: int = 0
    # telemetry sink selection (DESIGN.md §12); None == tracker "none"
    obs: Optional[ObservabilityConfig] = None


@dataclass(frozen=True)
class NetConfig:
    """Simulated physical network (per-link)."""

    bandwidth_gbps: float = 10.0
    rtprop_ms: float = 1.0
    loss_rate: float = 0.0           # non-congestion random loss
    queue_pkts: int = 256            # droptail switch queue
    mtu_bytes: int = 1500


@dataclass(frozen=True)
class TrainConfig:
    batch: int = 32
    seq: int = 256
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 0.0
    optimizer: str = "sgdm"          # sgdm | adamw
    steps: int = 100
    lr_decay_every: int = 0          # epochs; paper: x0.8 every 10 epochs
    lr_decay: float = 0.8
    seed: int = 0
    remat: bool = True


@dataclass(frozen=True)
class FaultConfig:
    """Fault-injection knobs for the elastic runtime (DESIGN.md §10).

    Converted into a concrete ``runtime.faults.FaultSchedule`` once the
    run horizon is known (``FaultSchedule.random`` takes ``t_end``); the
    all-zero default draws an empty schedule, which the runtime treats
    exactly like no fault layer at all.
    """

    crash_rate: float = 0.0          # worker crashes, per worker-second
    rejoin_after_s: Optional[float] = None  # crashed slots rejoin after this
    leave_rate: float = 0.0          # graceful departures, per worker-second
    ps_fail_at: Tuple[float, ...] = ()      # sim times of PS failures
    ps_recovery_s: float = 0.05      # PS downtime before checkpoint failover
    checkpoint_every_s: float = 0.0  # snapshot grid (0 = initial state only)
    min_active: int = 1              # random schedules never go below this
    seed: int = 0


@dataclass(frozen=True)
class NetFaultConfig:
    """Network-layer fault-injection knobs (DESIGN.md §14).

    Converted into a concrete ``net.netfaults.LinkFaultSchedule`` once
    the run horizon and topology are known
    (``netfault_schedule_from_config``); the all-zero default draws an
    empty schedule, which the runtime treats exactly like no fabric
    fault plane at all (zero-fault parity).
    """

    link_down_rate: float = 0.0      # uplink admin-downs, per link-second
    link_recover_s: float = 0.05     # downtime before the link comes back
    flap_rate: float = 0.0           # uplink flap episodes, per link-second
    flap_period_s: float = 0.02      # flap square-wave period
    flap_duty: float = 0.5           # fraction of each period spent down
    flap_duration_s: float = 0.2     # length of one flap episode
    degrade_rate: float = 0.0        # degrade episodes, per link-second
    degrade_rate_factor: float = 0.25  # line-rate multiplier while degraded
    degrade_extra_loss: float = 0.05   # added loss probability
    degrade_duration_s: float = 0.2
    switch_crash_at: Tuple[float, ...] = ()  # sim times of ToR crashes
    switch_recover_s: float = 0.05
    partition_at: Tuple[float, ...] = ()     # sim times of rack partitions
    partition_heal_s: float = 0.1
    max_cut: int = 1                 # concurrent-severed-racks ceiling
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    ltp: LTPConfig = field(default_factory=LTPConfig)
    net: NetConfig = field(default_factory=NetConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    faults: Optional[FaultConfig] = None
    net_faults: Optional[NetFaultConfig] = None
