"""Sanctioned accessors for the flow-generation fence (DESIGN.md §9.2).

Pooled flows are reused across iterations; each life bumps the flow's
generation, the sender stamps it into every outgoing packet's meta, the
receiver echoes it in ACKs, and stops carry it too. Any packet or echo
whose generation differs from the current one belongs to a previous
life and MUST be dropped — PR 5's fence gaps (and the replint
``gen-fence`` rule that now mechanizes them, DESIGN.md §13) exist
because three hand-rolled copies of this compare drifted apart.

Every read/write of the generation key goes through this module:

* write sites put ``GEN_KEY`` in the meta dict literal
  (``meta={"t": now, GEN_KEY: self.gen}``) — a name load, so the
  per-packet hot path pays nothing over the raw string;
* read sites call :func:`is_stale` (packet metas), :func:`echo_stale`
  (ACK echo dicts), or :func:`gen_of` (raw extraction).

The module is import-light on purpose: senders, receivers, and the
runtime transport all pull it into per-packet code.
"""
from __future__ import annotations

from typing import Any, Optional

#: the meta key carrying a pooled flow's generation
GEN_KEY = "g"


def gen_of(meta: Any, default: Optional[int] = None) -> Optional[int]:
    """The generation stamped in ``meta``, or ``default`` when the meta
    is not a dict or carries no generation (unpooled traffic)."""
    if isinstance(meta, dict):
        return meta.get(GEN_KEY, default)
    return default


def has_gen(meta: Any) -> bool:
    """True when ``meta`` carries a generation stamp."""
    return isinstance(meta, dict) and GEN_KEY in meta


def is_stale(meta: Any, gen: int) -> bool:
    """True when ``meta`` was stamped by a previous life of a pooled
    flow. Unstamped traffic (no meta / no key) is *current*: only an
    explicit mismatching stamp fences a packet."""
    return isinstance(meta, dict) and meta.get(GEN_KEY, gen) != gen


def echo_stale(echo: Any, gen: int) -> bool:
    """:func:`is_stale` over an ACK's echoed request meta. Split out so
    ACK-path call sites read as what they check, and so the two shapes
    can diverge later without touching callers."""
    return isinstance(echo, dict) and echo.get(GEN_KEY, gen) != gen
