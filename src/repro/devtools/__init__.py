"""Developer tooling that ships with the repo but never imports from
(or into) the simulation fast path.

``repro.devtools.replint`` is the AST-based invariant linter
(DESIGN.md §13); it is pure stdlib and safe to run anywhere.
"""
