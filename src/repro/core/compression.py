"""Gradient-compression baselines from the paper's §II-C (Fig 5): Top-k and
Random-k sparsification, with optional error feedback — used to reproduce
the accuracy/throughput comparison that motivates LTP's Random-k-like
behaviour, and to demonstrate LTP composing with compression (§VI-A).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _flatten(grads) -> Tuple[jnp.ndarray, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    flat = jnp.concatenate([x.astype(jnp.float32).ravel() for x in leaves])
    return flat, (treedef, [(x.shape, x.dtype) for x in leaves])


def _unflatten(flat, meta):
    treedef, shapes = meta
    out, off = [], 0
    for shape, dtype in shapes:
        sz = int(np.prod(shape)) if shape else 1
        out.append(flat[off : off + sz].reshape(shape).astype(dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


import numpy as np  # noqa: E402  (used in _unflatten)


def random_k(grads, k_frac: float, key, residual=None):
    """Keep a random k-fraction of gradient elements (Random-k [26]).

    Returns (sparse_grads, new_residual). Residual (error feedback) is in
    flat space; pass the previous call's output back in.
    """
    flat, meta = _flatten(grads)
    if residual is not None:
        flat = flat + residual
    mask = (jax.random.uniform(key, flat.shape) < k_frac).astype(flat.dtype)
    kept = flat * mask
    new_res = flat - kept
    return _unflatten(kept, meta), new_res


def top_k(grads, k_frac: float, residual=None, *, sample_cap: int = 1 << 20):
    """Keep the top k-fraction by |value| (Top-k [21]).

    The threshold is the (1-k) quantile of |g|; for very large gradients it
    is estimated on a strided sample (exact enough for the Fig-5 sweep and
    far cheaper than a full sort — mirroring the paper's note that Top-k's
    selection overhead is its weakness).
    """
    flat, meta = _flatten(grads)
    if residual is not None:
        flat = flat + residual
    a = jnp.abs(flat)
    if flat.size > sample_cap:
        stride = flat.size // sample_cap
        a_est = a[::stride]
    else:
        a_est = a
    thresh = jnp.quantile(a_est, jnp.clip(1.0 - k_frac, 0.0, 1.0))
    mask = (a >= thresh).astype(flat.dtype)
    kept = flat * mask
    new_res = flat - kept
    return _unflatten(kept, meta), new_res


def measure_density(grads) -> jnp.ndarray:
    flat, _ = _flatten(grads)
    return jnp.mean((flat != 0).astype(jnp.float32))
