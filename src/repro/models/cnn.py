"""papernet — ResNet-style mini CNN for the paper's own CIFAR-10 workload.

BatchNorm is replaced by per-position channel LayerNorm so the model is
deterministic under any data sharding (BN's cross-batch statistics would
couple workers through something other than the gradient sync the paper
studies).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import Params, split_keys
from repro.models.sharding import ShardCtx, NULL_CTX


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    return (jax.random.normal(key, (kh, kw, cin, cout)) * (2.0 / fan_in) ** 0.5).astype(dtype)


def _conv(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _chan_norm(x, scale, offset, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + offset


def _norm_p(c):
    return {"scale": jnp.ones((c,), jnp.float32), "offset": jnp.zeros((c,), jnp.float32)}


def init(key, cfg: ModelConfig) -> Params:
    """3 stages x (n_layers//3) basic blocks; widths (w, 2w, 4w)."""
    w = cfg.d_model
    blocks_per_stage = max(1, cfg.n_layers // 3)
    ks = split_keys(key, 2 + 3 * blocks_per_stage * 3)
    ki = iter(ks)
    params: Params = {
        "stem": {"conv": _conv_init(next(ki), 3, 3, 3, w), **_norm_p(w)},
        "stages": [],
    }
    cin = w
    for s in range(3):
        cout = w * (2**s)
        stage = []
        for b in range(blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            blk = {
                "conv1": _conv_init(next(ki), 3, 3, cin, cout),
                "n1": _norm_p(cout),
                "conv2": _conv_init(next(ki), 3, 3, cout, cout),
                "n2": _norm_p(cout),
            }
            if stride != 1 or cin != cout:
                blk["proj"] = _conv_init(next(ki), 1, 1, cin, cout)
            stage.append(blk)
            cin = cout
        params["stages"].append(stage)
    params["fc"] = (jax.random.normal(next(ki), (cin, cfg.vocab)) * 0.01).astype(jnp.float32)
    params["fc_b"] = jnp.zeros((cfg.vocab,), jnp.float32)
    return params


def forward(cfg: ModelConfig, params: Params, images, *, ctx: ShardCtx = NULL_CTX):
    """images: (B, 32, 32, 3) float32 -> logits (B, classes)."""
    x = ctx.batch_only(images)
    st = params["stem"]
    x = jax.nn.relu(_chan_norm(_conv(x, st["conv"]), st["scale"], st["offset"]))
    for s, stage in enumerate(params["stages"]):
        for b, blk in enumerate(stage):
            stride = 2 if (s > 0 and b == 0) else 1
            h = jax.nn.relu(
                _chan_norm(_conv(x, blk["conv1"], stride), blk["n1"]["scale"], blk["n1"]["offset"])
            )
            h = _chan_norm(_conv(h, blk["conv2"]), blk["n2"]["scale"], blk["n2"]["offset"])
            skip = _conv(x, blk["proj"], stride) if "proj" in blk else x
            x = jax.nn.relu(h + skip)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["fc"] + params["fc_b"]


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, Any], *,
            ctx: ShardCtx = NULL_CTX, remat: bool = False):
    logits = forward(cfg, params, batch["images"], ctx=ctx).astype(jnp.float32)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    logits = forward(cfg, params, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
