"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("n,p", [(130, 360), (256, 384), (7, 33), (1000, 128),
                                 (1, 1), (513, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dropfill(n, p, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    pkts = jax.random.normal(k1, (n, p)).astype(dtype)
    mask = (jax.random.uniform(k2, (n,)) < 0.7).astype(jnp.float32)
    scale = jax.random.uniform(k3, (n,), minval=0.5, maxval=2.0)
    out = ops.ltp_dropfill(pkts, mask, scale)
    expect = ref.dropfill_ref(pkts, mask, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_dropfill_zero_fills_lost():
    pkts = jnp.ones((64, 360))
    mask = jnp.zeros((64,)).at[::2].set(1.0)
    out = np.asarray(ops.ltp_dropfill(pkts, mask))
    assert np.all(out[1::2] == 0) and np.all(out[::2] == 1)


@pytest.mark.parametrize("w,n,p", [(8, 130, 360), (4, 64, 384), (16, 33, 100),
                                   (2, 5, 7)])
@pytest.mark.parametrize("comp", ["paper", "count"])
def test_packet_reduce(w, n, p, comp):
    k1, k2 = jax.random.split(KEY)
    pkts = jax.random.normal(k1, (w, n, p), jnp.float32)
    mask = (jax.random.uniform(k2, (w, n)) < 0.8).astype(jnp.float32)
    out = ops.ltp_packet_reduce(pkts, mask, compensation=comp)
    expect = ref.packet_reduce_ref(pkts, mask, compensation=comp)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


def test_packet_reduce_full_delivery_is_mean():
    pkts = jnp.stack([jnp.full((16, 8), float(w)) for w in range(4)])
    mask = jnp.ones((4, 16))
    out = np.asarray(ops.ltp_packet_reduce(pkts, mask))
    np.testing.assert_allclose(out, 1.5)


def test_packet_reduce_count_unbiased_single_worker():
    pkts = jnp.stack([jnp.full((8, 4), 5.0), jnp.zeros((8, 4))])
    mask = jnp.stack([jnp.ones((8,)), jnp.zeros((8,))])
    out = np.asarray(ops.ltp_packet_reduce(pkts, mask, compensation="count"))
    np.testing.assert_allclose(out, 5.0)   # only deliverer counts


@pytest.mark.parametrize("shape", [(1000,), (37, 23), (4096,), (3, 5, 7)])
@pytest.mark.parametrize("k", [0.0, 0.3, 1.0])
def test_randomk(shape, k):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, shape, jnp.float32)
    u = jax.random.uniform(k2, shape)
    out = ops.randomk_sparsify(x, u, k)
    expect = ref.randomk_ref(x, u, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_ops_donate_variants_match_and_cache_separately():
    """donate=True must be numerically identical to donate=False (on
    CPU donation is a no-op; on TPU it aliases the input buffer), and
    each (interpret, donate) variant gets its own cached jit so flags
    can't cross-contaminate compiled executables."""
    from repro.kernels import ops

    rng = np.random.default_rng(7)
    pkts = jnp.asarray(rng.normal(size=(33, 250)).astype(np.float32))
    mask = jnp.asarray((rng.random(33) < 0.6).astype(np.float32))
    ref = ops.ltp_dropfill(pkts, mask)
    # fresh buffer per donating call: a donated array may be consumed
    don = ops.ltp_dropfill(jnp.array(pkts), mask, donate=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(don))

    pkts_w = jnp.asarray(rng.normal(size=(3, 17, 250)).astype(np.float32))
    mask_w = jnp.asarray((rng.random((3, 17)) < 0.6).astype(np.float32))
    ref = ops.ltp_packet_reduce(pkts_w, mask_w, compensation="count")
    don = ops.ltp_packet_reduce(jnp.array(pkts_w), mask_w,
                                compensation="count", donate=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(don))

    assert ops._variant("dropfill", True, False) is \
        ops._variant("dropfill", True, False)
    assert ops._variant("dropfill", True, False) is not \
        ops._variant("dropfill", True, True)
