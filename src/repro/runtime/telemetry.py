"""Structured runtime telemetry (DESIGN.md §8).

Every actor/policy event in a ``ClusterRuntime`` run lands here as one
flat dict — an append-only stream the benchmarks and tests consume
directly, and ``summary()`` reduces into the scalar fields the sweep
rows carry.

Event schema — common fields ``kind`` (str) and ``t`` (sim seconds),
plus per-kind payload:

  compute_start   worker, iteration, dt
  grad_ready      worker, iteration            (compute leg done)
  grad_arrived    worker, iteration, staleness, delivered
  apply           step, n_grads, staleness_max, staleness_mean, loss
  early_close     worker|shard, iteration, delivered   (EC fire time = t)
  stale_drop      worker, iteration, staleness (SSP rejected the grad)
  block/unblock   worker, iteration            (SSP/BSP gating)
  queue           depth [, net_depth]          (PS pending / trunk pkts)
  masks           [worker,] iteration, digest  (DES delivery-mask hash)

Sampling discipline (DESIGN.md §9): per-event hooks record O(1)
payloads only; anything that walks topology state (trunk queue depths)
is sampled on the runtime's ``Sim.every`` wall grid, never per event.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class Telemetry:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: List[dict] = []

    def record(self, kind: str, t: float, **fields) -> None:
        if not self.enabled:
            return
        self.events.append({"kind": kind, "t": float(t), **fields})

    def of(self, kind: str) -> List[dict]:
        return [e for e in self.events if e["kind"] == kind]

    def blocked_seconds(self) -> float:
        """Total worker-seconds spent blocked on the staleness/barrier
        gate (paired block/unblock events; an unmatched block counts to
        the last event's timestamp)."""
        t_end = self.events[-1]["t"] if self.events else 0.0
        open_t: Dict[int, float] = {}
        total = 0.0
        for e in self.events:
            if e["kind"] == "block":
                open_t.setdefault(e["worker"], e["t"])
            elif e["kind"] == "unblock":
                t0 = open_t.pop(e["worker"], None)
                if t0 is not None:
                    total += e["t"] - t0
        total += sum(t_end - t0 for t0 in open_t.values())
        return total

    def summary(self) -> Dict[str, float]:
        """Scalar reduction of the stream — what a sweep row carries."""
        applies = self.of("apply")
        stale = [e["staleness_max"] for e in applies]
        stale_mean = [e["staleness_mean"] for e in applies]
        queues = self.of("queue")
        closes = self.of("early_close")
        out = {
            "n_events": len(self.events),
            "n_applies": len(applies),
            "n_early_close": len(closes),
            "n_stale_drops": len(self.of("stale_drop")),
            "blocked_s": round(self.blocked_seconds(), 6),
            "staleness_max": int(max(stale)) if stale else 0,
            "staleness_mean": round(float(np.mean(stale_mean)), 4)
            if stale_mean else 0.0,
        }
        if queues:
            depths = [e["depth"] for e in queues]
            out["queue_depth_mean"] = round(float(np.mean(depths)), 3)
            out["queue_depth_max"] = float(np.max(depths))
            net = [e["net_depth"] for e in queues if "net_depth" in e]
            if net:
                out["net_queue_max_pkts"] = round(float(np.max(net)), 2)
        if closes:
            out["early_close_mean_delivered"] = round(
                float(np.mean([e["delivered"] for e in closes])), 4)
        return out
