"""CrossTrafficSource lifecycle: start/stop idempotence, restart safety,
and offered_bps accounting under per-packet and chunked-train injection."""
import numpy as np
import pytest

from repro.net.simcore import CrossTrafficSource, Pipe, Sim


def _setup(train_len=1, load=0.5, rate=1e9, queue=10_000, seed=4):
    sim = Sim()
    pipe = Pipe(sim, rate, 0.1e-3, 0.0, queue, np.random.default_rng(seed))
    src = CrossTrafficSource(sim, pipe, load,
                             rng=np.random.default_rng(seed + 1),
                             on_mean=2e-3, off_mean=2e-3,
                             train_len=train_len)
    return sim, pipe, src


@pytest.mark.parametrize("train_len", [1, 8])
def test_start_is_idempotent(train_len):
    """A second start() on a running source must not double the burst
    chain: injections match a single-start twin exactly."""
    sim1, _, one = _setup(train_len)
    one.start()
    sim1.run(until=0.05)
    sim2, _, two = _setup(train_len)
    two.start()
    two.start()
    two.start()
    sim2.run(until=0.05)
    assert two.n_injected == one.n_injected > 0


@pytest.mark.parametrize("train_len", [1, 8])
def test_stop_is_idempotent_and_freezes_injection(train_len):
    sim, _, src = _setup(train_len)
    src.start()
    sim.run(until=0.02)
    src.stop()
    src.stop()
    frozen = src.n_injected
    assert frozen > 0
    sim.run()          # drain: pending bursts/injections must be no-ops
    assert src.n_injected == frozen
    assert src.n_delivered == frozen   # lossless pipe: all in-flight land


def test_stop_before_start_is_safe():
    sim, _, src = _setup()
    src.stop()
    sim.run()
    assert src.n_injected == 0
    src.start()        # still usable after a premature stop
    sim.run(until=0.01)
    assert src.n_injected > 0


def test_restart_after_stop_resumes_single_chain():
    sim, _, src = _setup(train_len=4)
    src.start()
    sim.run(until=0.02)
    src.stop()
    sim.run(until=0.04)
    mid = src.n_injected
    src.start()
    horizon = 1.0
    sim.run(until=0.04 + horizon)
    resumed_bps = (src.n_injected - mid) * src.pkt_bytes * 8.0 / horizon
    # one chain, not two: the resumed long-run rate tracks offered_bps
    # (a doubled burst chain would land near 2x)
    assert resumed_bps == pytest.approx(src.offered_bps, rel=0.4)


def test_stale_generation_injections_are_orphaned():
    """stop()+start() while a prior life's injection events are still in
    the heap must not double the offered load: old-generation events are
    no-ops."""
    sim, _, src = _setup()
    src.start()
    old_gen = src._gen
    src.stop()
    src.start()
    assert src._gen == old_gen + 1
    n = src.n_injected
    src._inject(old_gen)                     # orphaned per-packet event
    src._inject_train(4, 1e-6, old_gen)      # orphaned chunked train
    assert src.n_injected == n
    src._inject(src._gen)                    # current life still injects
    assert src.n_injected == n + 1


@pytest.mark.parametrize("train_len", [1, 8])
def test_offered_bps_accounting(train_len):
    """Long-run injected rate tracks offered_bps = load * duty * rate for
    both the per-packet and the chunked-train engines, and every injected
    packet is accounted for (delivered or dropped at the pipe)."""
    sim, pipe, src = _setup(train_len, load=0.4)
    assert src.offered_bps == pytest.approx(0.4 * 0.5 * pipe.rate)
    horizon = 2.0
    src.start()
    sim.run(until=horizon)
    src.stop()
    sim.run()          # drain in-flight
    injected_bps = src.n_injected * src.pkt_bytes * 8.0 / horizon
    assert injected_bps == pytest.approx(src.offered_bps, rel=0.25)
    assert src.n_injected == (src.n_delivered + pipe.n_dropped_queue
                              + pipe.n_dropped_loss)


def test_offered_bps_with_explicit_duty():
    sim = Sim()
    pipe = Pipe(sim, 1e9, 1e-4, 0.0, 100, np.random.default_rng(0))
    src = CrossTrafficSource(sim, pipe, 0.8, on_mean=5e-3, duty=0.25)
    assert src.duty == pytest.approx(0.25)
    assert src.off_mean == pytest.approx(5e-3 * 3)
    assert src.offered_bps == pytest.approx(0.8 * 0.25 * 1e9)
