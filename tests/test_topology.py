"""Topology-first construction surface (DESIGN.md §11).

Pins the builder API: ``flat``/``multi_ps``/``rack_spine`` validation,
the rack-grid geometry helpers, the attainable-share math that seeds the
Early-Close LT thresholds, the one ``resolve_topology`` rule every entry
point routes through, and the deprecation shims for the old construction
kwargs (``n_ps=`` / ``spec=``).
"""
import dataclasses

import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig, RuntimeConfig
from repro.net.simcore import Sim
from repro.net.topology import (
    APIDeprecationWarning,
    GatherSpec,
    Topology,
    as_topology,
    flat,
    multi_ps,
    rack_spine,
    resolve_topology,
)
from repro.runtime.transport import DESTransport

NET = NetConfig(10, 1, 0.001, 4096)
BW = NET.bandwidth_gbps * 1e9


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------


def test_flat_builder():
    t = flat()
    assert isinstance(t, Topology) and isinstance(t, GatherSpec)
    assert t.n_ps == 1 and not t.hierarchical and t.name == "flat"
    assert t.n_workers is None
    t4 = flat(n_ps=4)
    assert t4.n_ps == 4 and t4.name == "flat_ps4"
    with pytest.raises(ValueError, match="n_ps"):
        flat(n_ps=0)


def test_multi_ps_is_flat_sharded():
    t = multi_ps(8)
    assert t.n_ps == 8 and not t.hierarchical


def test_rack_spine_builder_and_geometry():
    t = rack_spine(4, 8, oversub=4.0, n_ps=2, ps_racks=(0, 3))
    assert t.hierarchical and t.n_workers == 32
    assert t.name == "rack4x8_agg_os4"
    assert t.rack_of(0) == 0 and t.rack_of(7) == 0 and t.rack_of(8) == 1
    assert t.rack_members(3) == list(range(24, 32))
    assert t.ps_rack(0) == 0 and t.ps_rack(1) == 3
    assert t.uplink_bps(NET) == pytest.approx(8 * BW / 4.0)
    t.validate_workers(32)
    with pytest.raises(ValueError, match="rack grid"):
        t.validate_workers(16, "caller")
    noagg = rack_spine(2, 4, agg=False)
    assert not noagg.inetwork_agg and noagg.name == "rack2x4_os4"
    assert noagg.ps_rack(0) is None


def test_rack_spine_validation():
    with pytest.raises(ValueError, match="positive"):
        rack_spine(0, 8)
    with pytest.raises(ValueError, match="positive"):
        rack_spine(4, 0)
    with pytest.raises(ValueError, match="oversub"):
        rack_spine(4, 8, oversub=0.0)
    with pytest.raises(ValueError, match="n_ps"):
        rack_spine(4, 8, n_ps=0)
    with pytest.raises(ValueError, match="per shard"):
        rack_spine(4, 8, n_ps=2, ps_racks=(0,))
    with pytest.raises(ValueError, match="out of range"):
        rack_spine(4, 8, n_ps=1, ps_racks=(4,))


# ---------------------------------------------------------------------------
# attainable-share math (feeds the LT init formula)
# ---------------------------------------------------------------------------


def test_worker_share_flat_matches_fair_share():
    assert flat().worker_share_bps(0, 16, NET) == pytest.approx(BW / 16)


def test_worker_share_rack_no_agg_pays_uplink_split():
    t = rack_spine(4, 8, oversub=4.0, n_ps=2, agg=False)
    up = t.uplink_bps(NET)
    expect = min(BW / 32, up / (8 * 2))
    assert t.worker_share_bps(5, 32, NET) == pytest.approx(expect)


def test_worker_share_rack_agg_rides_merged_flow():
    t = rack_spine(4, 8, oversub=4.0, n_ps=2, agg=True)
    expect = min(t.uplink_bps(NET) / 2, BW / 4)
    assert t.worker_share_bps(5, 32, NET) == pytest.approx(expect)
    # aggregation must never make the modeled share WORSE than per-worker
    noagg = rack_spine(4, 8, oversub=4.0, n_ps=2, agg=False)
    assert (t.worker_share_bps(5, 32, NET)
            >= noagg.worker_share_bps(5, 32, NET))


def test_worker_share_heterogeneous_access_cap():
    mult = np.full(8, 0.1)
    t = flat(worker_rate_mult=mult)
    assert t.heterogeneous
    assert t.worker_share_bps(3, 8, NET) == pytest.approx(BW * 0.1)


# ---------------------------------------------------------------------------
# coercion + resolution rule
# ---------------------------------------------------------------------------


def test_as_topology_copies_spec_fields():
    spec = GatherSpec(n_ps=4, cross_traffic_load=0.5,
                      worker_delay_ms=np.arange(8.0))
    t = as_topology(spec)
    assert isinstance(t, Topology) and not t.hierarchical
    assert t.n_ps == 4 and t.cross_traffic_load == 0.5
    np.testing.assert_array_equal(t.worker_delay_ms, np.arange(8.0))
    # identity on an already-built Topology
    built = rack_spine(2, 4)
    assert as_topology(built) is built


def test_resolve_topology_precedence():
    topo = rack_spine(2, 4)
    assert resolve_topology(topo) is topo
    # default: single-PS flat, no warning
    assert resolve_topology(None).n_ps == 1
    with pytest.raises(ValueError, match="not both"):
        resolve_topology(topo, n_ps=2, owner="X")
    with pytest.raises(ValueError, match="not both"):
        resolve_topology(topo, spec=GatherSpec(), owner="X")


def test_resolve_topology_deprecated_aliases_warn():
    with pytest.warns(APIDeprecationWarning, match="n_ps"):
        t = resolve_topology(None, n_ps=4, owner="X")
    assert t.n_ps == 4
    spec = GatherSpec(n_ps=2)
    with pytest.warns(APIDeprecationWarning, match="spec"):
        t = resolve_topology(None, spec=spec, owner="X")
    assert t.n_ps == 2
    with pytest.warns(APIDeprecationWarning):
        with pytest.raises(ValueError, match="contradicts"):
            resolve_topology(None, spec=spec, n_ps=4, owner="X")


def test_destransport_deprecated_nps_shim():
    with pytest.warns(APIDeprecationWarning, match="DESTransport"):
        tr = DESTransport(Sim(), NET, LTPConfig(), "ltp", 4, 1e5, n_ps=2)
    assert tr.n_ps == 2
    # new spelling: silent
    tr = DESTransport(Sim(), NET, LTPConfig(), "ltp", 4, 1e5,
                      topology=multi_ps(2))
    assert tr.n_ps == 2


def test_destransport_rejects_mismatched_rack_grid():
    with pytest.raises(ValueError, match="rack grid"):
        DESTransport(Sim(), NET, LTPConfig(), "ltp", 6, 1e5,
                     topology=rack_spine(2, 4))


# ---------------------------------------------------------------------------
# LTPConfig protocol/runtime split
# ---------------------------------------------------------------------------


def test_ltpconfig_runtime_view():
    ltp = LTPConfig(staleness_comp=0.5, error_feedback=True, seed=9)
    rc = ltp.runtime()
    assert isinstance(rc, RuntimeConfig)
    assert rc.staleness_comp == 0.5 and rc.error_feedback and rc.seed == 9


def test_with_runtime_overlay():
    base = LTPConfig()
    rc = RuntimeConfig(staleness_comp=0.7, sync_backend="jit",
                       kernel_interpret=False)
    merged = base.with_runtime(rc)
    assert merged.staleness_comp == 0.7
    assert merged.sync_backend == "jit" and not merged.kernel_interpret
    # protocol fields untouched
    assert merged.data_pct_threshold == base.data_pct_threshold
    assert merged.deadline_c_ms == base.deadline_c_ms
    # None -> identity (no silent reset of protocol-side defaults)
    assert base.with_runtime(None) is base
    # every RuntimeConfig field must exist on LTPConfig (the overlay
    # copies by name — a field rename on one side must fail loudly here)
    ltp_fields = {f.name for f in dataclasses.fields(LTPConfig)}
    rc_fields = {f.name for f in dataclasses.fields(RuntimeConfig)}
    assert rc_fields <= ltp_fields
