"""Worker / PS actors on the runtime's shared event clock (DESIGN.md §8).

A ``WorkerActor`` is the per-worker state machine: (policy gate) ->
fetch params -> compute (sampled from the compute model) -> hand the
gradient to the transport -> immediately attempt the next iteration.
Whether that attempt proceeds is the aggregation policy's call — bsp
blocks until the barrier commits, ssp blocks when the worker runs too
far ahead, async never blocks.

The ``PSActor`` is the admission side: every arriving gradient goes
through the policy, ready batches are folded into the model by the
runtime (which owns the JAX state), and too-stale arrivals are counted
out. Both actors only *schedule*; all numerical work lives in
``ClusterRuntime``.

Fault lifecycle (DESIGN.md §10): a worker slot moves through
``joining -> active -> draining -> dead``. ``crash()`` is the hard
transition (compute cancelled, in-flight traffic fenced by the
transport's generation bump); ``retire()`` is the graceful leave (the
current iteration drains, then the slot goes dead); ``rejoin()``
re-activates a dead slot at the committed frontier, charging the
compute model's ``rejoin_penalty_s`` to the first iteration back.
With no faults scheduled every slot stays ``active`` for the whole run
and none of these paths execute.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

from repro.runtime.policies import PendingGrad

if TYPE_CHECKING:
    from repro.runtime.runtime import ClusterRuntime


class WorkerActor:
    def __init__(self, rt: "ClusterRuntime", idx: int):
        self.rt = rt
        self.idx = idx
        self.it = 0
        self.blocked = False
        self.busy = False      # a compute event for self.it is in flight
        self.params_version = 0
        self.params_snap = None
        self.finished = False
        self.state = "active"  # joining | active | draining | dead
        self._compute_eid = None
        self._rejoin_pending = False  # charge rejoin_penalty_s next compute
        # pre-bound instrument (DESIGN.md §12): attribute deref + one
        # reservoir observe per compute — never a name lookup per event.
        # None when no tracker is attached, so the no-observability path
        # pays a single is-None branch.
        self._h_compute = (rt.metrics.histogram("worker/compute_s")
                           if rt.tracker is not None else None)

    def start(self) -> None:
        self._try_begin()

    # -- fault lifecycle ----------------------------------------------------
    def crash(self) -> None:
        """Hard failure: the in-flight compute event is cancelled and the
        slot goes dead. Transport fencing is the runtime's job."""
        rt = self.rt
        if self.state == "dead":
            return
        self.state = "dead"
        if self._compute_eid is not None:
            rt.sim.cancel(self._compute_eid)
            self._compute_eid = None
        self.busy = False
        if self.blocked:
            self.blocked = False
            rt._blocked.discard(self.idx)
        rt.tel.record("lifecycle", rt.sim.now, worker=self.idx,
                      state="dead", iteration=self.it, reason="crash")

    def retire(self) -> None:
        """Graceful leave: finish the current iteration (its gradient
        still counts), then go dead."""
        rt = self.rt
        if self.state == "dead":
            return
        if self.busy:
            self.state = "draining"
            rt.tel.record("lifecycle", rt.sim.now, worker=self.idx,
                          state="draining", iteration=self.it)
            return
        self.state = "dead"
        if self.blocked:
            self.blocked = False
            rt._blocked.discard(self.idx)
        rt.tel.record("lifecycle", rt.sim.now, worker=self.idx,
                      state="dead", iteration=self.it, reason="leave")

    def rejoin(self, at_iteration: int) -> None:
        """Re-activate a dead slot at ``at_iteration`` (the committed
        frontier for bsp, the current step for async/ssp)."""
        rt = self.rt
        rt.tel.record("lifecycle", rt.sim.now, worker=self.idx,
                      state="joining", iteration=int(at_iteration))
        self.state = "active"
        self.finished = False
        self.busy = False
        self.it = int(at_iteration)
        self._rejoin_pending = True
        rt.tel.record("lifecycle", rt.sim.now, worker=self.idx,
                      state="active", iteration=self.it, reason="join")
        self._try_begin()

    def reset_to(self, iteration: int) -> None:
        """PS failover rolled the model back: cancel any in-flight
        compute and re-anchor this slot at ``iteration``."""
        rt = self.rt
        if self.state == "dead":
            return
        if self._compute_eid is not None:
            rt.sim.cancel(self._compute_eid)
            self._compute_eid = None
        self.busy = False
        self.finished = False
        self._rejoin_pending = False
        if self.blocked:
            self.blocked = False
            rt._blocked.discard(self.idx)
        self.it = int(iteration)

    def _try_begin(self) -> None:
        rt = self.rt
        if self.busy or self.finished or self.state != "active":
            return   # wake paths may overlap; one compute per iteration
        if self.it >= rt.steps:
            if self.blocked:
                self.blocked = False
                rt._blocked.discard(self.idx)
                rt.tel.record("unblock", rt.sim.now, worker=self.idx,
                              iteration=self.it)
            if not self.finished:
                self.finished = True
                rt.on_worker_finished(self.idx)
            return
        if not rt.policy.may_start(self.idx, self.it):
            if not self.blocked:
                self.blocked = True
                rt._blocked.add(self.idx)
                rt.tel.record("block", rt.sim.now, worker=self.idx,
                              iteration=self.it)
            return
        if self.blocked:
            self.blocked = False
            rt._blocked.discard(self.idx)
            rt.tel.record("unblock", rt.sim.now, worker=self.idx,
                          iteration=self.it)
        rt.policy.on_start(self.idx, self.it)
        self.params_version, self.params_snap = rt.visible_params()
        dt = rt.compute.sample(self.idx, self.it)
        if self._rejoin_pending:
            dt += getattr(rt.compute, "rejoin_penalty_s", 0.0)
            self._rejoin_pending = False
        it = self.it
        if self._h_compute is not None:
            self._h_compute.observe(dt)
        rt.tel.record("compute_start", rt.sim.now, worker=self.idx,
                      iteration=it, dt=dt)
        self.busy = True
        self._compute_eid = rt.sim.after(dt, lambda: self._grad_ready(it))
        # starting an iteration advances this worker's clock, which may
        # release SSP peers parked on the staleness bound
        rt.wake_blocked(exclude=self.idx)

    def _grad_ready(self, it: int) -> None:
        rt = self.rt
        if self.state == "dead":
            return   # crash raced the compute event; the slot is fenced
        self.busy = False
        self._compute_eid = None
        rt.tel.record("grad_ready", rt.sim.now, worker=self.idx, iteration=it)
        rt.on_grad_ready(self, it)
        if self.state == "draining":
            # graceful leave: this iteration's gradient is in flight /
            # delivered; the slot now exits the membership
            self.state = "dead"
            rt.tel.record("lifecycle", rt.sim.now, worker=self.idx,
                          state="dead", iteration=it, reason="leave")
            rt.on_worker_dead(self.idx, graceful=True)
            return
        self.it = it + 1
        self._try_begin()


class PSActor:
    """Admission + flush loop over the aggregation policy."""

    def __init__(self, rt: "ClusterRuntime"):
        self.rt = rt
        # pre-bound instrument (DESIGN.md §12; see WorkerActor)
        self._h_stale = (rt.metrics.histogram("ps/arrival_staleness")
                         if rt.tracker is not None else None)

    def on_arrival(self, g: PendingGrad) -> None:
        rt = self.rt
        if rt._ps_down:
            # the PS is between failure and failover restore: arrivals
            # have nowhere to land and are counted out, not parked
            rt.tel.record("ps_lost", rt.sim.now, worker=g.worker,
                          iteration=g.iteration)
            rt.maybe_finish()
            return
        if self._h_stale is not None:
            self._h_stale.observe(g.staleness)
        rt.tel.record("grad_arrived", rt.sim.now, worker=g.worker,
                      iteration=g.iteration, staleness=g.staleness,
                      delivered=float(g.payload["frac"]))
        # O(1) sample: PS pending depth only. Trunk queue depths are
        # sampled on the runtime's Sim.every wall grid, NOT per arrival —
        # a topology walk per gradient would put an O(pipes) cost on the
        # hot path (DESIGN.md §9).
        rt.policy.on_arrival(g)
        rt.tel.record("queue", rt.sim.now, depth=rt.policy.pending_count())
        self.flush()

    def flush(self) -> None:
        rt = self.rt
        for g in rt.policy.drained_stale():
            rt.tel.record("stale_drop", rt.sim.now, worker=g.worker,
                          iteration=g.iteration, staleness=g.staleness)
        batch = rt.policy.ready()
        while batch:
            rt.apply_batch(batch)
            batch = rt.policy.ready()
        rt.maybe_finish()
