"""Packetization of gradient pytrees (paper §III-C, §III-E, §IV-A).

The gradient pytree is flattened into one contiguous float stream and cut
into fixed-size packets (payload = ``packet_floats`` float32 values). The
paper's *padding bubble* guarantees no float straddles a packet boundary;
we generalize it: payloads are whole-float (and, on the TPU kernel path,
whole-lane: 128-float multiples). The stream tail is zero-padded to a
whole packet.

*Critical packets* (§III-E): the packets containing the first/last elements
of each tensor ("indispensable bytes of the matrix ... first and last part
of the matrix bitstream") are always delivered.

Sharded semantics: packetization happens per (worker=data-index,
PS-shard=model-index) link — each model shard is its own PS, as in the
paper's multi-PS deployment — so a ``PacketPlan`` is built from the LOCAL
leaf shapes and no resharding is ever needed for the sync.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PacketPlan:
    """Static description of the packet layout for one gradient pytree."""

    packet_floats: int
    n_floats: int                 # unpadded total float count
    n_packets: int
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_offsets: Tuple[int, ...]  # float offset of each leaf in the stream
    critical: np.ndarray           # (n_packets,) bool
    treedef: Any

    @property
    def padded_floats(self) -> int:
        return self.n_packets * self.packet_floats

    @property
    def n_critical(self) -> int:
        return int(self.critical.sum())

    @property
    def payload_bytes(self) -> int:
        return self.packet_floats * 4


def make_plan(
    tree: Any,
    packet_floats: int = 360,
    critical_per_tensor: int = 1,
) -> PacketPlan:
    """Build the packet plan from a pytree of arrays or ShapeDtypeStructs."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(x.shape) for x in leaves)
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    offsets = tuple(int(x) for x in (np.cumsum([0] + sizes)[:-1]))
    n_floats = int(sum(sizes))
    n_packets = max(1, -(-n_floats // packet_floats))
    critical = np.zeros((n_packets,), bool)
    c = critical_per_tensor
    for off, sz in zip(offsets, sizes):
        first = off // packet_floats
        last = (off + sz - 1) // packet_floats
        critical[first : min(first + c, n_packets)] = True
        critical[max(last - c + 1, 0) : last + 1] = True
    return PacketPlan(
        packet_floats=packet_floats,
        n_floats=n_floats,
        n_packets=n_packets,
        leaf_shapes=shapes,
        leaf_offsets=offsets,
        critical=critical,
        treedef=treedef,
    )


def flatten(plan: PacketPlan, tree: Any) -> jnp.ndarray:
    """Pytree -> (n_packets, packet_floats) float32 stream (zero-padded)."""
    leaves = jax.tree_util.tree_leaves(tree)
    flat = jnp.concatenate([x.astype(jnp.float32).ravel() for x in leaves])
    pad = plan.padded_floats - plan.n_floats
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(plan.n_packets, plan.packet_floats)


def unflatten(plan: PacketPlan, packets: jnp.ndarray, dtypes: Sequence[Any] | None = None) -> Any:
    """(n_packets, packet_floats) -> pytree with the plan's leaf shapes."""
    flat = packets.reshape(-1)[: plan.n_floats]
    leaves: List[jnp.ndarray] = []
    for shape, off in zip(plan.leaf_shapes, plan.leaf_offsets):
        sz = int(np.prod(shape)) if shape else 1
        leaf = jax.lax.slice_in_dim(flat, off, off + sz).reshape(shape)
        leaves.append(leaf)
    if dtypes is not None:
        leaves = [x.astype(d) for x, d in zip(leaves, dtypes)]
    return jax.tree_util.tree_unflatten(plan.treedef, leaves)


def delivery_mask(
    plan: PacketPlan, key, delivered_frac, *, extra_critical=None
) -> jnp.ndarray:
    """Random per-packet delivery (threshold-controlled Random-k, §II-C).

    ``delivered_frac`` may be a traced scalar in [0, 1]. Critical packets
    are always delivered. Returns (n_packets,) float32 mask.
    """
    u = jax.random.uniform(key, (plan.n_packets,))
    crit = jnp.asarray(plan.critical)
    if extra_critical is not None:
        crit = crit | extra_critical
    return jnp.where(crit, 1.0, (u < delivered_frac).astype(jnp.float32))


def local_shape(shape: Tuple[int, ...], spec, mesh) -> Tuple[int, ...]:
    """Per-device block shape of a global array under a PartitionSpec."""
    out = list(shape)
    for dim, names in enumerate(spec):
        if names is None:
            continue
        names = (names,) if isinstance(names, str) else tuple(names)
        total = 1
        for n in names:
            total *= mesh.shape[n]
        assert out[dim] % total == 0, (shape, spec, dim)
        out[dim] //= total
    return tuple(out)


def local_plan(
    params_shape: Any, specs: Any, mesh, packet_floats: int = 360,
    critical_per_tensor: int = 1,
) -> PacketPlan:
    """PacketPlan over LOCAL (per-device) leaf shapes given param specs."""
    locals_ = jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            local_shape(tuple(sds.shape), spec, mesh), sds.dtype
        ),
        params_shape,
        specs,
        is_leaf=lambda x: hasattr(x, "shape"),
    )
    return make_plan(locals_, packet_floats, critical_per_tensor)
