"""ClusterRuntime — event-driven compute/network co-simulation of the
PS training cluster (DESIGN.md §8).

One shared ``Sim`` clock carries everything: per-worker compute times
(``runtime.compute``), the transport leg (analytic per-flow timing or
the packet-level DES in ``runtime.transport``), and the PS-side
aggregation policy (``runtime.policies``). The JAX state (params,
optimizer, packet plan, kernel-backed reductions) lives here; actors and
policies only schedule.

Execution paths:

* ``policy="bsp"`` — barrier semantics. The runtime runs the SAME fused
  jitted step as the legacy lockstep ``PSTrainer`` on the SAME
  Early-Close controller and delivery-mask RNG streams, so with the
  default deterministic compute model a bsp run reproduces the legacy
  loop record-for-record (tests/test_runtime.py pins this).
* ``policy="async" | "ssp"`` — apply-on-arrival. Each worker's gradient
  is computed against the params version that worker actually fetched
  (so staleness is real, not simulated), gated per-gradient through the
  error-feedback/delivery machinery, and folded in by
  ``reduce_packet_stream`` with the policy's staleness-damped weights.

Fault tolerance (DESIGN.md §10): a ``FaultSchedule`` (or a
``FaultConfig`` drawn at run time) injects worker crash/join/leave and
PS failure onto the same clock. Worker death rides the transport's
generation-fencing protocol, so a dead node's in-flight traffic is
provably dropped; PS failover restores the last periodic snapshot
(optionally round-tripped through ``repro.checkpoint``) and, with
``n_ps > 1``, rebalances shard ownership across survivors. Every fault
path is a structural no-op when no faults are scheduled — a zero-fault
run is record-for-record identical to the fault-unaware runtime
(tests/test_faults.py pins this).

Truncation safety: if the event loop stops on ``max_events`` mid-run the
runtime raises instead of returning a partial history.
"""
from __future__ import annotations

import hashlib
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (
    FaultConfig,
    LTPConfig,
    NetConfig,
    NetFaultConfig,
    ObservabilityConfig,
    RuntimeConfig,
    TrainConfig,
)
from repro.core import packets as pk
from repro.core.early_close import (
    AnalyticIncastModel,
    MultiPSEarlyClose,
    broadcast_time,
)
from repro.models.api import ModelApi
from repro.net.netfaults import (
    LinkFaultSchedule,
    NetFaultPlane,
    netfault_schedule_from_config,
)
from repro.net.scenarios import GatherSpec
from repro.net.simcore import PERF, Sim
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracker import make_tracker
from repro.net.topology import resolve_topology
from repro.optim import Optimizer, lr_at
from repro.runtime import step as stp
from repro.checkpoint.io import restore_checkpoint, save_checkpoint
from repro.runtime.actors import PSActor, WorkerActor
from repro.runtime.compute import ComputeModel, make_compute_model
from repro.runtime.faults import (
    FaultEvent,
    FaultSchedule,
    ShardLedger,
    schedule_from_config,
)
from repro.runtime.policies import (
    AggregationPolicy,
    AsyncPolicy,
    BSPPolicy,
    PendingGrad,
    SSPPolicy,
    make_policy,
)
from repro.runtime.telemetry import Telemetry
from repro.runtime.transport import AnalyticPerWorkerNet, DESTransport


class _BSPRound:
    """One in-flight barrier iteration (bsp only)."""

    __slots__ = ("iteration", "ready", "gather", "t_first", "flows_done",
                 "members")

    def __init__(self, iteration: int):
        self.iteration = iteration
        self.ready: set = set()
        self.gather = None          # _DESBarrierGather under transport="des"
        self.t_first: Optional[float] = None
        self.flows_done: set = set()  # completed reliable flows (non-ltp DES)
        # membership snapshot at round creation; crashes shrink it, and
        # the barrier closes when members ⊆ ready (== the legacy
        # len(ready) == W condition whenever the cluster is whole)
        self.members: set = set()


class ClusterRuntime:
    def __init__(
        self,
        api: ModelApi,
        opt: Optimizer,
        train: TrainConfig,
        ltp: LTPConfig,
        net: NetConfig,
        n_workers: int = 8,
        protocol: str = "ltp",
        policy="bsp",
        policy_kw: Optional[dict] = None,
        compute_model=None,
        compute_time: float = 0.05,
        n_ps: Optional[int] = None,
        seed: int = 0,
        transport: str = "analytic",
        spec: Optional[GatherSpec] = None,
        coalesce: Optional[int] = None,
        telemetry: bool = True,
        params=None,
        opt_state=None,
        faults=None,
        checkpoint_every_s: float = 0.0,
        checkpoint_dir: Optional[str] = None,
        topology: Optional[GatherSpec] = None,
        runtime_cfg: Optional[RuntimeConfig] = None,
        obs: Optional[ObservabilityConfig] = None,
        net_faults=None,
        budget=None,
    ):
        if transport not in ("analytic", "des"):
            raise ValueError(f"unknown transport {transport!r}")
        ltp = ltp.with_runtime(runtime_cfg)
        self.topology = resolve_topology(topology, n_ps=n_ps, spec=spec,
                                         owner="ClusterRuntime")
        self.topology.validate_workers(n_workers, "ClusterRuntime")
        self.api = api
        self.opt = opt
        self.train_cfg = train
        self.ltp = ltp
        self.net = net
        self.w = n_workers
        self.protocol = protocol
        self.n_ps = self.topology.n_ps
        self.seed = seed
        self.transport = transport
        self.sim = Sim()
        # observability layer (DESIGN.md §12): the explicit ``obs=``
        # kwarg wins, else the config riding on LTPConfig/RuntimeConfig.
        # tracker="none" resolves to tracker None — the runtime then
        # holds no sink and every hot path keeps its exact old shape
        # (bitwise-identical runs, pinned in tests/test_obs.py).
        self.obs_cfg = obs if obs is not None \
            else (ltp.obs or ObservabilityConfig())
        self.tracker = make_tracker(self.obs_cfg,
                                    run_name=self.obs_cfg.run_name)
        self.metrics = MetricsRegistry(reservoir=self.obs_cfg.reservoir,
                                       seed=seed)
        self._perf0: Dict[str, int] = {}
        self.tel = Telemetry(telemetry, tracker=self.tracker)
        self.policy: AggregationPolicy = make_policy(policy,
                                                     **(policy_kw or {}))
        # LTPConfig.staleness_comp governs the damping law for BOTH
        # apply-on-arrival policies unless the instance overrides it
        if isinstance(self.policy, SSPPolicy) \
                and self.policy.staleness_comp == 0:
            self.policy.staleness_comp = ltp.staleness_comp
        if isinstance(self.policy, AsyncPolicy) and self.policy.damping is None:
            self.policy.damping = ltp.staleness_comp
        self.policy.bind(n_workers)
        self.compute: ComputeModel = make_compute_model(
            compute_model, n_workers, base=compute_time, seed=seed)

        key = jax.random.PRNGKey(seed)
        self.params = api.init(key) if params is None else params
        self.opt_state = opt.init(self.params) if opt_state is None \
            else opt_state
        self.plan = pk.make_plan(self.params, ltp.packet_floats,
                                 ltp.critical_per_tensor)
        self.model_bytes = self.plan.n_floats * 4
        self.residual = (
            jnp.zeros((n_workers, self.plan.n_packets,
                       self.plan.packet_floats))
            if ltp.error_feedback else None)

        # legacy-parity RNG/controller streams (bsp path; seeds match
        # the lockstep PSTrainer exactly)
        self._mask_rng = np.random.default_rng(seed + 23)
        self.controller = MultiPSEarlyClose(ltp, net, n_workers,
                                            self.model_bytes, n_ps=self.n_ps)
        self.gather_models = [
            AnalyticIncastModel(net, n_workers, protocol=protocol,
                                seed=seed + 1 + 1000 * p)
            for p in range(self.n_ps)
        ]
        # async/ssp streams (separate, so they cannot perturb bsp parity)
        self._amask_rng = np.random.default_rng(seed + 29)

        self.net_des: Optional[DESTransport] = None
        self.anet: Optional[AnalyticPerWorkerNet] = None
        if transport == "des":
            self.net_des = DESTransport(
                self.sim, net, ltp, protocol, n_workers, self.model_bytes,
                topology=self.topology, seed=seed, coalesce=coalesce,
                on_early_close=lambda shard, t, d, lat=0.0: self.tel.record(
                    "early_close", t, shard=shard, delivered=d, lat=lat))
        else:
            self.anet = AnalyticPerWorkerNet(
                self.sim, net, ltp, protocol, n_workers, self.model_bytes,
                seed=seed)

        # jitted machinery, built lazily per execution path
        self._fused_step = None
        self._grad_fn = None
        self._apply_fn = None
        self._ef_gate = None

        # fault layer (runtime/faults.py): dormant unless armed.
        # ``faults`` is a FaultSchedule (explicit timeline) or a
        # FaultConfig (random churn drawn in run(), once the horizon is
        # known).
        self._fault_cfg: Optional[FaultConfig] = None
        self.faults: Optional[FaultSchedule] = None
        if isinstance(faults, FaultSchedule):
            self.faults = faults
        elif isinstance(faults, FaultConfig):
            self._fault_cfg = faults
            if checkpoint_every_s == 0.0:
                checkpoint_every_s = faults.checkpoint_every_s
        elif faults is not None:
            raise TypeError(
                f"faults must be a FaultSchedule or FaultConfig, "
                f"got {type(faults)!r}")
        # network fault plane (net/netfaults.py, DESIGN.md §14): same
        # dormant-unless-armed contract as the node-fault layer.
        # ``net_faults`` is a LinkFaultSchedule (explicit timeline) or a
        # NetFaultConfig (random fabric churn drawn in run()). Fabric
        # faults act on the packet-level topology, so they require
        # transport="des"; an empty schedule arms nothing and the run
        # stays bitwise-identical to a fault-unaware one.
        self._netfault_cfg: Optional[NetFaultConfig] = None
        self.net_faults: Optional[LinkFaultSchedule] = None
        self.netfault_plane: Optional[NetFaultPlane] = None
        if isinstance(net_faults, LinkFaultSchedule):
            self.net_faults = net_faults
        elif isinstance(net_faults, NetFaultConfig):
            self._netfault_cfg = net_faults
        elif net_faults is not None:
            raise TypeError(
                f"net_faults must be a LinkFaultSchedule or "
                f"NetFaultConfig, got {type(net_faults)!r}")
        if (self.net_faults is not None or self._netfault_cfg is not None) \
                and transport != "des":
            raise ValueError(
                "net_faults requires transport='des' — the analytic "
                "transport has no links or switches to fail")
        # closed-loop loss-budget controller (runtime/budget.py): bound
        # and ticked in run() only when provided (None -> untouched
        # thresholds, zero-fault parity)
        self.budget = budget
        if budget is not None and transport != "des":
            raise ValueError(
                "budget controller requires transport='des' — the "
                "analytic transport has no per-shard Early-Close "
                "receivers to actuate")
        self._budget_cancel = None
        self._ckpt_every = float(checkpoint_every_s)
        self._ckpt_dir = checkpoint_dir
        self._snap: Optional[dict] = None
        self._ckpt_cancel = None
        self._ps_down = False
        self._ps_epoch = 0          # bumps at each PS failure; fences
        #                             scheduled closures from a dead epoch
        self._flight: Dict[tuple, int] = {}   # (worker, it) -> ps epoch
        self.active_workers: set = set(range(n_workers))
        self.ledger = ShardLedger(self.n_ps)

        self.ps = PSActor(self)
        self.workers: List[WorkerActor] = []
        self._blocked: set = set()
        self._bsp_round: Optional[_BSPRound] = None
        self._visible = (0, self.params)
        self.version = 0                 # PS apply counter
        self.max_applied_iter = -1
        self.sim_time = 0.0
        self.step_idx = 0                # committed bsp iterations
        self.history: List[Dict] = []
        self._stopped = False
        self._batches: List = []
        self._shaped_cache: Dict[int, object] = {}
        self.steps = 0
        self._eval_fn = None
        self._eval_every = 0
        self._epoch_steps = 0
        self._log_every = 0

    # ------------------------------------------------------------------
    # params visibility (the broadcast leg)
    # ------------------------------------------------------------------
    def visible_params(self):
        return self._visible

    def _publish(self, version: int, params) -> None:
        delay = broadcast_time(self.net, self.model_bytes, n_ps=self.n_ps)
        epoch = self._ps_epoch

        def set_visible():
            # a broadcast launched before a PS failure must not clobber
            # the restored params (epoch fence); always 0 == 0 when no
            # faults are scheduled
            if epoch == self._ps_epoch and version > self._visible[0]:
                self._visible = (version, params)
            self.wake_blocked()

        self.sim.after(delay, set_visible)

    # ------------------------------------------------------------------
    # worker events
    # ------------------------------------------------------------------
    def wake_blocked(self, exclude: Optional[int] = None) -> None:
        for idx in sorted(self._blocked):
            if idx != exclude:
                self.workers[idx]._try_begin()

    def _worker_batch(self, worker: int, it: int):
        return jax.tree.map(lambda x: x[worker], self._shaped_batch(it))

    def _shaped_batch(self, it: int):
        shaped = self._shaped_cache.get(it)
        if shaped is None:
            b = self._batches[it]
            shaped = jax.tree.map(
                lambda x: jnp.asarray(x).reshape(
                    (self.w, x.shape[0] // self.w) + x.shape[1:]),
                b,
            )
            self._shaped_cache[it] = shaped
            # small LRU: live iterations span at most the staleness
            # window; without a bound a long run would pin one device
            # copy of every batch it ever consumed
            while len(self._shaped_cache) > 8:
                self._shaped_cache.pop(next(iter(self._shaped_cache)))
        return shaped

    def on_grad_ready(self, actor: WorkerActor, it: int) -> None:
        if self._ps_down:
            # the PS is between failure and failover: this gradient has
            # nowhere to go — counted out, never sent
            self.tel.record("ps_lost", self.sim.now, worker=actor.idx,
                            iteration=it)
            return
        if isinstance(self.policy, BSPPolicy):
            self._bsp_grad_ready(actor.idx, it)
            return
        # async/ssp: the gradient is computed against the params snapshot
        # this worker fetched — staleness is real
        if self._grad_fn is None:
            self._grad_fn = stp.build_worker_grad_fn(self.api, self.plan)
        loss, flat = self._grad_fn(actor.params_snap,
                                   self._worker_batch(actor.idx, it))
        worker = actor.idx
        # flight registry: teardown paths (worker crash, PS failure) pop
        # entries, and the delivery callback drops itself when its entry
        # is gone — a dead flow can never fold into the model
        self._flight[(worker, it)] = self._ps_epoch

        if self.net_des is not None:
            def on_delivered(masks_ps, frac, early, worker=worker, it=it,
                             loss=loss, flat=flat):
                if self._flight.pop((worker, it), None) is None:
                    return
                stream = np.concatenate(list(masks_ps))
                row = stp.tile_mask_onto_plan(self.plan, stream)
                if self.tel.enabled:
                    self.tel.record(
                        "masks", self.sim.now, worker=worker, iteration=it,
                        digest=hashlib.blake2b(
                            np.ascontiguousarray(masks_ps).tobytes(),
                            digest_size=8).hexdigest())
                if early:
                    self.tel.record("early_close", self.sim.now,
                                    worker=worker, iteration=it,
                                    delivered=float(frac))
                self._deliver(worker, it, loss, flat, row, float(frac))

            self.net_des.send(worker, on_delivered)
        else:
            def on_close(frac, early, worker=worker, it=it, loss=loss,
                         flat=flat):
                if self._flight.pop((worker, it), None) is None:
                    return
                if self.protocol == "ltp":
                    row = (self._amask_rng.random(self.plan.n_packets)
                           < frac).astype(np.float32)
                    row[self.plan.critical] = 1.0
                else:
                    row = np.ones(self.plan.n_packets, np.float32)
                if early:
                    self.tel.record("early_close", self.sim.now,
                                    worker=worker, iteration=it,
                                    delivered=float(frac))
                self._deliver(worker, it, loss, flat, row, float(frac))

            self.anet.send(worker, on_close)

    def _deliver(self, worker: int, it: int, loss, flat, mask_row: np.ndarray,
                 frac: float) -> None:
        g = PendingGrad(
            worker=worker, iteration=it, t_ready=self.sim.now,
            staleness=max(0, self.max_applied_iter - it),
            payload={"loss": loss, "flat": flat,
                     "mask": jnp.asarray(mask_row), "frac": frac})
        self.ps.on_arrival(g)

    def on_worker_finished(self, idx: int) -> None:
        self.maybe_finish()

    def on_worker_dead(self, idx: int, graceful: bool = False) -> None:
        """Remove ``idx`` from the membership. A crash (graceful=False)
        additionally tears down its transport state and fences its
        in-flight gradients; a graceful leave lets them deliver."""
        self.active_workers.discard(idx)
        if not graceful:
            for key in [k for k in self._flight if k[0] == idx]:
                del self._flight[key]
                self.tel.record("flow_torn", self.sim.now, worker=idx,
                                iteration=key[1])
            if self.net_des is not None:
                self.net_des.teardown_worker(idx)
        self.policy.on_membership(self.active_workers)
        if not graceful and isinstance(self.policy, BSPPolicy):
            self._bsp_round_member_lost(idx)
        self.wake_blocked()
        self.maybe_finish()

    # ------------------------------------------------------------------
    # bsp barrier path (legacy-parity)
    # ------------------------------------------------------------------
    def _bsp_grad_ready(self, worker: int, it: int) -> None:
        rnd = self._bsp_round
        if rnd is None or rnd.iteration != it:
            rnd = self._bsp_round = _BSPRound(it)
            rnd.t_first = self.sim.now
            rnd.members = set(self.active_workers)
            if self.net_des is not None and self.protocol == "ltp":
                rnd.gather = self.net_des.start_gather(
                    self._bsp_des_closed,
                    members=(None if len(rnd.members) == self.w
                             else rnd.members))
        rnd.ready.add(worker)
        if self.net_des is None:
            if rnd.members and rnd.members <= rnd.ready:
                self._bsp_analytic_close(rnd)
        elif self.protocol == "ltp":
            rnd.gather.add_worker(worker)
        else:
            # reliable protocols: independent flows; the barrier closes
            # when the last byte of the last member's flow lands
            # staleness guard lives in _bsp_reliable_check (``rnd is not
            # self._bsp_round`` → return); marking a dead round's
            # flows_done set first is harmless, the object is garbage.
            def on_flow(masks_ps, frac, early, rnd=rnd, worker=worker):
                rnd.flows_done.add(worker)
                self._bsp_reliable_check(rnd)

            self.net_des.send(worker, on_flow)  # replint: ok(gen-fence)

    def _bsp_reliable_check(self, rnd: _BSPRound) -> None:
        if rnd is not self._bsp_round or not rnd.members \
                or not rnd.members <= rnd.flows_done:
            return
        masks = np.ones((self.w, self.plan.n_packets), np.float32)
        close = self.sim.now - rnd.t_first
        bst = close + broadcast_time(self.net, self.model_bytes,
                                     n_ps=self.n_ps)
        if len(rnd.ready & rnd.members) == self.w:
            self._bsp_commit(rnd, masks, np.ones(self.w), bst)
        else:
            self._bsp_commit_degraded(rnd, masks, np.ones(self.w), bst)

    def _bsp_round_member_lost(self, worker: int) -> None:
        """A crash removed ``worker`` mid-round: shrink the barrier to
        the survivors and re-check whether it can now close."""
        rnd = self._bsp_round
        if rnd is None or worker not in rnd.members:
            return
        rnd.members.discard(worker)
        if worker in rnd.ready:
            # its gradient reached the round but will never complete the
            # transport leg — the flow is torn, not applied (and leaves
            # ``ready`` so a later PS failure cannot double-count it)
            rnd.ready.discard(worker)
            self.tel.record("flow_torn", self.sim.now, worker=worker,
                            iteration=rnd.iteration)
        if rnd.gather is not None:
            # the gather's own close rule re-evaluates over the
            # surviving flows (may fire _bsp_des_closed synchronously)
            rnd.gather.abandon_worker(worker)
            return
        if not rnd.members:
            self._bsp_round_dissolved()
            return
        if self.net_des is None:
            if rnd.members <= rnd.ready:
                self._bsp_analytic_close(rnd)
        else:
            self._bsp_reliable_check(rnd)

    def _bsp_round_dissolved(self) -> None:
        """Every participant of the in-flight round crashed before it
        could commit. Survivor-less rounds leave joiners parked at
        iteration+1; re-anchor every live idle worker at the committed
        frontier so the barrier restarts."""
        self._bsp_round = None
        for wk in self.workers:
            if wk.state != "dead" and not wk.busy and not wk.finished:
                wk.reset_to(self.step_idx)
                wk._try_begin()
        self.maybe_finish()

    def _bsp_analytic_close(self, rnd: _BSPRound) -> None:
        """All grads ready: sample the transport models and the Early
        Close controller exactly as the lockstep loop does."""
        it = rnd.iteration
        shard_bytes = self.model_bytes / self.n_ps
        samples = [m.sample(shard_bytes) for m in self.gather_models]
        if self.protocol == "ltp":
            total = max(1, self.train_cfg.steps)
            self.controller.set_progress(it / total)
            close, frac = self.controller.step(samples)
            bst = close + broadcast_time(self.net, self.model_bytes,
                                         n_ps=self.n_ps)
        else:
            close = max(float(s.completion_times.max()) for s in samples)
            bst = close + broadcast_time(
                self.net, self.model_bytes, n_ps=self.n_ps
            ) * self.gather_models[0].loss_inflation()
            frac = np.ones(self.w)
        masks = (stp.draw_delivery_masks(self.plan, self.w, self._mask_rng,
                                         frac)
                 if self.protocol == "ltp"
                 else np.ones((self.w, self.plan.n_packets), np.float32))
        if self.protocol == "ltp" and float(np.mean(frac)) < 1.0 - 1e-9:
            self.tel.record("early_close", self.sim.now + close,
                            iteration=it, delivered=float(np.mean(frac)))
        # the analytic incast model assumes all W flows start together, so
        # the gather is anchored at the LAST grad-ready (= now, the event
        # that completed the barrier) — under heterogeneous compute the
        # straggler's lateness must not absorb the transport cost
        if len(rnd.ready & rnd.members) == self.w:
            self._bsp_commit(rnd, masks, frac, bst, t_anchor=self.sim.now)
        else:
            self._bsp_commit_degraded(rnd, masks, frac, bst,
                                      t_anchor=self.sim.now)

    def _bsp_des_closed(self, sharded) -> None:
        """All DES shards closed: real delivery masks -> fused step."""
        rnd = self._bsp_round
        if rnd is None:
            return
        if not (rnd.ready & rnd.members):
            # every participant crashed before the gather closed
            self._bsp_round_dissolved()
            return
        per_shard = sharded.delivery_masks()        # (n_ps, W, n)
        if self.tel.enabled:
            self.tel.record(
                "masks", self.sim.now, iteration=rnd.iteration,
                digest=hashlib.blake2b(
                    np.ascontiguousarray(per_shard).tobytes(),
                    digest_size=8).hexdigest())
        masks = np.stack([
            stp.tile_mask_onto_plan(
                self.plan, np.concatenate([per_shard[p][f]
                                           for p in range(self.n_ps)]))
            for f in range(self.w)
        ])
        frac = sharded.delivered_fracs()
        close = self.sim.now - rnd.t_first
        bst = close + broadcast_time(self.net, self.model_bytes,
                                     n_ps=self.n_ps)
        if len(rnd.ready & rnd.members) == self.w:
            self._bsp_commit(rnd, masks, frac, bst)
        else:
            self._bsp_commit_degraded(rnd, masks, frac, bst)

    def _bsp_commit(self, rnd: _BSPRound, masks: np.ndarray,
                    frac: np.ndarray, bst: float,
                    t_anchor: Optional[float] = None) -> None:
        it = rnd.iteration
        if self._fused_step is None:
            self._fused_step = stp.build_fused_step(
                self.api, self.opt, self.ltp, self.plan, self.w,
                self.protocol)
        lr = lr_at(self.train_cfg, it, self._epoch_steps)
        (self.params, self.opt_state, self.residual, loss, realized) = \
            self._fused_step(self.params, self.opt_state, self.residual,
                             self._shaped_batch(it), jnp.asarray(masks),
                             jnp.asarray(frac, jnp.float32),
                             jnp.asarray(lr, jnp.float32))
        # the iteration commits when the broadcast lands: history record,
        # params visibility, and the barrier release all happen there.
        # ``t_anchor`` is the gather start (analytic: last grad-ready;
        # DES: the round's first send, whose ``bst`` already spans the
        # in-flight gather).
        t_commit = (rnd.t_first if t_anchor is None else t_anchor) + bst
        epoch = self._ps_epoch

        def commit(loss=loss, realized=realized):
            if epoch != self._ps_epoch:
                return   # PS failed between close and commit; rolled back
            self.version += 1
            self.max_applied_iter = it
            self._visible = (self.version, self.params)
            self.sim_time = self.sim.now
            # loss/realized stay as LAZY jax scalars: forcing them here
            # would block the event loop on the XLA step instead of
            # letting it run concurrently (DESIGN.md §9); ``run``
            # converts the whole history once the sim drains.
            rec = {
                "step": it,
                "loss": loss,
                "bst": bst,
                "delivered": realized,
                "sim_time": self.sim_time,
            }
            self.tel.record("apply", self.sim.now, step=it, n_grads=self.w,
                            staleness_max=0, staleness_mean=0.0,
                            loss=rec["loss"])
            if self._epoch_steps and (it + 1) % self._epoch_steps == 0:
                self.controller.new_epoch()
            if self._eval_fn is not None and self._eval_every and \
                    (it + 1) % self._eval_every == 0:
                rec["eval"] = float(self._eval_fn(self.params))
            self.history.append(rec)
            if self._log_every and it % self._log_every == 0:
                msg = f"step {it:5d} loss {float(rec['loss']):.4f} " \
                      f"bst {bst*1e3:6.1f}ms " \
                      f"delivered {float(rec['delivered']):.3f}"
                if "eval" in rec:
                    msg += f" eval {rec['eval']:.4f}"
                print(msg, flush=True)
            self.step_idx = it + 1
            self._bsp_round = None
            self.policy.on_applied([])
            self.wake_blocked()
            self.maybe_finish()

        self.sim.at(t_commit, commit)

    def _bsp_commit_degraded(self, rnd: _BSPRound, masks: np.ndarray,
                             frac, bst: float,
                             t_anchor: Optional[float] = None) -> None:
        """Partial-membership barrier commit. The fused step is shaped
        over all W batch shards, so a degraded round instead computes
        per-survivor gradients (same grad fn as the async path) and
        folds them with weight W/n_survivors — composed with the apply
        fn's 1/W reduction that is exactly the mean over survivors."""
        it = rnd.iteration
        survivors = sorted(rnd.ready & rnd.members)
        if not survivors:
            self._bsp_round_dissolved()
            return
        frac_arr = np.asarray(frac, float)
        if frac_arr.ndim == 0:
            frac_arr = np.full(self.w, float(frac_arr))
        t_commit = (rnd.t_first if t_anchor is None else t_anchor) + bst
        epoch = self._ps_epoch

        def commit():
            if epoch != self._ps_epoch:
                return
            if self._grad_fn is None:
                self._grad_fn = stp.build_worker_grad_fn(self.api, self.plan)
            if self._apply_fn is None:
                self._apply_fn = stp.build_apply_fn(
                    self.api, self.opt, self.ltp, self.plan, self.w,
                    premasked=self.ltp.error_feedback)
                if self.ltp.error_feedback:
                    self._ef_gate = stp.build_ef_gate_fn(self.ltp)
            n, p = self.plan.n_packets, self.plan.packet_floats
            weights = np.zeros(self.w, np.float32)
            rows_flat, rows_mask, losses = [], [], []
            scale = self.w / len(survivors)
            for i, wkr in enumerate(survivors):
                snap = self.workers[wkr].params_snap
                loss, flat = self._grad_fn(
                    self.params if snap is None else snap,
                    self._worker_batch(wkr, it))
                mask = jnp.asarray(masks[wkr])
                if self._ef_gate is not None:
                    flat, new_res = self._ef_gate(
                        flat, self.residual[wkr], mask)
                    self.residual = self.residual.at[wkr].set(new_res)
                rows_flat.append(flat)
                rows_mask.append(mask)
                weights[i] = scale
                losses.append(loss)
            pad = self.w - len(survivors)
            if pad:
                rows_flat.append(jnp.zeros((pad, n, p), jnp.float32))
                rows_mask.append(jnp.zeros((pad, n), jnp.float32))
                stacked = jnp.concatenate(
                    [jnp.stack(rows_flat[:-1]), rows_flat[-1]])
                mrows = jnp.concatenate(
                    [jnp.stack(rows_mask[:-1]), rows_mask[-1]])
            else:
                stacked = jnp.stack(rows_flat)
                mrows = jnp.stack(rows_mask)
            lr = lr_at(self.train_cfg, it, self._epoch_steps)
            fr = float(np.mean(frac_arr[survivors]))
            self.params, self.opt_state = self._apply_fn(
                self.params, self.opt_state, stacked, mrows,
                jnp.asarray(weights), jnp.asarray(fr, jnp.float32),
                jnp.asarray(lr, jnp.float32))
            loss = jnp.mean(jnp.stack(losses))
            self.version += 1
            self.max_applied_iter = it
            self._visible = (self.version, self.params)
            self.sim_time = self.sim.now
            rec = {
                "step": it,
                "loss": loss,
                "bst": bst,
                "delivered": fr,
                "sim_time": self.sim_time,
                "n_grads": len(survivors),
            }
            self.tel.record("apply", self.sim.now, step=it,
                            n_grads=len(survivors), staleness_max=0,
                            staleness_mean=0.0, loss=loss)
            if self._epoch_steps and (it + 1) % self._epoch_steps == 0:
                self.controller.new_epoch()
            if self._eval_fn is not None and self._eval_every and \
                    (it + 1) % self._eval_every == 0:
                rec["eval"] = float(self._eval_fn(self.params))
            self.history.append(rec)
            if self._log_every and it % self._log_every == 0:
                print(f"step {it:5d} loss {float(rec['loss']):.4f} "
                      f"bst {bst*1e3:6.1f}ms degraded "
                      f"n_grads {len(survivors)}/{self.w}", flush=True)
            self.step_idx = it + 1
            self._bsp_round = None
            self.policy.on_applied([])
            self.wake_blocked()
            self.maybe_finish()

        self.sim.at(t_commit, commit)

    # ------------------------------------------------------------------
    # async/ssp apply path
    # ------------------------------------------------------------------
    def apply_batch(self, batch: List[PendingGrad]) -> None:
        if self._apply_fn is None:
            self._apply_fn = stp.build_apply_fn(
                self.api, self.opt, self.ltp, self.plan, self.w,
                premasked=self.ltp.error_feedback)
            if self.ltp.error_feedback:
                self._ef_gate = stp.build_ef_gate_fn(self.ltp)
        n, p = self.plan.n_packets, self.plan.packet_floats
        pw = self.policy.weights(batch)
        weights = np.zeros(self.w, np.float32)
        rows_flat, rows_mask, fracs = [], [], []
        for i, g in enumerate(batch):
            flat, mask = g.payload["flat"], g.payload["mask"]
            if self._ef_gate is not None:
                flat, new_res = self._ef_gate(flat, self.residual[g.worker],
                                              mask)
                self.residual = self.residual.at[g.worker].set(new_res)
            rows_flat.append(flat)
            rows_mask.append(mask)
            weights[i] = 1.0 if pw is None else pw[i]
            fracs.append(g.payload["frac"])
        pad = self.w - len(batch)   # fixed (W, n, p) shape: compile once
        if pad:
            rows_flat.append(jnp.zeros((pad, n, p), jnp.float32))
            rows_mask.append(jnp.zeros((pad, n), jnp.float32))
            stacked = jnp.concatenate(
                [jnp.stack(rows_flat[:-1]), rows_flat[-1]])
            masks = jnp.concatenate(
                [jnp.stack(rows_mask[:-1]), rows_mask[-1]])
        else:
            stacked = jnp.stack(rows_flat)
            masks = jnp.stack(rows_mask)
        top_it = max(g.iteration for g in batch)
        lr = lr_at(self.train_cfg, top_it, self._epoch_steps)
        frac = jnp.asarray(np.mean(fracs), jnp.float32)
        self.params, self.opt_state = self._apply_fn(
            self.params, self.opt_state, stacked, masks,
            jnp.asarray(weights), frac, jnp.asarray(lr, jnp.float32))
        self.version += 1
        self.max_applied_iter = max(self.max_applied_iter, top_it)
        stale = [g.staleness for g in batch]
        # lazy mean loss — forcing here would serialize the event loop
        # behind every XLA apply (see _bsp_commit / run finalization)
        loss = jnp.mean(jnp.stack([g.payload["loss"] for g in batch]))
        self.sim_time = self.sim.now
        rec = {
            "step": self.version - 1,
            "loss": loss,
            "delivered": float(np.mean(fracs)),
            "staleness": int(max(stale)),
            "n_grads": len(batch),
            "sim_time": self.sim_time,
        }
        self.tel.record("apply", self.sim.now, step=self.version - 1,
                        n_grads=len(batch), staleness_max=int(max(stale)),
                        staleness_mean=float(np.mean(stale)), loss=loss)
        if self._eval_fn is not None and self._eval_every and \
                self.version % self._eval_every == 0:
            rec["eval"] = float(self._eval_fn(self.params))
        self.history.append(rec)
        if self._log_every and (self.version - 1) % self._log_every == 0:
            print(f"apply {self.version - 1:5d} loss {float(loss):.4f} "
                  f"staleness {max(stale)} n_grads {len(batch)}", flush=True)
        self.policy.on_applied(batch)
        self._publish(self.version, self.params)
        self.wake_blocked()

    # ------------------------------------------------------------------
    # fault injection (DESIGN.md §10)
    # ------------------------------------------------------------------
    def on_fault(self, ev: FaultEvent) -> None:
        """FaultSchedule dispatch target; one call per armed event."""
        if self._stopped:
            return
        self.tel.record("fault", self.sim.now, fault=ev.kind,
                        target=ev.target)
        if ev.kind == "worker_crash":
            self._fault_worker_crash(ev.target % self.w)
        elif ev.kind == "worker_leave":
            self._fault_worker_leave(ev.target % self.w)
        elif ev.kind == "worker_join":
            self._fault_worker_join(ev.target % self.w)
        elif ev.kind == "ps_fail":
            self._fault_ps_fail(ev.target % self.n_ps, ev.recover_s)
        elif ev.kind == "ps_recover":
            self._fault_ps_recover(ev.target % self.n_ps)

    # -- network fault plane (DESIGN.md §14) ---------------------------

    def _on_netfault(self, ev) -> None:
        """NetFaultPlane ``on_event`` tap: one record per realized
        LinkFaultEvent (mirrors the node-fault ``fault`` records)."""
        self.tel.record("netfault", self.sim.now, fault=ev.kind,
                        target=str(ev.target))

    def _on_path_state(self, kind: str, target: str) -> None:
        """NetFaultPlane ``on_path`` tap: path-state transitions —
        ``reroute`` (backup absorbed the cut) or ``blackhole`` (no
        redundancy; traffic on the path is being dropped)."""
        self.tel.record(kind, self.sim.now, link=str(target))

    def on_flow_dead(self, idx: int) -> None:
        """LTP blackhole detection fired for worker ``idx``: its sender
        hit BLACKHOLE_RTOS consecutive timeouts and aborted the flow.
        The worker itself is alive — only its transport leg is gone —
        so this drops the in-flight contribution (bsp: shrink the
        barrier; async/ssp: fence the flight entry) and tears the
        worker's flow state so the next iteration starts clean."""
        if self._stopped:
            return
        for key in [k for k in self._flight if k[0] == idx]:
            del self._flight[key]
            self.tel.record("flow_dead", self.sim.now, worker=idx,
                            iteration=key[1])
        if self.net_des is not None:
            self.net_des.teardown_worker(idx)
        if isinstance(self.policy, BSPPolicy):
            self._bsp_round_flow_dead(idx)
        self.wake_blocked()
        self.maybe_finish()

    def _bsp_round_flow_dead(self, worker: int) -> None:
        """A blackholed flow removed ``worker``'s contribution from the
        in-flight round. Same barrier surgery as a crash
        (_bsp_round_member_lost) but the event is ``flow_dead`` — the
        worker survives and rejoins the barrier next round."""
        rnd = self._bsp_round
        if rnd is None or worker not in rnd.members:
            return
        rnd.members.discard(worker)
        if worker in rnd.ready:
            rnd.ready.discard(worker)
            self.tel.record("flow_dead", self.sim.now, worker=worker,
                            iteration=rnd.iteration)
        if rnd.gather is not None:
            rnd.gather.abandon_worker(worker)
            return
        if not rnd.members:
            self._bsp_round_dissolved()
            return
        if self.net_des is not None:
            self._bsp_reliable_check(rnd)

    def _fault_worker_crash(self, idx: int) -> None:
        wk = self.workers[idx]
        if wk.state == "dead":
            return
        wk.crash()
        self.on_worker_dead(idx, graceful=False)

    def _fault_worker_leave(self, idx: int) -> None:
        wk = self.workers[idx]
        if wk.state == "dead":
            return
        wk.retire()
        if wk.state == "dead":
            # it was idle/blocked: no iteration to drain
            self.on_worker_dead(idx, graceful=True)

    def _fault_worker_join(self, idx: int) -> None:
        wk = self.workers[idx]
        if wk.state != "dead":
            return   # slot already alive; elasticity is over fixed slots
        self.active_workers.add(idx)
        self.policy.on_membership(self.active_workers)
        if isinstance(self.policy, BSPPolicy):
            # rejoin at the committed frontier; if a round is in flight
            # the joiner sits it out (its gather flows were abandoned at
            # round start and cannot re-enter a running barrier)
            at_it = self.policy.committed
            if self._bsp_round is not None:
                at_it = self._bsp_round.iteration + 1
        else:
            at_it = max(wk.it, self.max_applied_iter + 1)
        wk.rejoin(at_it)

    def _fault_ps_fail(self, ps: int, recover_s: float) -> None:
        if self._ps_down:
            return
        self._ps_down = True
        self._ps_epoch += 1   # fences queued publishes/commits/callbacks
        now = self.sim.now
        # every in-flight gradient loses its destination
        for (wkr, it) in list(self._flight):
            self.tel.record("ps_lost", now, worker=wkr, iteration=it)
        self._flight.clear()
        if self.net_des is not None:
            self.net_des.teardown_all()
        for g in self.policy.drop_pending():
            self.tel.record("ps_lost", now, worker=g.worker,
                            iteration=g.iteration)
        rnd = self._bsp_round
        if rnd is not None:
            for wkr in rnd.ready:
                self.tel.record("ps_lost", now, worker=wkr,
                                iteration=rnd.iteration)
            self._bsp_round = None
        self.ledger.fail(ps)
        self.sim.after(max(recover_s, 0.0), lambda: self._ps_failover(ps))

    def _ps_failover(self, ps: int) -> None:
        """Bring the PS back from the last snapshot: global rollback of
        model/optimizer/history, shard re-homing, and a barrier restart
        for bsp (surviving workers re-run from the committed frontier)."""
        if not self._ps_down or self._stopped:
            return
        snap = self._snap
        if snap is None:
            raise RuntimeError(
                "PS failed with no snapshot taken — arm the checkpoint "
                "grid (checkpoint_every_s / FaultConfig.checkpoint_every_s)"
                " when scheduling ps_fail events")
        params, opt_state = snap["params"], snap["opt_state"]
        if self._ckpt_dir is not None:
            # exercise the real durability path: restore the archive the
            # snapshot grid wrote, not the in-memory reference
            tree, _ = restore_checkpoint(
                self._ckpt_path(), {"params": params, "opt_state": opt_state})
            params, opt_state = tree["params"], tree["opt_state"]
        self.params, self.opt_state = params, opt_state
        self.residual = snap["residual"]
        self.version = snap["version"]
        self.max_applied_iter = snap["max_applied_iter"]
        self.step_idx = snap["step_idx"]
        del self.history[snap["n_hist"]:]
        self.policy.rollback(self.step_idx)
        if self.net_des is not None and self.n_ps > 1:
            moves = list(self.ledger.owner)
            self.net_des.set_shard_owners(moves)
            self.tel.record("rebalance", self.sim.now, owner=tuple(moves))
        self._ps_down = False
        self._visible = (self.version, self.params)
        self.tel.record("ps_failover", self.sim.now, ps=ps,
                        step=self.step_idx, n_hist=snap["n_hist"])
        if isinstance(self.policy, BSPPolicy):
            for wk in self.workers:
                if wk.state == "draining":
                    # its drain iteration was cancelled with the round;
                    # complete the leave instead of wedging the barrier
                    wk.state = "dead"
                    if wk._compute_eid is not None:
                        self.sim.cancel(wk._compute_eid)
                        wk._compute_eid = None
                    wk.busy = False
                    self.tel.record("lifecycle", self.sim.now,
                                    worker=wk.idx, state="dead",
                                    iteration=wk.it, reason="leave")
                    self.on_worker_dead(wk.idx, graceful=True)
            for wk in self.workers:
                if wk.state != "dead":
                    wk.reset_to(self.step_idx)
            for wk in self.workers:
                if wk.state != "dead":
                    wk._try_begin()
        else:
            self.wake_blocked()
        self.maybe_finish()

    def _fault_ps_recover(self, ps: int) -> None:
        moves = self.ledger.recover(ps)
        if moves and self.net_des is not None and self.n_ps > 1:
            self.net_des.set_shard_owners(list(self.ledger.owner))
            self.tel.record("rebalance", self.sim.now,
                            owner=tuple(self.ledger.owner))

    def _ckpt_path(self) -> str:
        return os.path.join(self._ckpt_dir, "runtime_ckpt")

    def _take_snapshot(self) -> None:
        """Periodic async snapshot on the Sim.every grid. In-memory by
        default (jax trees are immutable, so a reference is a copy);
        with ``checkpoint_dir`` the params/opt tree also round-trips
        through repro.checkpoint's npz archive."""
        self._snap = {
            "params": self.params,
            "opt_state": self.opt_state,
            "residual": self.residual,
            "version": self.version,
            "max_applied_iter": self.max_applied_iter,
            "step_idx": self.step_idx,
            "n_hist": len(self.history),
            "t": self.sim.now,
        }
        if self._ckpt_dir is not None:
            save_checkpoint(
                self._ckpt_path(),
                {"params": self.params, "opt_state": self.opt_state},
                step=self.step_idx)
        self.tel.record("checkpoint", self.sim.now, step=self.step_idx,
                        n_hist=len(self.history))

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def maybe_finish(self) -> None:
        if self._stopped or not self.workers:
            return
        if not all(wk.finished or wk.state == "dead"
                   for wk in self.workers):
            return
        if self._flight or self._bsp_round is not None:
            return
        if self._ps_down:
            return   # failover is scheduled; it restarts or finishes us
        if self.policy.pending_count():
            return
        self._stopped = True
        if self.net_des is not None:
            self.net_des.stop()
        if self._sampler_cancel is not None:
            self._sampler_cancel()
        if self._ckpt_cancel is not None:
            self._ckpt_cancel()
        if self._budget_cancel is not None:
            self._budget_cancel()

    _sampler_cancel = None

    def run(self, batches, *, epoch_steps: int = 0, eval_fn=None,
            eval_every: int = 0, log_every: int = 0,
            max_events: int = 200_000_000) -> List[Dict]:
        self._batches = list(batches)
        self.steps = len(self._batches)
        self._epoch_steps = epoch_steps
        self._eval_fn = eval_fn
        self._eval_every = eval_every
        self._log_every = log_every
        self.workers = [WorkerActor(self, i) for i in range(self.w)]
        if self._fault_cfg is not None and self.faults is None:
            # horizon estimate for the random churn draw: the schedule
            # only needs a rough upper bound on run length
            base = float(getattr(self.compute, "base", 0.05))
            t_end = max(self.steps * base * 3.0, 1.0)
            self.faults = schedule_from_config(self._fault_cfg, self.w, t_end)
        if self.faults is not None or self._ckpt_every > 0:
            self._take_snapshot()    # t=0 anchor: failover always has one
        if self._ckpt_every > 0:
            self._ckpt_cancel = self.sim.every(self._ckpt_every,
                                               self._take_snapshot)
        if self.faults is not None:
            self.faults.arm(self.sim, self.on_fault)
        if self._netfault_cfg is not None and self.net_faults is None:
            base = float(getattr(self.compute, "base", 0.05))
            t_end = max(self.steps * base * 3.0, 1.0)
            self.net_faults = netfault_schedule_from_config(
                self._netfault_cfg, self.topology, t_end)
        if self.net_faults is not None and len(self.net_faults) > 0 \
                and self.net_des is not None:
            # fabric faults armed: build the plane over the live DES
            # topology and turn on sender self-healing (RTO backoff +
            # blackhole abort -> on_flow_dead). An EMPTY schedule skips
            # all of this, so pipes stay unfaulted and senders keep the
            # exact unhealed timing (zero-fault parity pin).
            self.netfault_plane = NetFaultPlane(
                self.sim, self.net_des.topo, self.topology,
                seed=self.seed, on_event=self._on_netfault,
                on_path=self._on_path_state)
            self.net_faults.arm(self.sim, self.netfault_plane.dispatch)
            self.net_des.enable_healing(self.on_flow_dead)
        if self.budget is not None:
            self.budget.bind(self)
            self._budget_cancel = self.sim.every(self.budget.interval_s,
                                                 self.budget.tick)
        if self.net_des is not None and self.tel.enabled:
            # trunk-queue sampler: an actor hook on the shared clock.
            # The O(n_ps) topology walk lives HERE, on the wall grid —
            # never in a per-event hook (DESIGN.md §9/§12).
            interval = max(self.net.rtprop_ms * 1e-3, 1e-3)
            if self.tracker is not None:
                # tracker-active arm: per-trunk depths (feeds the trace
                # exporter's per-trunk counter tracks) + histograms.
                # Separate lambda so tracker="none" keeps the exact old
                # event payload, byte for byte.
                h_pend = self.metrics.histogram("queue/ps_pending")
                h_net = self.metrics.histogram("queue/trunk_max_pkts")
                sample_trunks = self.obs_cfg.sample_trunks

                def _sample():
                    depth = self.policy.pending_count()
                    net_depth = self.net_des.queue_depth_pkts()
                    h_pend.observe(depth)
                    h_net.observe(net_depth)
                    if sample_trunks:
                        self.tel.record(
                            "queue", self.sim.now, depth=depth,
                            net_depth=net_depth,
                            trunks=self.net_des.trunk_depths())
                    else:
                        self.tel.record("queue", self.sim.now, depth=depth,
                                        net_depth=net_depth)

                self._sampler_cancel = self.sim.every(interval, _sample)
            else:
                self._sampler_cancel = self.sim.every(
                    interval,
                    lambda: self.tel.record(
                        "queue", self.sim.now,
                        depth=self.policy.pending_count(),
                        net_depth=self.net_des.queue_depth_pkts()))
        if self.tracker is not None:
            self._perf0 = PERF.snapshot()
        for wk in self.workers:
            wk.start()
        self.sim.run(max_events=max_events)
        if self.sim.truncated:
            n_done = sum(1 for wk in self.workers
                         if wk.finished or wk.state == "dead")
            raise RuntimeError(
                f"co-simulation truncated at max_events={max_events} "
                f"(t={self.sim.now:.3f}s, {n_done}/{self.w} "
                f"workers finished) — raise max_events or shrink the "
                f"scenario; a truncated run must not pass as converged")
        if not self._stopped and self._ps_down:
            raise RuntimeError(
                "event loop drained while the PS was down — the failover "
                "event was lost; a wedged run must not pass as converged")
        if self.net_des is not None:
            self.net_des.stop()
        if self._sampler_cancel is not None:
            self._sampler_cancel()
        if self._ckpt_cancel is not None:
            self._ckpt_cancel()
        if self._budget_cancel is not None:
            self._budget_cancel()
        self._finalize_history()
        if self.tracker is not None:
            self._emit_observability()
        return self.history

    def _finalize_history(self) -> None:
        """Force the lazy jax scalars the commit paths deferred (loss /
        realized fraction) into plain floats, AFTER the event loop has
        drained — one sync at the end instead of one per iteration."""
        for rec in self.history:
            for k in ("loss", "delivered"):
                v = rec.get(k)
                if v is not None and not isinstance(v, (int, float)):
                    rec[k] = float(v)
        for e in self.tel.events:
            v = e.get("loss")
            if v is not None and not isinstance(v, (int, float)):
                e["loss"] = float(v)

    def _emit_observability(self) -> None:
        """Final flush into the tracker (DESIGN.md §12), AFTER
        ``_finalize_history`` forced the lazy jax scalars: per-step
        metric points from the history, the metrics-registry snapshot
        (PERF delta for this run, cumulative per-flow/per-switch
        protocol counters) folded into the run summary, then
        ``finish()`` — the only point where file I/O may block."""
        perf = PERF.snapshot()
        self.metrics.absorb(
            "sim", {k: v - self._perf0.get(k, 0) for k, v in perf.items()})
        if self.net_des is not None:
            self.metrics.absorb("flow", self.net_des.flow_stats())
        for rec in self.history:
            self.tracker.log_metrics(
                {k: v for k, v in rec.items()
                 if isinstance(v, (int, float))},
                step=int(rec["step"]))
        summary = dict(self.tel.summary())
        summary.update(self.metrics.snapshot())
        self.tracker.log_summary(summary)
        self.tracker.finish()

    def export_trace(self, path: str,
                     meta: Optional[dict] = None) -> dict:
        """Write this run's event stream as a Chrome trace (Perfetto-
        loadable; DESIGN.md §12). Call after ``run()``; returns the
        trace document."""
        from repro.obs.trace import write_chrome_trace
        base = {"policy": type(self.policy).__name__,
                "protocol": self.protocol, "transport": self.transport,
                "seed": self.seed}
        if meta:
            base.update(meta)
        return write_chrome_trace(path, self.tel.events, n_workers=self.w,
                                  n_ps=self.n_ps, meta=base)

    # throughput in items/sec of simulated wall-clock
    def throughput(self, items_per_step: int) -> float:
        if not self.history:
            return 0.0
        n_iters = (len(self.history) if isinstance(self.policy, BSPPolicy)
                   else self.steps)
        return items_per_step * n_iters / max(self.sim_time, 1e-12)
