"""Network-simulator invariants + protocol behaviour (paper's §V setups)."""
import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig
from repro.net.ltp_receiver import LTPFlowReceiver
from repro.net.scenarios import (
    fairness_share, incast_gather, p2p_transfer,
)
from repro.net.simcore import Packet, Pipe, Sim


def test_pipe_serialization_and_delay():
    sim = Sim()
    pipe = Pipe(sim, rate_bps=8e6, delay=0.01, loss=0.0, queue_pkts=10,
                rng=np.random.default_rng(0))
    got = []
    for i in range(3):
        pipe.send(Packet(0, i, 1000), lambda p: got.append((sim.now, p.seq)))
    sim.run()
    # 1000B at 1MB/s = 1ms serialization each, +10ms delay
    times = [t for t, _ in got]
    np.testing.assert_allclose(times, [0.011, 0.012, 0.013], rtol=1e-6)


def test_sim_truncation_warns_and_flags():
    """Hitting max_events with work pending must be loud: RuntimeWarning
    + sim.truncated, so a cut-off co-simulation can't pass as converged."""
    sim = Sim()

    def chain():
        sim.after(1e-3, chain)

    chain()
    with pytest.warns(RuntimeWarning, match="max_events"):
        sim.run(max_events=5)
    assert sim.truncated and sim.pending()
    # a clean run leaves the flag untouched
    sim2 = Sim()
    sim2.after(0.1, lambda: None)
    sim2.run(max_events=5)
    assert not sim2.truncated


def test_sim_every_hook():
    sim = Sim()
    ticks = []
    cancel = sim.every(0.01, lambda: ticks.append(sim.now))
    sim.after(0.055, cancel)
    sim.run()
    np.testing.assert_allclose(ticks, [0.01, 0.02, 0.03, 0.04, 0.05])


def test_pipe_loss_and_conservation():
    sim = Sim()
    rng = np.random.default_rng(1)
    pipe = Pipe(sim, 1e9, 0.001, loss=0.3, queue_pkts=10_000, rng=rng)
    got = []
    n = 2000
    for i in range(n):
        pipe.send(Packet(0, i, 1000), lambda p: got.append(p.seq))
    sim.run()
    # delivered + dropped == sent
    assert len(got) + pipe.n_dropped_loss == n
    assert abs(len(got) / n - 0.7) < 0.05


def test_droptail_queue():
    sim = Sim()
    pipe = Pipe(sim, 8e3, 0.0, 0.0, queue_pkts=5, rng=np.random.default_rng(0))
    ok = [pipe.send(Packet(0, i, 1500), lambda p: None) for i in range(50)]
    assert sum(ok) < 50 and sum(ok) >= 5
    assert pipe.n_dropped_queue == 50 - sum(ok)


@pytest.mark.parametrize("proto", ["reno", "cubic", "bbr", "ltp"])
def test_p2p_completes_under_loss(proto):
    net = NetConfig(bandwidth_gbps=1, rtprop_ms=2, loss_rate=0.01,
                    queue_pkts=1024)
    r = p2p_transfer(proto, net, 5e5, seed=2)
    assert 0 < r["fct"] < 60
    assert r["utilization"] > 0.005


def test_loss_hurts_tcp_not_ltp():
    """Fig 4 direction: order-preserving CCAs collapse with loss; LTP holds."""
    clean = NetConfig(10, 1, 0.0, 1024)
    lossy = NetConfig(10, 1, 0.01, 1024)
    for proto, min_keep in [("cubic", 0.0), ("ltp", 0.55)]:
        a = p2p_transfer(proto, clean, 4e6, seed=1)["utilization"]
        b = p2p_transfer(proto, lossy, 4e6, seed=1)["utilization"]
        if proto == "cubic":
            assert b < 0.35 * a   # collapses
        else:
            assert b > min_keep * a  # holds


def test_incast_ltp_early_close_bounds_bst():
    net = NetConfig(10, 1, 0.0, 4096)
    ltp = LTPConfig()
    rs = incast_gather("ltp", net, 8, 1e6, iters=6, seed=4,
                       straggler_prob=0.5, straggler_scale=1.0)
    ect = 1.5e-3 + 1e6 / (10e9 / 8 / 8)
    deadline_bound = 3 * (ect + ltp.deadline_c_ms * 1e-3)
    for r in rs:
        assert r.bst_gather <= deadline_bound
        assert 0.3 <= r.delivered.mean() <= 1.0
        assert r.criticals_ok


def test_incast_tcp_reliable():
    net = NetConfig(10, 1, 0.001, 4096)
    rs = incast_gather("cubic", net, 4, 5e5, iters=3, seed=5)
    for r in rs:
        np.testing.assert_array_equal(r.delivered, 1.0)


def test_incast_ltp_beats_cubic_bst_under_loss():
    net = NetConfig(10, 1, 0.005, 4096)
    bl = np.mean([r.bst_gather for r in
                  incast_gather("ltp", net, 8, 1e6, iters=6, seed=6)])
    bc = np.mean([r.bst_gather for r in
                  incast_gather("cubic", net, 8, 1e6, iters=6, seed=6)])
    assert bl < bc


def test_fairness_ltp_vs_bbr():
    a, b = fairness_share("ltp", "bbr", NetConfig(10, 1, 0.0, 4096),
                          duration=0.15, seed=0)
    assert 0.3 < a < 0.7   # paper Fig 15: near-even split


def test_ltp_receiver_bubbles():
    sim = Sim()
    fr = LTPFlowReceiver(sim, lambda p: None, 0)
    fr.on_data(Packet(0, -1, 64, kind="reg",
                      meta={"n": 10, "critical": np.zeros(10, bool)}),
               lambda: None)
    for s in [0, 2, 4, 6, 8]:
        fr.on_data(Packet(0, s, 100, kind="data", meta={}), lambda: None)
    bubbles = fr.bubbles()
    np.testing.assert_array_equal(bubbles, [False, True] * 5)
    assert fr.pct == 0.5


def test_lost_reg_does_not_deadlock_gather():
    """Regression: the registration packet is lost but every data packet
    lands and is acked. The sender must NOT finish on data-complete alone
    — the receiver cannot close (flow length / critical set unknown)
    until a retried reg arrives, so a sender that went silent here would
    deadlock the gather past its deadline."""
    from repro.net import senders as snd
    from repro.net.ltp_receiver import PSGatherReceiver

    sim = Sim()
    rng = np.random.default_rng(0)

    class DropFirstReg:
        def __init__(self, inner):
            self.inner = inner
            self.dropped = False

        def send(self, pkt, deliver):
            if pkt.kind == "reg" and not self.dropped:
                self.dropped = True
                return False        # eaten by the wire, exactly once
            return self.inner.send(pkt, deliver)

        def send_train(self, pkts, deliver_train, t_ready=None):
            return self.inner.send_train(pkts, deliver_train, t_ready)

    path = DropFirstReg(Pipe(sim, 1e9, 0.0005, 0.0, 10_000, rng))
    back = Pipe(sim, 1e9, 0.0005, 0.0, 10_000, rng)
    stops = {}
    ps = PSGatherReceiver(sim, [0], lt_threshold=0.005, deadline=0.05,
                          pct_threshold=0.8,
                          send_stop=lambda f: stops[f]())
    n = 20
    s = snd.LTPSender(sim, path, ps.on_data, n, flow=0, rng=rng)
    ps.attach_ack(0, lambda pkt: back.send(pkt, s.on_ack))
    stops[0] = lambda: back.send(Packet(0, -2, 41, kind="stop"), s.on_ack)
    sim.at(0.0, s.start)
    sim.run(until=10.0)
    assert path.dropped
    assert s.reg_acked           # the reg retry chain survived data-complete
    assert ps.closed             # and the gather closed on its arrival
    assert ps.flows[0].n == n
