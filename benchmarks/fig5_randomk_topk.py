"""Paper Fig 5: Top-k vs Random-k — top-1 accuracy and relative throughput
on the CIFAR-like workload (motivates LTP's Random-k-like loss profile).

Throughput model mirrors the paper's observation: Top-k pays a selection
overhead proportional to the gradient size (sort/threshold work on the
worker), Random-k is nearly free; both send k% of the data.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.configs import get_config
from repro.core import compression
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.models.cnn import accuracy
from repro.optim import make_optimizer

from benchmarks.common import emit


def _train(cfg, api, tc, data, test, kind: str, k: float, steps: int):
    opt = make_optimizer(tc)
    params = api.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    residual = None
    key = jax.random.PRNGKey(1)

    @jax.jit
    def step(params, state, batch, key, residual_flat):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch))(params)
        return loss, grads

    sel_times = []
    for i, b in enumerate(batches(data, tc.batch, steps)):
        b = {k2: jnp.asarray(v) for k2, v in b.items()}
        loss, grads = step(params, state, b, key, residual)
        t0 = time.perf_counter()
        if kind == "topk":
            grads, residual = compression.top_k(grads, k, residual)
            jax.block_until_ready(jax.tree.leaves(grads)[0])
        elif kind == "randomk":
            key, sub = jax.random.split(key)
            grads, residual = compression.random_k(grads, k, sub, residual)
            jax.block_until_ready(jax.tree.leaves(grads)[0])
        sel_times.append(time.perf_counter() - t0)
        upd, state = opt.update(grads, state, params, jnp.float32(tc.lr))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    acc = float(accuracy(cfg, params, test))
    return acc, float(np.median(sel_times))


def run(quick: bool = True):
    cfg = get_config("papernet").replace(d_model=8 if quick else 16,
                                         n_layers=3 if quick else 6)
    api = build(cfg)
    tc = TrainConfig(batch=128, lr=0.05)
    steps = 30 if quick else 120
    data = SyntheticCIFAR(seed=3)
    test = {k: jnp.asarray(v) for k, v in data.test_set(1024).items()}
    ks = [0.1, 0.4] if quick else [0.05, 0.1, 0.2, 0.3, 0.4, 0.7]
    rows = []
    base_acc, _ = _train(cfg, api, tc, data, test, "none", 1.0, steps)
    rows.append({"kind": "dense", "k": 1.0, "top1": round(base_acc, 4),
                 "rel_throughput": 1.0})
    for k in ks:
        for kind in ["randomk", "topk"]:
            acc, sel = _train(cfg, api, tc, data, test, kind, k, steps)
            # throughput: compute+comm fixed; selection overhead differs
            base_step = 0.05 + 0.02
            rel = base_step / (base_step + sel)
            rows.append({"kind": kind, "k": k, "top1": round(acc, 4),
                         "sel_overhead_ms": round(sel * 1e3, 2),
                         "rel_throughput": round(rel, 3)})
    return emit(rows, "fig5_randomk_topk")


if __name__ == "__main__":
    run(quick=False)
