"""Chrome-trace (Perfetto-loadable) export of a runtime event stream
(DESIGN.md §12).

``chrome_trace`` renders the §8 telemetry events of a ``ClusterRuntime``
run as a Trace Event Format document — open it at https://ui.perfetto.dev
or chrome://tracing. The track layout:

  pid 1 "workers"    per-worker threads: ``compute`` spans (compute_start
                     -> grad_ready, clipped at a crash), ``blocked``
                     spans (block -> unblock), lifecycle + per-worker
                     Early-Close instants.
  pid 2 "transport"  per-worker threads: ``transport`` spans from
                     grad_ready to the gradient's fate — grad_arrived
                     (async/ssp), the iteration's barrier commit (bsp),
                     or a flow_torn / ps_lost teardown.
  pid 3 "ps"         per-shard threads: shard Early-Close instants;
                     thread 0 additionally carries apply / checkpoint /
                     ps_failover / rebalance markers.
  pid 4 "net"        counter tracks from the ``Sim.every`` queue samples:
                     PS pending depth, max trunk depth, and (when the
                     sampler recorded per-trunk depths) one counter per
                     trunk.
  pid 5 "control"    injected fault markers (one instant per FaultEvent);
                     thread 1 carries fabric-fault markers (one instant
                     per LinkFaultEvent plus reroute/blackhole path
                     transitions, DESIGN.md §14) and thread 2 the
                     budget-controller pct_threshold counter.

Spans are ``X`` (complete) events in microseconds of sim time; tracks
exist for every worker/PS slot via thread_name metadata even when empty,
so a trace of a degraded run still shows who was silent.

``validate_chrome_trace`` is the schema smoke CI runs on the exported
artifact: parses, one track per worker/PS, spans well-nested per track,
fault markers present when demanded.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence

PID_WORKERS = 1
PID_TRANSPORT = 2
PID_PS = 3
PID_NET = 4
PID_CONTROL = 5

_US = 1e6   # sim seconds -> trace microseconds


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[dict]:
    out = [{"name": "process_name", "ph": "M", "pid": pid,
            "args": {"name": name}}]
    if tid is not None:
        out.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid, "args": {"name": tname or f"{name} {tid}"}})
    return out


def _span(name: str, pid: int, tid: int, t0: float, t1: float,
          args: Optional[dict] = None) -> dict:
    ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
          "ts": t0 * _US, "dur": max(0.0, (t1 - t0)) * _US, "cat": "sim"}
    if args:
        ev["args"] = args
    return ev


def _instant(name: str, pid: int, tid: int, t: float,
             args: Optional[dict] = None, scope: str = "t") -> dict:
    ev = {"name": name, "ph": "i", "s": scope, "pid": pid, "tid": tid,
          "ts": t * _US, "cat": "sim"}
    if args:
        ev["args"] = args
    return ev


def _counter(name: str, series: Dict[str, float], t: float) -> dict:
    return {"name": name, "ph": "C", "pid": PID_NET, "tid": 0,
            "ts": t * _US, "args": series}


def chrome_trace(events: Iterable[dict], *, n_workers: Optional[int] = None,
                 n_ps: Optional[int] = None,
                 meta: Optional[dict] = None) -> Dict[str, Any]:
    """Render a telemetry event stream (``Telemetry.events``) into a
    Trace Event Format document (dict; ``json.dump``-able).

    ``n_workers`` / ``n_ps`` pin how many worker/PS tracks exist even if
    some recorded no events (inferred from the stream otherwise);
    ``meta`` lands in ``otherData`` for provenance (config, seed).
    """
    evs = list(events)
    workers = set(range(n_workers or 0))
    shards = set(range(n_ps or 0))
    for e in evs:
        if "worker" in e:
            workers.add(int(e["worker"]))
        if "shard" in e:
            shards.add(int(e["shard"]))
    if not shards:
        shards.add(0)
    t_end = evs[-1]["t"] if evs else 0.0
    # bsp runs record no grad_arrived: the iteration's apply commits every
    # open transport span instead (see module docstring)
    has_arrivals = any(e["kind"] == "grad_arrived" for e in evs)

    out: List[dict] = []
    out += _meta(PID_WORKERS, "workers")
    out += _meta(PID_TRANSPORT, "transport")
    out += _meta(PID_PS, "ps")
    out += _meta(PID_NET, "net", 0, "queues")
    out += _meta(PID_CONTROL, "control", 0, "faults")
    out += _meta(PID_CONTROL, "control", 1, "fabric")[1:]
    out += _meta(PID_CONTROL, "control", 2, "budget")[1:]
    for w in sorted(workers):
        out += _meta(PID_WORKERS, "workers", w, f"worker {w}")[1:]
        out += _meta(PID_TRANSPORT, "transport", w, f"worker {w} flows")[1:]
    for p in sorted(shards):
        out += _meta(PID_PS, "ps", p, f"ps shard {p}")[1:]

    compute_open: Dict[int, dict] = {}          # worker -> compute_start
    block_open: Dict[int, float] = {}           # worker -> t(block)
    flight_open: Dict[tuple, float] = {}        # (worker, it) -> t(ready)

    def close_compute(w: int, t: float, status: str) -> None:
        e = compute_open.pop(w, None)
        if e is not None:
            out.append(_span("compute", PID_WORKERS, w, e["t"], t,
                             {"iteration": e.get("iteration"),
                              "status": status}))

    def close_flight(w: int, it: int, t: float, status: str,
                     args: Optional[dict] = None) -> None:
        t0 = flight_open.pop((w, it), None)
        if t0 is not None:
            out.append(_span("transport", PID_TRANSPORT, w, t0, t,
                             {"iteration": it, "status": status,
                              **(args or {})}))

    for e in evs:
        kind, t = e["kind"], e["t"]
        if kind == "compute_start":
            w = int(e["worker"])
            # a cancelled compute (crash/rollback) never saw grad_ready:
            # close the stale span at the next start so tracks stay sane
            close_compute(w, t, "superseded")
            compute_open[w] = e
        elif kind == "grad_ready":
            w = int(e["worker"])
            close_compute(w, t, "done")
            flight_open[(w, int(e["iteration"]))] = t
        elif kind == "grad_arrived":
            close_flight(int(e["worker"]), int(e["iteration"]), t,
                         "delivered", {"staleness": e.get("staleness"),
                                       "delivered": e.get("delivered")})
        elif kind == "apply":
            if not has_arrivals:
                it = int(e["step"])
                for (w, fit) in [k for k in flight_open if k[1] == it]:
                    close_flight(w, fit, t, "committed")
            out.append(_instant("apply", PID_PS, 0, t,
                                {"step": e.get("step"),
                                 "n_grads": e.get("n_grads"),
                                 "staleness_max": e.get("staleness_max")}))
        elif kind == "flow_torn":
            close_flight(int(e["worker"]), int(e["iteration"]), t, "torn")
        elif kind == "ps_lost":
            close_flight(int(e["worker"]), int(e["iteration"]), t, "lost")
        elif kind == "block":
            block_open.setdefault(int(e["worker"]), t)
        elif kind == "unblock":
            t0 = block_open.pop(int(e["worker"]), None)
            if t0 is not None:
                out.append(_span("blocked", PID_WORKERS, int(e["worker"]),
                                 t0, t))
        elif kind == "early_close":
            if "shard" in e:
                out.append(_instant("early_close", PID_PS, int(e["shard"]),
                                    t, {"delivered": e.get("delivered")}))
            else:
                out.append(_instant(
                    "early_close", PID_TRANSPORT,
                    int(e.get("worker", 0)), t,
                    {"delivered": e.get("delivered"),
                     "iteration": e.get("iteration")}))
        elif kind == "stale_drop":
            out.append(_instant("stale_drop", PID_PS, 0, t,
                                {"worker": e.get("worker"),
                                 "staleness": e.get("staleness")}))
        elif kind == "queue":
            series = {"ps_pending": e.get("depth", 0)}
            if "net_depth" in e:
                series["trunk_max_pkts"] = e["net_depth"]
            out.append(_counter("queues", series, t))
            trunks = e.get("trunks")
            if trunks:
                for i, d in enumerate(trunks):
                    out.append(_counter(f"trunk{i} queue_pkts",
                                        {"pkts": d}, t))
        elif kind == "fault":
            out.append(_instant(f"fault:{e.get('fault')}", PID_CONTROL, 0,
                                t, {"target": e.get("target")}, scope="g"))
        elif kind == "netfault":
            # fabric faults (DESIGN.md §14) get their own control
            # thread so a link_flap timeline reads as a dotted row
            # distinct from node crash/failover markers
            out.append(_instant(f"netfault:{e.get('fault')}", PID_CONTROL,
                                1, t, {"target": e.get("target")},
                                scope="g"))
        elif kind in ("reroute", "blackhole"):
            out.append(_instant(f"path:{kind}", PID_CONTROL, 1, t,
                                {"link": e.get("link")}))
        elif kind == "flow_dead":
            close_flight(int(e["worker"]), int(e["iteration"]), t, "dead")
        elif kind == "budget":
            out.append({"name": f"pct_threshold shard{e.get('shard')}",
                        "ph": "C", "pid": PID_CONTROL, "tid": 2,
                        "ts": t * _US, "args": {"pct": e.get("pct")}})
        elif kind == "lifecycle":
            w = int(e["worker"])
            if e.get("state") == "dead":
                close_compute(w, t, "dead")
            out.append(_instant(f"worker:{e.get('state')}", PID_WORKERS,
                                w, t, {"iteration": e.get("iteration"),
                                       "reason": e.get("reason")}))
        elif kind == "ps_failover":
            out.append(_instant("ps_failover", PID_PS, 0, t,
                                {"ps": e.get("ps"), "step": e.get("step"),
                                 "n_hist": e.get("n_hist")}, scope="g"))
        elif kind == "checkpoint":
            out.append(_instant("checkpoint", PID_PS, 0, t,
                                {"step": e.get("step")}))
        elif kind == "rebalance":
            out.append(_instant("rebalance", PID_PS, 0, t,
                                {"owner": list(e.get("owner", ()))}))
        # masks digests carry no timeline information: skipped

    # unmatched opens at stream end: clip to the last event
    for w, e in list(compute_open.items()):
        out.append(_span("compute", PID_WORKERS, w, e["t"],
                         max(t_end, e["t"] + e.get("dt", 0.0)),
                         {"iteration": e.get("iteration"),
                          "status": "open"}))
    for w, t0 in block_open.items():
        out.append(_span("blocked", PID_WORKERS, w, t0, t_end,
                         {"status": "open"}))
    for (w, it), t0 in flight_open.items():
        out.append(_span("transport", PID_TRANSPORT, w, t0, t_end,
                         {"iteration": it, "status": "open"}))

    doc: Dict[str, Any] = {"traceEvents": out, "displayTimeUnit": "ms"}
    other = {"n_workers": len(workers), "n_ps": len(shards),
             "n_events": len(evs)}
    if meta:
        other.update(meta)
    doc["otherData"] = other
    return doc


def write_chrome_trace(path: str, events: Iterable[dict],
                       **kw: Any) -> Dict[str, Any]:
    doc = chrome_trace(events, **kw)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def _well_nested(spans: Sequence[dict], eps: float = 1e-3) -> Optional[str]:
    """None if the track's spans form a proper containment forest;
    else a description of the first partial overlap."""
    ordered = sorted(spans, key=lambda s: (s["ts"], -s["dur"]))
    stack: List[dict] = []
    for s in ordered:
        end = s["ts"] + s["dur"]
        while stack and stack[-1]["ts"] + stack[-1]["dur"] <= s["ts"] + eps:
            stack.pop()
        if stack:
            top_end = stack[-1]["ts"] + stack[-1]["dur"]
            if end > top_end + eps:
                return (f"span {s['name']!r} [{s['ts']:.3f}, {end:.3f}]us "
                        f"partially overlaps {stack[-1]['name']!r} ending "
                        f"{top_end:.3f}us")
        stack.append(s)
    return None


def validate_chrome_trace(doc: Dict[str, Any],
                          n_workers: Optional[int] = None,
                          n_ps: Optional[int] = None,
                          require_fault_markers: bool = False,
                          require_netfault_markers: bool = False
                          ) -> List[str]:
    """Schema smoke over an exported trace; returns problem strings
    (empty = valid). Checks: JSON-shape, thread tracks for every
    worker/PS slot, at least one compute and one transport span, spans
    well-nested per (pid, tid) track, fault markers when demanded."""
    problems: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    try:
        json.dumps(doc)
    except (TypeError, ValueError) as e:  # non-serializable payloads
        problems.append(f"not JSON-serializable: {e}")
    threads = {(e["pid"], e.get("tid")) for e in evs
               if e.get("ph") == "M" and e.get("name") == "thread_name"}
    for w in range(n_workers or 0):
        if (PID_WORKERS, w) not in threads:
            problems.append(f"no worker track for worker {w}")
    for p in range(n_ps or 0):
        if (PID_PS, p) not in threads:
            problems.append(f"no ps track for shard {p}")
    spans_by_track: Dict[tuple, List[dict]] = {}
    names = set()
    for e in evs:
        if e.get("ph") == "X":
            if e.get("dur", -1.0) < 0:
                problems.append(f"negative duration on {e.get('name')!r}")
            spans_by_track.setdefault((e["pid"], e["tid"]), []).append(e)
            names.add(e.get("name"))
    if "compute" not in names:
        problems.append("no compute spans")
    if "transport" not in names:
        problems.append("no transport spans")
    for (pid, tid), spans in sorted(spans_by_track.items()):
        bad = _well_nested(spans)
        if bad:
            problems.append(f"track (pid={pid}, tid={tid}) not "
                            f"well-nested: {bad}")
    if require_fault_markers:
        if not any(e.get("ph") == "i"
                   and str(e.get("name", "")).startswith("fault:")
                   for e in evs):
            problems.append("no fault markers in a faulted run")
    if require_netfault_markers:
        if not any(e.get("ph") == "i"
                   and str(e.get("name", "")).startswith("netfault:")
                   for e in evs):
            problems.append("no netfault markers in a fabric-faulted run")
    return problems
