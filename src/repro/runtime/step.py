"""Shared JAX step machinery for the PS training loops (DESIGN.md §8).

The fused BSP step (vmap worker grads -> ONE masked multi-worker
reduction -> optimizer update) lives here so the legacy lockstep
``PSTrainer`` loop and the event-driven ``ClusterRuntime`` execute the
*same* jitted function — the bsp-equivalence guarantee is by
construction, not by parallel maintenance.

The per-gradient pieces (``build_worker_grad_fn`` / ``build_apply_fn`` /
``build_ef_gate_fn``) are the async/SSP path: under apply-on-arrival
aggregation each worker's gradient is computed against the params
version that worker actually fetched, so the fused vmap (which assumes
one shared params tree) cannot be used. The apply function always takes
a fixed-shape (W, n_packets, payload) buffer — shorter batches are
zero-weight padded — so it compiles exactly once per runtime.

Every ``build_*`` factory memoizes through a module-level jit cache
(DESIGN.md §9) keyed on (api, opt, ltp, plan geometry, W, protocol):
constructing a second ``ClusterRuntime``/``PSTrainer`` over the same
model and config reuses the already-compiled step instead of paying
XLA compilation again — that compile used to dominate the runtime DES
benchmark's wall clock. Cached entries pin their api/opt objects (the
key uses object identity), and the cache is LRU-bounded.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LTPConfig
from repro.core import ltp_sync as ls
from repro.core import packets as pk
from repro.optim import Optimizer

_JIT_CACHE: "OrderedDict[tuple, Tuple[Callable, tuple]]" = OrderedDict()
_JIT_CACHE_MAX = 32


def _plan_key(plan) -> tuple:
    """Structural identity of a PacketPlan (its arrays are unhashable)."""
    return (plan.packet_floats, plan.n_packets, plan.leaf_shapes,
            plan.leaf_offsets, plan.critical.tobytes())


def _cached(key: tuple, pins: tuple, build: Callable) -> Callable:
    """Return the memoized build() result for ``key``. ``pins`` holds
    strong references to the identity-keyed objects (api/opt) so their
    ids cannot be recycled while the entry lives."""
    hit = _JIT_CACHE.get(key)
    if hit is not None:
        _JIT_CACHE.move_to_end(key)
        return hit[0]
    fn = build()
    _JIT_CACHE[key] = (fn, pins)
    while len(_JIT_CACHE) > _JIT_CACHE_MAX:
        _JIT_CACHE.popitem(last=False)
    return fn


def build_fused_step(api, opt: Optimizer, ltp: LTPConfig, plan, w: int,
                     protocol: str):
    """The lockstep/BSP train step: per-worker grads via vmap, one fused
    masked reduction (kernel-backed under sync_backend="pallas"), one
    optimizer update. Signature:

      step(params, opt_state, residual, batch, masks, frac, lr)
        -> (params, opt_state, residual, mean_loss, realized_frac)
    """
    # id() keys a process-local jit cache only (api/opt objects are
    # unhashable); cache identity never touches the replayed sim state.
    key = ("fused", id(api), id(opt), ltp, _plan_key(plan), w, protocol)  # replint: ok(determinism)
    return _cached(key, (api, opt), lambda: _build_fused_step(
        api, opt, ltp, plan, w, protocol))


def _build_fused_step(api, opt: Optimizer, ltp: LTPConfig, plan, w: int,
                      protocol: str):
    use_ltp = protocol == "ltp"

    def per_worker_grads(params, batch):
        def one(b):
            return jax.value_and_grad(lambda p: api.loss_fn(p, b))(params)
        return jax.vmap(one)(batch)   # (W,) losses, (W, ...) grads

    def step(params, opt_state, residual, batch, masks, frac, lr):
        losses, grads_w = per_worker_grads(params, batch)
        flat_w = jax.vmap(lambda g: pk.flatten(plan, g))(grads_w)
        if use_ltp:
            # the PS hot loop: ONE fused masked multi-worker reduction
            # (kernels.packet_reduce under sync_backend="pallas")
            if residual is not None:
                # error feedback materializes the gated stream anyway —
                # gate once (dropfill under pallas), reduce the result
                flat_w = flat_w + residual
                sent = ls.apply_delivery(
                    flat_w.reshape(w * plan.n_packets, plan.packet_floats),
                    masks.reshape(-1), backend=ltp.sync_backend,
                    interpret=ltp.kernel_interpret,
                ).reshape(flat_w.shape)
                new_residual = flat_w - sent
                mean_flat = ls.reduce_packet_stream(
                    sent, masks, ltp, w, expected_frac=frac,
                    premasked=True)
            else:
                new_residual = None
                mean_flat = ls.reduce_packet_stream(
                    flat_w, masks, ltp, w, expected_frac=frac)
            realized = jnp.mean(masks)
        else:
            mean_flat = jnp.mean(flat_w, axis=0)
            new_residual = residual
            realized = jnp.ones(())
        dtypes = [x.dtype for x in jax.tree_util.tree_leaves(params)]
        mean_grads = pk.unflatten(plan, mean_flat, dtypes)
        updates, opt_state = opt.update(mean_grads, opt_state, params, lr)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, new_residual, jnp.mean(losses), realized

    return jax.jit(step)


def build_worker_grad_fn(api, plan):
    """One worker's gradient against ITS OWN params snapshot (the
    async/SSP compute leg): (params, batch_slice) -> (loss, flat packets
    of shape (n_packets, packet_floats))."""
    key = ("grad", id(api), _plan_key(plan))  # replint: ok(determinism)

    def build():
        @jax.jit
        def grad_fn(params, batch):
            loss, grads = jax.value_and_grad(
                lambda p: api.loss_fn(p, batch))(params)
            return loss, pk.flatten(plan, grads)

        return grad_fn

    return _cached(key, (api,), build)


def build_ef_gate_fn(ltp: LTPConfig):
    """Error-feedback gate for the per-gradient path: accumulate what the
    network dropped, re-add it next round (EF-SGD, DESIGN.md §2)."""

    def build():
        @jax.jit
        def gate(flat, residual, mask):
            flat = flat + residual
            sent = ls.apply_delivery(flat, mask, backend=ltp.sync_backend,
                                     interpret=ltp.kernel_interpret)
            return sent, flat - sent

        return gate

    return _cached(("ef", ltp), (), build)


def build_apply_fn(api, opt: Optimizer, ltp: LTPConfig, plan, w: int,
                   premasked: bool = False):
    """PS-side apply for an admitted batch of gradients (async/SSP).

    (params, opt_state, stacked (W, n, p), masks (W, n), weights (W,),
     frac, lr) -> (params, opt_state).

    The reduction divides by the cluster size ``w`` regardless of how
    many gradients the batch holds (zero-weight rows contribute nothing),
    so each admitted gradient lands with effective step lr * weight / W —
    the same per-contribution scale as one BSP iteration. ``weights``
    carries the policy's staleness damping (``ls.staleness_weights``).
    Note: under "count" compensation the per-packet deliverer count is
    taken within the admitted batch.
    """
    key = ("apply", id(api), id(opt), ltp, _plan_key(plan), w, premasked)  # replint: ok(determinism)

    def build():
        @jax.jit
        def apply(params, opt_state, stacked, masks, weights, frac, lr):
            mean_flat = ls.reduce_packet_stream(
                stacked, masks, ltp, w, expected_frac=frac,
                worker_weights=weights, premasked=premasked)
            dtypes = [x.dtype for x in jax.tree_util.tree_leaves(params)]
            mean_grads = pk.unflatten(plan, mean_flat, dtypes)
            updates, opt_state = opt.update(mean_grads, opt_state, params,
                                            lr)
            params = jax.tree.map(lambda p, u: p + u, params, updates)
            return params, opt_state

        return apply

    return _cached(key, (api, opt), build)


def draw_delivery_masks(plan, w: int, rng: np.random.Generator,
                        frac: np.ndarray,
                        mask_trace: np.ndarray = None,
                        it: int = 0) -> np.ndarray:
    """(W, n_packets) float32 per-(worker, packet) delivery mask.

    From the DES ``mask_trace`` when given (the trace's packet stream is
    tiled/cropped onto the plan's packets), else Bernoulli(frac) per
    packet. Critical packets are always pinned to 1 — the CQ retransmit
    guarantee (paper §III-E).
    """
    n = plan.n_packets
    if mask_trace is not None:
        m = mask_trace[it % len(mask_trace)]
        reps = -(-n // m.shape[1])
        m = np.tile(m, (1, reps))[:, :n].astype(np.float32)
    else:
        m = (rng.random((w, n)) < np.asarray(frac)[:, None]).astype(np.float32)
    m[:, plan.critical] = 1.0
    return m


def tile_mask_onto_plan(plan, mask_row: np.ndarray) -> np.ndarray:
    """(n_transport_pkts,) bool -> (plan.n_packets,) float32, tiled/cropped
    with criticals pinned — one worker's DES delivery state mapped onto
    the packet plan the aggregation kernels consume."""
    n = plan.n_packets
    reps = -(-n // len(mask_row))
    m = np.tile(mask_row, reps)[:n].astype(np.float32)
    m[plan.critical] = 1.0
    return m
