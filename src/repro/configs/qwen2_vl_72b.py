"""Qwen2-VL-72B — VLM backbone with M-RoPE and dynamic resolution [arXiv:2409.12191].

The ViT vision encoder + projector are STUBBED per the assignment:
``input_specs`` provides precomputed patch embeddings of shape
(batch, vision_patches, d_model); this config describes the language decoder.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    block_pattern=("A",),
    rope_theta=1e6,
    pos_type="mrope",
    mrope_sections=(16, 24, 24),   # temporal/height/width split of hd/2
    vision_patches=1024,           # stub ViT output length (dynamic-res capable)
    source="arXiv:2409.12191",
)

REDUCED = CONFIG.replace(
    name="qwen2-vl-72b-reduced",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv=4,
    head_dim=32,
    d_ff=512,
    vocab=512,
    mrope_sections=(4, 6, 6),
    vision_patches=16,
)
