"""Packet-level discrete-event transport simulator.

Reproduces the paper's protocol-level experiments at packet granularity:
Fig 3 (incast FCT long tail), Fig 4 (TCP under non-congestion loss),
Fig 12/14 (training throughput / BST), Fig 15 (fairness) — plus the
composable topology engine behind the multi-PS / straggler / cross-traffic
scenarios (DESIGN.md §5). Run any scenario by name via ``run_scenario``.
"""
from repro.net.simcore import (  # noqa: F401
    CrossTrafficSource,
    Packet,
    Pipe,
    Route,
    Sim,
    Topology,
)
from repro.net.aggtree import AggIngress, AggSwitch  # noqa: F401
from repro.net.scenarios import (  # noqa: F401
    PROTOCOLS,
    SCENARIOS,
    GatherSpec,
    cross_traffic,
    fairness_share,
    incast_gather,
    list_scenarios,
    multi_ps_gather,
    p2p_transfer,
    rack_spine_gather,
    run_scenario,
    straggler_gather,
    topology_gather,
    train_iterations,
)
# topology-first builders (DESIGN.md §11). The builder result class
# (repro.net.topology.Topology) is NOT re-exported by name here — it
# would shadow the simcore pipe registry above; use the builders.
from repro.net.topology import (  # noqa: F401
    APIDeprecationWarning,
    as_topology,
    flat,
    multi_ps,
    rack_spine,
    resolve_topology,
)
