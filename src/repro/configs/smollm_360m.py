"""SmolLM-360M — small llama-architecture dense model [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv=5,
    head_dim=64,
    d_ff=2560,
    vocab=49152,
    block_pattern=("A",),
    rope_theta=1e4,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

REDUCED = CONFIG.replace(
    name="smollm-360m-reduced",
    n_layers=2,
    d_model=192,
    n_heads=6,
    n_kv=2,
    head_dim=32,
    d_ff=512,
    vocab=512,
)
