"""The paper's primary contribution: loss-tolerant gradient synchronization.

  packets.py      float-aligned packetization + critical packets (SIII-C/E)
  early_close.py  LT-threshold / deadline controller (SIII-B)
  ltp_sync.py     masked-psum gradient sync under shard_map (the JAX core)
  compression.py  Top-k / Random-k baselines (SII-C)
"""
from repro.core.early_close import (  # noqa: F401
    AnalyticIncastModel,
    EarlyCloseController,
    MultiPSEarlyClose,
    broadcast_time,
    phase_pct_threshold,
)
from repro.core.ltp_sync import LTPSync, make_ltp_sync  # noqa: F401
from repro.core.packets import PacketPlan, make_plan  # noqa: F401
