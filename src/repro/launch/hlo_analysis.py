"""Loop-aware cost model over compiled (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts every instruction ONCE — scan
(while) bodies are not multiplied by trip count, which under-reports a
60-layer scan by 60x. This walker parses the HLO module text, recovers the
computation graph and per-name result types, reads while-loop trip counts
from ``backend_config={"known_trip_count":...}`` (fallback: the largest
int constant in the loop condition), and accumulates:

  flops             dot/convolution FLOPs (the dominant terms), x trips
  bytes             operand+output bytes of top-level instructions (fusion
                    internals excluded — they stay in VMEM/registers), x trips
  collective_bytes  operand bytes of all-reduce / all-gather /
                    reduce-scatter / all-to-all / collective-permute
                    (+ -start forms), x trips — per device, since the
                    module is the per-device SPMD partition

Heuristics (see benchmarks/README.md, roofline row):
  * `conditional` contributes its most expensive branch;
  * elementwise flops ignored (dot/conv dominate ML steps);
  * bytes is an upper bound on HBM traffic (no inter-op reuse modelling).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "reduce-scatter-start", "ragged-all-to-all",
}

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(
            self.flops * k, self.bytes * k, self.collective_bytes * k,
            {n: v * k for n, v in self.by_collective.items()},
        )


@dataclasses.dataclass
class Instruction:
    name: str
    out_type: str
    opcode: str
    rest: str             # text after the opening paren
    operand_names: List[str]


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instruction]] = {}
        self.types: Dict[str, str] = {}   # instruction name -> result type
        self._parse(text)
        self._cost_cache: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            s = line.strip()
            if not s or s.startswith("//"):
                continue
            if s.endswith("{") and ("(" in s) and ("=" not in s.split("(")[0]):
                header = s.split("(")[0].strip()
                cur = header.replace("ENTRY", "").strip().lstrip("%")
                self.computations[cur] = []
                if "ENTRY" in s:
                    self.entry = cur
                continue
            if s.startswith("}"):
                continue
            m = _INSTR_RE.match(line)
            if m and cur is not None:
                name, out_type, opcode, rest = m.groups()
                # operand names: within the call parens (up to un-nested ')')
                depth, end = 1, len(rest)
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                operands = _OPERAND_NAME_RE.findall(rest[:end])
                ins = Instruction(name, out_type, opcode, rest, operands)
                self.computations[cur].append(ins)
                self.types[name] = out_type

    # ------------------------------------------------------------------
    def _operand_bytes(self, ins: Instruction) -> int:
        inline = sum(_shape_bytes(s.group(0))
                     for s in _SHAPE_RE.finditer(ins.rest.split("),")[0]))
        if inline:
            return inline
        return sum(_shape_bytes(self.types.get(n, "")) for n in ins.operand_names)

    def _operand_type(self, ins: Instruction, idx: int) -> str:
        if idx < len(ins.operand_names):
            t = self.types.get(ins.operand_names[idx], "")
            if t:
                return t
        shapes = list(_SHAPE_RE.finditer(ins.rest))
        if idx < len(shapes):
            return shapes[idx].group(0)
        return ""

    def trip_count(self, ins: Instruction) -> int:
        mm = _TRIP_RE.search(ins.rest)
        if mm:
            return int(mm.group(1))
        mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
        best = 1
        if mc:
            for sub in self.computations.get(mc.group(1), []):
                if sub.opcode == "constant" and sub.out_type in ("s32[]", "u32[]"):
                    c = re.search(r"constant\((\d+)\)", sub.rest)
                    if c:
                        best = max(best, int(c.group(1)))
        return best

    def _dot_flops(self, ins: Instruction) -> float:
        out_elems = _shape_elems(ins.out_type)
        lhs_t = self._operand_type(ins, 0)
        mdims = _SHAPE_RE.search(lhs_t)
        if not mdims:
            return 0.0
        lhs_dims = [int(d) for d in mdims.group(2).split(",") if d]
        mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
        contract = 1
        if mm:
            for idx in mm.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, ins: Instruction) -> float:
        out_elems = _shape_elems(ins.out_type)
        k_t = self._operand_type(ins, 1)
        mdims = _SHAPE_RE.search(k_t)
        if not mdims:
            return 0.0
        k_dims = [int(d) for d in mdims.group(2).split(",") if d]
        if not k_dims:
            return 0.0
        cout = max(k_dims)
        kprod = 1
        for d in k_dims:
            kprod *= d
        return 2.0 * out_elems * kprod / max(cout, 1)

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._cost_cache:
            return self._cost_cache[comp_name]
        total = Cost()
        self._cost_cache[comp_name] = total   # cycle guard
        for ins in self.computations.get(comp_name, []):
            op = ins.opcode
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                trips = self.trip_count(ins)
                if mb:
                    total += self.cost_of(mb.group(1)).scaled(trips)
                continue
            if op == "conditional":
                names = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%?([\w.\-]+))", ins.rest)
                flat: List[str] = []
                for a, b in names:
                    if a:
                        flat += [x.strip().lstrip("%") for x in a.split(",")]
                    if b:
                        flat.append(b)
                if flat:
                    costs = [self.cost_of(n) for n in flat]
                    total += max(costs, key=lambda c: c.flops + c.bytes)
                continue
            if op == "fusion":
                mcalls = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if mcalls:
                    sub = self.cost_of(mcalls.group(1))
                    total.flops += sub.flops
                    total.collective_bytes += sub.collective_bytes
                    for k, v in sub.by_collective.items():
                        total.by_collective[k] = total.by_collective.get(k, 0) + v
                total.bytes += _shape_bytes(ins.out_type) + self._operand_bytes(ins)
                continue
            if op in ("call", "custom-call") or op.startswith("async"):
                mt = re.search(r"(?:to_apply|calls|called_computations=\{)[=]?%?([\w.\-]+)",
                               ins.rest)
                if mt and mt.group(1) in self.computations:
                    total += self.cost_of(mt.group(1))
                total.bytes += _shape_bytes(ins.out_type)
                continue
            if op in _COLLECTIVES:
                nbytes = self._operand_bytes(ins)
                base = op.replace("-start", "")
                total.collective_bytes += nbytes
                total.by_collective[base] = total.by_collective.get(base, 0) + nbytes
                total.bytes += nbytes + _shape_bytes(ins.out_type)
                continue
            if op == "dot":
                total.flops += self._dot_flops(ins)
            elif op == "convolution":
                total.flops += self._conv_flops(ins)
            if op in _SKIP_BYTES:
                continue
            total.bytes += _shape_bytes(ins.out_type) + self._operand_bytes(ins)
        self._cost_cache[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        name = getattr(self, "entry", None)
        if name is None:
            for n in self.computations:
                if n.startswith("main"):
                    name = n
            if name is None:
                name = list(self.computations)[-1]
        return self.cost_of(name)


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
