"""Calendar-queue event engine (DESIGN.md §9): execution-order parity
with the reference heap engine, FIFO tie-breaks under batch-pop, run()
semantics (until / max_events / cancel) on the calendar path, and the
pinned runtime-DES determinism contract across the engine swap."""
import numpy as np
import pytest

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.net import simcore
from repro.net.scenarios import run_scenario
from repro.net.simcore import Sim
from repro.optim import make_optimizer
from repro.runtime import ClusterRuntime, LognormalStragglerCompute

NET = NetConfig(10, 1, 0.001, 4096)


def test_engine_selection_and_default():
    assert Sim().engine == simcore.DEFAULT_ENGINE == "calendar"
    assert Sim(engine="heap")._wheel is None
    with pytest.raises(ValueError, match="unknown Sim engine"):
        Sim(engine="splay")


def _random_workload(engine, seed=0, n_events=4000):
    """Self-extending random schedule with duplicate timestamps and
    zero-delay reschedules; returns the (now, tag) execution log."""
    sim = Sim(engine=engine)
    rng = np.random.default_rng(seed)
    log = []

    def rec(tag):
        log.append((sim.now, tag))
        if len(log) < n_events:
            dt = float(rng.choice([0.0, 1e-9, 1e-6, 3.7e-5, 2e-3, 0.75]))
            sim.after(dt, lambda tag=tag: rec(tag + 10_000))

    for i in range(150):
        sim.at(float(rng.integers(0, 4)) * 1e-3, lambda i=i: rec(i))
    sim.run()
    return log


@pytest.mark.parametrize("seed", [0, 1, 7])
def test_calendar_matches_heap_exactly(seed):
    """Same-seed runs execute the same callbacks at the same times in
    the same order under either engine — (time, schedule-id) order is
    the contract both implement."""
    assert _random_workload("heap", seed) == _random_workload("calendar",
                                                              seed)


def test_same_timestamp_fifo_batch_pop():
    """All events at one timestamp run FIFO by schedule id, including
    events scheduled AT that timestamp from within the batch (they get
    fresh, higher ids and run after every already-queued peer)."""
    sim = Sim(engine="calendar")
    order = []
    for i in range(64):
        sim.at(1e-3, lambda i=i: order.append(i))
    # a batch member that enqueues a same-timestamp follow-up mid-batch
    sim.at(1e-3, lambda: (order.append("spawn"),
                          sim.after(0.0, lambda: order.append("child"))))
    sim.run()
    assert order == list(range(64)) + ["spawn", "child"]


def test_calendar_until_and_resume():
    sim = Sim(engine="calendar")
    seen = []
    for t in (0.001, 0.002, 5.0, 9.0):
        sim.at(t, lambda t=t: seen.append(t))
    sim.run(until=0.01)
    assert seen == [0.001, 0.002] and sim.pending() == 2
    sim.run()
    assert seen == [0.001, 0.002, 5.0, 9.0] and sim.pending() == 0


def test_calendar_cancel_including_batch_mates():
    sim = Sim(engine="calendar")
    got = []
    eids = [sim.at(1e-3, lambda i=i: got.append(i)) for i in range(4)]
    # event 0 cancels event 2, which sits in the SAME popped batch
    sim.at(1e-3 / 2, lambda: sim.cancel(eids[2]))
    sim.cancel(eids[3])
    sim.run()
    assert got == [0, 1]


def test_calendar_truncation_warns_and_flags():
    sim = Sim(engine="calendar")

    def chain():
        sim.after(1e-3, chain)

    chain()
    with pytest.warns(RuntimeWarning, match="max_events"):
        sim.run(max_events=5)
    assert sim.truncated and sim.pending()


def test_calendar_wide_timescale_mix():
    """ns-scale bursts and multi-second gaps in one run: recalibration
    plus the far heap keep ordering exact across 9 orders of magnitude."""
    a = _random_workload("heap", seed=3, n_events=6000)
    b = _random_workload("calendar", seed=3, n_events=6000)
    assert a == b
    times = [t for t, _ in b]
    assert times == sorted(times)           # now never runs backwards


# ---------------------------------------------------------------------------
# hypothesis: batch-popped same-timestamp events preserve FIFO order
# ---------------------------------------------------------------------------

try:        # property tests run wherever the test extra is installed (CI);
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # the seeded sweeps above cover the seed container
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=6), min_size=1,
                    max_size=120))
    def test_hypothesis_fifo_among_equal_timestamps(slot_ids):
        """Events drawn onto a handful of duplicate-heavy timestamps
        must execute in (time, schedule-id) order — i.e. FIFO inside
        every same-timestamp batch — under the calendar engine, exactly
        matching the heap engine."""
        slots = [0.0, 1e-9, 1e-6, 1e-3, 1e-3, 0.5, 2.0]   # dup on purpose

        def drive(engine):
            sim = Sim(engine=engine)
            log = []
            for i, s in enumerate(slot_ids):
                sim.at(slots[s], lambda i=i: log.append((sim.now, i)))
            sim.run()
            return log

        cal = drive("calendar")
        assert cal == drive("heap")
        expect = sorted(range(len(slot_ids)),
                        key=lambda i: (slots[slot_ids[i]], i))
        assert [i for _, i in cal] == expect


# ---------------------------------------------------------------------------
# pinned runtime DES determinism across the engine swap
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def api():
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    return build(cfg)


def _des_history(api, engine, policy, monkeypatch_ctx):
    monkeypatch_ctx.setattr(simcore, "DEFAULT_ENGINE", engine)
    w, steps = 4, 4
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
    compute = LognormalStragglerCompute(w, base=0.05, seed=11, sigma=0.3,
                                        straggler_prob=0.25,
                                        straggler_mult=4.0)
    kw = {"policy_kw": {"staleness": 2}} if policy == "ssp" else {}
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(staleness_comp=0.5), NET,
        n_workers=w, protocol="ltp", policy=policy, compute_model=compute,
        compute_time=0.05, seed=11, transport="des", **kw)
    assert rt.sim.engine == engine
    rt.run(batches(SyntheticCIFAR(seed=3), tc.batch, steps),
           epoch_steps=2)
    closes = [(e["t"], e.get("worker", e.get("shard")), e["delivered"])
              for e in rt.tel.of("early_close")]
    masks = [(e["t"], e.get("worker"), e["digest"])
             for e in rt.tel.of("masks")]
    hist = [(r["step"], r["sim_time"], round(float(r["delivered"]), 12))
            for r in rt.history]
    return hist, closes, masks


@pytest.mark.parametrize("policy", ["bsp", "async"])
def test_runtime_des_history_pinned_across_engines(api, policy,
                                                   monkeypatch):
    """The determinism contract of the engine swap: iteration close
    times, delivered fractions, and per-iteration delivery-mask digests
    of a same-seed packet-level co-simulation are IDENTICAL under the
    heap and calendar engines."""
    with monkeypatch.context() as m:
        heap = _des_history(api, "heap", policy, m)
    with monkeypatch.context() as m:
        cal = _des_history(api, "calendar", policy, m)
    assert heap[0] == cal[0]        # history: steps, sim times, delivered
    assert heap[1] == cal[1]        # early-close firing times + fractions
    assert heap[2] == cal[2]        # delivery-mask digests


def test_netsim_scenario_pinned_across_engines(monkeypatch):
    """Scenario-level A/B: the full multi-PS gather (trains, cross
    traffic machinery, LT/deadline timers) produces identical delivery
    masks and close times under both engines."""
    out = {}
    for engine in ("heap", "calendar"):
        with monkeypatch.context() as m:
            m.setattr(simcore, "DEFAULT_ENGINE", engine)
            rs = run_scenario("multi_ps_gather", "ltp", NET, w=16,
                              size_bytes=4e5, n_ps=2, iters=2, seed=5,
                              coalesce=8)
        out[engine] = rs
    for a, b in zip(out["heap"], out["calendar"]):
        assert a.bst_gather == b.bst_gather
        np.testing.assert_array_equal(a.delivered, b.delivered)
        np.testing.assert_array_equal(a.masks, b.masks)


# ---------------------------------------------------------------------------
# flow pooling: objects are reused, generations fence the rounds
# ---------------------------------------------------------------------------


def test_des_transport_pools_flows_across_iterations(api):
    """The bsp DES path must not reconstruct its flow graph each round:
    the barrier gather, its senders, and the per-flow back pipes are
    the same objects across iterations, fenced by a bumped generation."""
    w, steps = 4, 3
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(), NET, n_workers=w,
        protocol="ltp", policy="bsp", compute_time=0.05, seed=0,
        transport="des")
    rt.run(batches(SyntheticCIFAR(seed=0), tc.batch, steps))
    tr = rt.net_des
    barrier = tr._barrier
    assert barrier is not None and barrier.gen == steps
    assert len(barrier._senders) == w * tr.n_ps      # one per (ps, worker)
    for s in barrier._senders.values():
        assert s.gen == steps                        # reset every round
    assert barrier.sharded.shard(0).gen == steps


def test_des_flowset_pool_reuse_async(api):
    w, steps = 4, 4
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=steps)
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(staleness_comp=0.5), NET,
        n_workers=w, protocol="ltp", policy="async", compute_time=0.05,
        seed=0, transport="des")
    rt.run(batches(SyntheticCIFAR(seed=0), tc.batch, steps))
    pools = rt.net_des._flowsets
    assert set(pools) == set(range(w))
    for worker, pool in pools.items():
        # far fewer flow-set objects than iterations: reuse worked
        assert 1 <= len(pool) < steps
        assert sum(f.gen for f in pool) == steps     # every round served
        assert all(f.idle for f in pool)             # all rounds closed


def test_stale_generation_restops_orphaned_sender(api):
    """A sender whose Early-Close stop was lost keeps retransmitting
    into receivers that have advanced a generation; the on_stale hook
    must re-stop it — but only while it still lives the stale
    generation (a reset sender must not be killed by its past round)."""
    from repro.net.simcore import Packet
    from repro.runtime.transport import DESTransport

    w = 2
    tc = TrainConfig(batch=4 * w, lr=0.05, steps=2)
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(), NET, n_workers=w,
        protocol="ltp", policy="bsp", compute_time=0.05, seed=0,
        transport="des")
    assert isinstance(rt.net_des, DESTransport)
    rt.run(batches(SyntheticCIFAR(seed=0), tc.batch, 2))
    barrier = rt.net_des._barrier
    shard = barrier.sharded.shard(0)
    s = barrier._senders[(0, 0)]
    # forge an orphan: sender pinned one generation behind the receiver
    s.reset(gen=shard.gen - 1)
    s.done = False
    stale = Packet(0, 3, 100, kind="data",
                   meta={"t": 0.0, "order": 0, "g": shard.gen - 1})
    shard.on_data(stale)
    rt.sim.run()                    # deliver the re-sent stop
    assert s.done and s.stopped     # orphan was stopped, not ignored
    # a CURRENT-generation sender must never be stopped by stale data
    s2 = barrier._senders[(0, 1)]
    s2.reset(gen=shard.gen)
    s2.done = False
    shard.on_data(Packet(1, 3, 100, kind="data",
                         meta={"t": 0.0, "order": 0, "g": shard.gen - 1}))
    rt.sim.run()
    assert not s2.stopped


def test_teardown_fences_pooled_flow_then_pool_reuses_it():
    """Node-death fencing on the pooled fast path (DESIGN.md §10): a
    torn flow set's callback must never fire — its receivers bump a
    generation, so every in-flight packet is provably dropped as stale
    — and the SAME pooled object must serve the next send cleanly."""
    from repro.runtime.transport import DESTransport

    sim = Sim()
    tr = DESTransport(sim, NET, LTPConfig(), "ltp", 2, 4096.0, seed=0)
    fired = []
    tr.send(0, lambda masks, frac, early: fired.append("torn"))
    fs = tr._flowsets[0][0]
    gen0 = fs.gen
    assert not fs.idle
    sim.run(until=sim.now + 1e-4)       # mid-flight: packets on the wire
    tr.teardown_worker(0)
    assert fs.idle                      # returned to the pool, silenced
    assert fs.gen == gen0 + 1           # generation fence bumped
    sim.run(until=sim.now + 0.5)        # drain the torn round's packets
    assert fired == []                  # dead flow never delivered
    # the pool must hand back the same object, good as new
    tr.send(0, lambda masks, frac, early: fired.append("clean"))
    assert tr._flowsets[0][0] is fs and not fs.idle
    sim.run(until=sim.now + 0.5)
    tr.stop()
    assert fired == ["clean"]           # reused flow delivers exactly once


def test_cancelled_ghost_beyond_until_pending_parity():
    """A cancelled event beyond ``until`` must be discarded by both
    engines (the heap drops a cancelled head regardless of until), so
    pending()-driven driver loops terminate identically."""
    for engine in ("heap", "calendar"):
        sim = Sim(engine=engine)
        sim.cancel(sim.at(5.0, lambda: None))
        sim.run(until=1.0)
        assert sim.pending() == 0, engine
        # near-wheel variant: a live event pulls the ghost into the wheel
        sim2 = Sim(engine=engine)
        sim2.at(0.5, lambda: None)
        sim2.cancel(sim2.at(0.9, lambda: None))
        sim2.run(until=0.7)
        assert sim2.pending() == 0, engine
