from repro.data.synthetic import (  # noqa: F401
    SyntheticCIFAR,
    SyntheticLM,
    batches,
)
