"""Trainer integration: plain vs LTP shard_map train steps agree at full
delivery; the ZeRO-packet variant matches the psum variant numerically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.config import LTPConfig
from repro.configs import get_reduced
from repro.core import ltp_sync as ls
from repro.models import build
from repro.models.api import demo_inputs
from repro.optim import sgd_momentum
from repro.shapes import InputShape
from repro.train.trainer import (
    TrainState, init_state, make_ltp_train_step, make_plain_train_step,
)


def _mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("smollm_360m").replace(dtype="float32")
    api = build(cfg)
    opt = sgd_momentum()
    state = init_state(api, opt, jax.random.PRNGKey(0))
    batch = demo_inputs(cfg, InputShape("t", 64, 4, "train"),
                        jax.random.PRNGKey(1))
    return cfg, api, opt, state, batch


def test_ltp_full_delivery_matches_plain(setup):
    cfg, api, opt, state, batch = setup
    mesh = _mesh()
    lr = jnp.float32(0.1)
    plain = make_plain_train_step(api, opt)
    s_plain, m_plain = plain(state, batch, lr)

    ltp_cfg = LTPConfig(packet_floats=128)
    with compat.set_mesh(mesh):
        step = make_ltp_train_step(api, opt, mesh, ltp_cfg, ("data",),
                                   jax.tree.map(lambda _: P(), batch))
        s_ltp, m_ltp = step(state, batch, jnp.ones((1,)),
                            jax.random.PRNGKey(2), lr)
    np.testing.assert_allclose(float(m_ltp["loss"]), float(m_plain["loss"]),
                               rtol=1e-5)
    assert float(m_ltp["delivered_frac"]) == 1.0
    for a, b in zip(jax.tree.leaves(s_plain.params),
                    jax.tree.leaves(s_ltp.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_ltp_zero_variant_matches_psum_variant(setup):
    cfg, api, opt, state, batch = setup
    mesh = _mesh()
    lr = jnp.float32(0.1)
    ltp_cfg = LTPConfig(packet_floats=128)
    batch_specs = jax.tree.map(lambda _: P(), batch)
    frac = jnp.full((1,), 0.7)
    key = jax.random.PRNGKey(3)

    with compat.set_mesh(mesh):
        step = make_ltp_train_step(api, opt, mesh, ltp_cfg, ("data",),
                                   batch_specs)
        s_psum, _ = step(state, batch, frac, key, lr)
        # zero-state variant
        m_sds = ls.zero_momentum_shapes(
            jax.eval_shape(lambda: state.params), ltp_cfg, 1)
        zstate = TrainState(
            params=state.params,
            opt_state={"m_pkts": [jnp.zeros(s.shape, s.dtype) for s in m_sds]},
            step=state.step,
        )
        s_zero, m_zero = step(zstate, batch, frac, key, lr)
    for a, b in zip(jax.tree.leaves(s_psum.params),
                    jax.tree.leaves(s_zero.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    assert 0.3 < float(m_zero["delivered_frac"]) <= 1.0
