"""Chrome-trace export CLI (DESIGN.md §12): run one ClusterRuntime DES
cell — the same papernet/straggler shape the runtime sweep measures —
and write its event stream as a Perfetto-loadable trace.

  PYTHONPATH=src python -m benchmarks.trace_export --out trace.json
  PYTHONPATH=src python -m benchmarks.trace_export \\
      --out trace.json --policy async --workers 8 --steps 6 --faults

Open the output at https://ui.perfetto.dev (or chrome://tracing): one
track per worker (compute/blocked spans), per-worker transport tracks,
PS apply/Early-Close/failover markers, trunk-queue counters, and fault
instants. ``--validate`` (default on) runs the same schema smoke CI
gates on: JSON parses, every worker/PS has a track, spans are
well-nested, fault markers present when faults were injected.
"""
from __future__ import annotations

import argparse
import json

from repro.config import LTPConfig, NetConfig, ObservabilityConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.obs.trace import validate_chrome_trace
from repro.optim import make_optimizer
from repro.net.topology import rack_spine
from repro.runtime import (
    ClusterRuntime,
    FaultEvent,
    FaultSchedule,
    LinkFaultEvent,
    LinkFaultSchedule,
    LognormalStragglerCompute,
)


def _fault_schedule(w: int) -> FaultSchedule:
    """A small deterministic chaos timeline: one crash, one PS failure
    with failover, one rejoin — enough to light every marker type."""
    return FaultSchedule([
        FaultEvent(0.08, "worker_crash", w - 1),
        FaultEvent(0.30, "ps_fail", 0, recover_s=0.02),
        FaultEvent(0.60, "worker_join", w - 1),
    ])


def _netfault_schedule() -> LinkFaultSchedule:
    """Fabric chaos for the control track's fabric thread (DESIGN.md
    §14): a link_flap square wave plus one trunk degrade, so the
    exported trace shows the flap timeline and reroute markers."""
    return LinkFaultSchedule([
        LinkFaultEvent(0.05, "link_flap", target="rack1/up",
                       period_s=0.03, duty=0.5, duration_s=0.15),
        LinkFaultEvent(0.40, "link_degrade", target="ps0/trunk",
                       rate_factor=0.5, extra_loss=0.02,
                       duration_s=0.1),
    ])


def export(out: str, *, policy: str = "bsp", workers: int = 4,
           steps: int = 6, faults: bool = False, seed: int = 11,
           tracker: str = "none") -> dict:
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    tc = TrainConfig(batch=4 * workers, lr=0.05, steps=steps)
    net = NetConfig(10, 1, 0.001, 4096)
    kw = {}
    if faults:
        kw["faults"] = _fault_schedule(workers)
        kw["checkpoint_every_s"] = 0.1
        if workers % 2 == 0:
            # rack/spine so the link_flap has an uplink to flap and a
            # spine backup to reroute through (DESIGN.md §14)
            kw["topology"] = rack_spine(2, workers // 2, n_ps=1)
            kw["net_faults"] = _netfault_schedule()
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(staleness_comp=0.5), net,
        n_workers=workers, policy=policy, transport="des",
        compute_model=LognormalStragglerCompute(
            workers, base=0.05, seed=seed, sigma=0.3,
            straggler_prob=0.15, straggler_mult=5.0),
        seed=seed, obs=ObservabilityConfig(tracker=tracker), **kw)
    rt.run(batches(SyntheticCIFAR(seed=3), tc.batch, steps))
    doc = rt.export_trace(out, meta={"steps": steps, "faulted": faults})
    return {"doc": doc, "runtime": rt}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--policy", default="bsp",
                    choices=("bsp", "async", "ssp"))
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--faults", action="store_true",
                    help="inject a deterministic crash/PS-failover/"
                         "rejoin timeline")
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--no-validate", action="store_true")
    args = ap.parse_args(argv)

    res = export(args.out, policy=args.policy, workers=args.workers,
                 steps=args.steps, faults=args.faults, seed=args.seed)
    doc, rt = res["doc"], res["runtime"]
    n_spans = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
    print(f"wrote {args.out}: {len(doc['traceEvents'])} trace events "
          f"({n_spans} spans) from {len(rt.tel.events)} runtime events")
    if not args.no_validate:
        with open(args.out) as f:
            loaded = json.load(f)      # the artifact itself must parse
        problems = validate_chrome_trace(
            loaded, n_workers=args.workers, n_ps=rt.n_ps,
            require_fault_markers=args.faults,
            require_netfault_markers=(args.faults
                                      and args.workers % 2 == 0))
        if problems:
            for p in problems:
                print(f"INVALID: {p}")
            return 1
        print("trace schema: ok (tracks per worker/PS, spans "
              "well-nested)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
