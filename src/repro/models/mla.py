"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill decompress K/V from the latent; decode uses the *absorbed*
formulation: the query is projected into the kv_lora latent space so the
KV cache holds only (c_kv: kv_lora) + (k_rope: qk_rope_dim) per token —
the whole point of MLA (576 B/token/layer for the assigned config vs
32 KiB for vanilla MHA-128).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.attention import multi_head_attention, NEG_INF
from repro.models.layers import Params, apply_rope, dense_init, rms_norm, split_keys
from repro.models.sharding import ShardCtx, NULL_CTX


def mla_params(key, cfg: ModelConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = split_keys(key, 6)
    p = {
        "w_dkv": dense_init(ks[0], d, cfg.kv_lora + cfg.qk_rope_dim, dtype),
        "w_uk": dense_init(ks[1], cfg.kv_lora, h * cfg.qk_nope_dim, dtype),
        "w_uv": dense_init(ks[2], cfg.kv_lora, h * cfg.v_head_dim, dtype),
        "wo": dense_init(ks[3], h * cfg.v_head_dim, d, dtype),
        "kv_norm_scale": jnp.zeros((cfg.kv_lora,), jnp.float32),
    }
    if cfg.q_lora > 0:
        p["w_dq"] = dense_init(ks[4], d, cfg.q_lora, dtype)
        p["w_uq"] = dense_init(ks[5], cfg.q_lora, h * qk, dtype)
        p["q_norm_scale"] = jnp.zeros((cfg.q_lora,), jnp.float32)
    else:
        p["wq"] = dense_init(ks[4], d, h * qk, dtype)
    return p


def _queries(cfg: ModelConfig, p: Params, x):
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora > 0:
        cq = x @ p["w_dq"]
        q = rms_norm(cq, p["q_norm_scale"], cfg.norm_eps) @ p["w_uq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, h, qk)
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]


def _latents(cfg: ModelConfig, p: Params, x, positions):
    """Returns (c_kv normed, k_rope with rope applied)."""
    ckv_full = x @ p["w_dkv"]
    c_kv = rms_norm(ckv_full[..., : cfg.kv_lora], p["kv_norm_scale"], cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora :][:, :, None, :]  # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_attention(
    cfg: ModelConfig, p: Params, x, positions, *, ctx: ShardCtx = NULL_CTX
):
    """Full-sequence MLA (train/prefill). Decompresses K/V per layer."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _queries(cfg, p, x)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _latents(cfg, p, x, positions)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, cfg.qk_nope_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))], axis=-1
    )
    # pad v to q/k head_dim so the shared chunked kernel applies, then crop
    pad = q.shape[-1] - cfg.v_head_dim
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad > 0 else v
    out = multi_head_attention(q, k, vp, causal=True, ctx=ctx)[..., : cfg.v_head_dim]
    return out.reshape(b, s, h * cfg.v_head_dim) @ p["wo"]


def mla_decode(
    cfg: ModelConfig, p: Params, x1, cache_ckv, cache_krope, pos
):
    """Absorbed one-token MLA decode.

    cache_ckv: (B, Smax, kv_lora); cache_krope: (B, Smax, qk_rope_dim).
    Returns (out, new_ckv, new_krope).
    """
    b = x1.shape[0]
    h = cfg.n_heads
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    positions = jnp.full((b, 1), pos, jnp.int32)

    q_nope, q_rope = _queries(cfg, p, x1)  # (B,1,h,*)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv1, k_rope1 = _latents(cfg, p, x1, positions)

    new_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv1.astype(cache_ckv.dtype), pos, axis=1
    )
    new_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, k_rope1[:, :, 0, :].astype(cache_krope.dtype), pos, axis=1
    )

    # absorb W_uk into the query: q_abs (B,1,h,kv_lora)
    w_uk = p["w_uk"].reshape(cfg.kv_lora, h, cfg.qk_nope_dim)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)
    scores = (
        jnp.einsum("bqhl,bsl->bhqs", q_abs, new_ckv)
        + jnp.einsum("bqhd,bsd->bhqs", q_rope, new_krope[:, :, :])
    ).astype(jnp.float32) * scale
    valid = jnp.arange(cache_ckv.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    pr = jnp.exp(scores - m)
    pr = (pr / jnp.maximum(jnp.sum(pr, axis=-1, keepdims=True), 1e-30)).astype(
        new_ckv.dtype
    )
    out_lat = jnp.einsum("bhqs,bsl->bqhl", pr, new_ckv)  # (B,1,h,kv_lora)
    w_uv = p["w_uv"].reshape(cfg.kv_lora, h, cfg.v_head_dim)
    out = jnp.einsum("bqhl,lhv->bqhv", out_lat, w_uv)
    out = out.reshape(b, 1, h * cfg.v_head_dim) @ p["wo"]
    return out, new_ckv, new_krope
