"""Loss-tolerant gradient synchronization — the paper's technique as a
first-class JAX feature.

Semantics (paper §III): during *gathering*, each worker's gradient
contribution is packetized; non-critical packets are delivered i.i.d. with
the Early-Close-controlled fraction; lost packets are bubble-filled with
zeros at the PS. *Broadcasting* (the reduced result) is reliable — here it
is simply the psum output, exactly the paper's asymmetry.

Mapping onto the mesh: worker = (pod, data) index; the model axis shards
the payload itself (each model shard is its own PS, as in multi-PS
deployments), so packetization is per-device-local and the sync is pure
elementwise work + one psum over the data axes — implemented as a fully
manual ``jax.shard_map`` (no tensor resharding, no extra collectives).

Compensation modes (beyond-paper, DESIGN.md §2):
  paper     sum/W             (plain mean with zero bubbles — the paper)
  count     sum/count         (per-packet unbiased mean over deliverers)
  expected  sum/(W*E[frac])   (global rescale)

Error feedback (beyond-paper): each worker accumulates the packets it
failed to deliver and re-adds them next iteration (EF-SGD style).

Aggregation backends (DESIGN.md §7): every masked-aggregation step
dispatches through ``apply_delivery`` / ``reduce_packet_stream`` on
``LTPConfig.sync_backend`` — ``python`` is the pure-jnp reference,
``pallas`` runs the fused ``kernels.dropfill`` / ``kernels.packet_reduce``
tiles (one HBM pass for the whole PS hot loop; interpret mode on CPU).
Both backends agree to float tolerance (tests/test_sync_backend.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map as _shard_map
from repro.config import LTPConfig
from repro.core import packets as pk
from repro.kernels import ops as kops
from repro.models.sharding import dp_axes

# number of leading mesh axes used as the worker index, in order
_DP_ORDER = ("pod", "data")


# ----------------------------------------------------------------------------
# backend dispatch: the PS hot loop as fused kernels or jnp reference
# ----------------------------------------------------------------------------

#: python/pallas crossover in stream elements (W * n_packets * payload)
#: for COMPILED kernels (``kernel_interpret=False``): below it the jnp
#: reference wins on dispatch overhead, above it the fused single-pass
#: tiles win on memory traffic. In interpret mode the kernel body runs
#: in the Python interpreter and never beats jnp, so "auto" always
#: resolves to python there — measured by ``benchmarks.kernel_bench``
#: (``sync_crossover_elems`` in BENCH_kernels.json).
AUTO_CROSSOVER_ELEMS = 1 << 22


def resolve_backend(backend: str, n_elems: int,
                    interpret: bool = True) -> str:
    """Resolve ``sync_backend="auto"`` to a concrete backend for a
    stream of ``n_elems`` elements; passes explicit backends through.
    The guarantee the benchmarks gate: auto is never a regression — it
    picks python below the measured crossover and pallas above it, and
    interpret-mode kernels (CPU) never win, so auto==python there."""
    if backend != "auto":
        return backend
    if interpret or n_elems < AUTO_CROSSOVER_ELEMS:
        return "python"
    return "pallas"


def apply_delivery(packets, mask, scale=None, *, backend: str = "python",
                   interpret: bool = True):
    """Bubble-fill + compensation gate: ``packets * mask * scale``.

    packets: (n_packets, payload); mask/scale: (n_packets,). The pallas
    backend runs ``kernels.dropfill`` through the ``ops`` padding wrappers
    (arbitrary geometry in, lane-aligned tiles inside); ``"auto"``
    resolves via ``resolve_backend`` on the stream size.
    """
    backend = resolve_backend(backend, packets.size, interpret)
    if backend == "pallas":
        m = mask if scale is None else mask * scale
        return kops.ltp_dropfill(packets, m, interpret=interpret)
    gate = mask if scale is None else mask * scale
    return packets * gate[:, None].astype(packets.dtype)


def staleness_weights(staleness, damping: float) -> np.ndarray:
    """(W,) contribution weights for gradients ``staleness`` iterations
    old: 1 / (1 + damping * s) — the staleness-aware damping the
    async/SSP aggregation policies feed to ``reduce_packet_stream`` as
    ``worker_weights`` (DESIGN.md §8). The coefficient comes from
    ``LTPConfig.staleness_comp`` (or a policy override); 0 gives the
    identity (every admitted gradient weighs 1). This is THE damping
    law — policies call it rather than re-deriving it."""
    s = np.asarray(staleness, np.float32)
    return 1.0 / (1.0 + float(damping) * np.maximum(s, 0.0))


def reduce_packet_stream(packets_w, masks_w, ltp: LTPConfig, n_workers: int,
                         *, expected_frac=None, backend: Optional[str] = None,
                         interpret: Optional[bool] = None,
                         premasked: bool = False, worker_weights=None):
    """The PS-side hot loop: one fused masked multi-worker reduction.

    packets_w: (W, n_packets, payload); masks_w: (W, n_packets) {0,1}.
    Returns the (n_packets, payload) compensated mean under
    ``ltp.compensation`` (paper | count | expected; ``expected`` needs
    ``expected_frac``, the Early-Close target fraction).

    backend="pallas" executes ``kernels.packet_reduce`` — the worker loop
    is unrolled inside the kernel so each output tile is written once and
    each input tile read once (single HBM pass). backend="python" is the
    jnp reference the kernels are verified against.

    ``premasked=True`` declares that ``packets_w`` has already been gated
    by ``masks_w`` (the error-feedback path materializes the masked
    stream anyway): the python backend skips the multiply; the pallas
    kernel re-applies the {0,1} mask, which is idempotent.

    ``worker_weights`` ((W,) float, optional) damps each worker's
    contribution — staleness-aware compensation under async/SSP
    aggregation (DESIGN.md §8). A weight multiplies the worker's gradient
    exactly as per-contribution learning-rate damping would, so it
    composes identically with every compensation mode and both backends
    (the stream is pre-scaled before the fused reduction).
    """
    backend = backend or ltp.sync_backend
    interpret = ltp.kernel_interpret if interpret is None else interpret
    backend = resolve_backend(backend, packets_w.size, interpret)
    comp = ltp.compensation
    if worker_weights is not None:
        w_ = jnp.asarray(worker_weights, jnp.float32)
        packets_w = packets_w * w_[:, None, None]
    if backend == "pallas":
        out = kops.ltp_packet_reduce(
            packets_w, masks_w,
            compensation="count" if comp == "count" else "paper",
            interpret=interpret)
        if comp == "expected":
            # paper-mode output is sum/W; expected = sum/(W*E[frac])
            ef = (jnp.mean(masks_w) if expected_frac is None
                  else jnp.mean(jnp.asarray(expected_frac)))
            out = out / jnp.maximum(ef, 1e-6)
        return out
    masks_w = masks_w.astype(jnp.float32)
    gated = (packets_w.astype(jnp.float32) if premasked
             else packets_w.astype(jnp.float32) * masks_w[:, :, None])
    tot = jnp.sum(gated, axis=0)
    if comp == "count":
        cnt = jnp.maximum(jnp.sum(masks_w, axis=0), 1.0)
        return tot / cnt[:, None]
    if comp == "expected":
        ef = (jnp.mean(masks_w) if expected_frac is None
              else jnp.mean(jnp.asarray(expected_frac)))
        return tot / (n_workers * jnp.maximum(ef, 1e-6))
    return tot / n_workers


@dataclasses.dataclass(frozen=True)
class LTPSync:
    """Callable gradient synchronizer bound to (mesh, plan, config)."""

    mesh: Any
    plan: pk.PacketPlan
    ltp: LTPConfig
    grad_specs: Any          # pytree of PartitionSpecs matching grads
    n_workers: int

    def residual_spec(self):
        """Global residual: (W, nm, n_packets, packet_floats)."""
        dp = dp_axes(self.mesh)
        nm = self.mesh.shape.get("model", 1) if hasattr(self.mesh.shape, "get") else (
            self.mesh.shape["model"] if "model" in self.mesh.axis_names else 1
        )
        shape = (self.n_workers, nm, self.plan.n_packets, self.plan.packet_floats)
        spec = P(dp if len(dp) > 1 else (dp[0] if dp else None),
                 "model" if "model" in self.mesh.axis_names else None, None, None)
        return jax.ShapeDtypeStruct(shape, jnp.float32), spec

    def init_residual(self):
        sds, spec = self.residual_spec()
        if self.ltp.error_feedback:
            return jnp.zeros(sds.shape, sds.dtype)
        return None

    def __call__(self, grads, frac, key, residual=None):
        """grads: pytree (sharded per grad_specs); frac: (W,) float32
        delivered fraction per worker; key: uint32 PRNG key.

        Returns (synced_grads, new_residual, stats) where stats carries the
        realized delivered fraction (scalar) for logging.
        """
        mesh = self.mesh
        dp = dp_axes(mesh)
        has_model = "model" in mesh.axis_names
        W = self.n_workers
        plan = self.plan
        ltp = self.ltp
        leaf_dtypes = [x.dtype for x in jax.tree_util.tree_leaves(grads)]

        def local(g, frac, key, res):
            # worker index over dp axes (row-major over (pod, data))
            widx = jnp.zeros((), jnp.int32)
            for a in dp:
                widx = widx * mesh.shape[a] + jax.lax.axis_index(a)
            k = jax.random.fold_in(key, widx)
            if has_model:
                k = jax.random.fold_in(k, jax.lax.axis_index("model"))
            flat = pk.flatten(plan, g)
            if res is not None:
                flat = flat + res.reshape(flat.shape)
            mask = pk.delivery_mask(plan, k, frac[widx])
            # bubble-fill gate + compensation both dispatch on the backend:
            # fused dropfill tiles under "pallas", jnp reference otherwise
            sent = apply_delivery(flat, mask, backend=ltp.sync_backend,
                                  interpret=ltp.kernel_interpret)
            tot = jax.lax.psum(sent, dp)
            if ltp.compensation == "count":
                cnt = jax.lax.psum(mask, dp)
                out = apply_delivery(tot, jnp.ones_like(cnt),
                                     1.0 / jnp.maximum(cnt, 1.0),
                                     backend=ltp.sync_backend,
                                     interpret=ltp.kernel_interpret)
            elif ltp.compensation == "expected":
                mean_frac = jnp.mean(
                    jnp.where(jnp.asarray(plan.critical), 1.0, jnp.mean(frac))
                )
                out = tot / (W * mean_frac)
            else:  # paper
                out = tot / W
            new_res = (flat - sent).reshape(res.shape) if res is not None else None
            realized = jax.lax.psum(jnp.mean(mask), dp) / W
            return pk.unflatten(plan, out, leaf_dtypes), new_res, realized

        res_in = residual
        sds, res_spec = self.residual_spec()
        args_specs = (self.grad_specs, P(), P())
        out_res_spec = res_spec
        if res_in is None:
            def f(g, fr, k):
                return local(g, fr, k, None)[::2]   # (grads, realized)
            synced, realized = _shard_map(
                f,
                mesh=mesh,
                in_specs=args_specs,
                out_specs=(self.grad_specs, P()),
            )(grads, frac, key)
            return synced, None, {"delivered_frac": realized}
        synced, new_res, realized = _shard_map(
            local,
            mesh=mesh,
            in_specs=args_specs + (res_spec,),
            out_specs=(self.grad_specs, out_res_spec, P()),
        )(grads, frac, key, res_in)
        return synced, new_res, {"delivered_frac": realized}


def _leaf_packet_mask(i, leaf_shape, key, frac, ltp: LTPConfig):
    """(n_pkts,) float32 delivery mask for leaf index ``i``."""
    size = int(np.prod(leaf_shape)) if leaf_shape else 1
    n_pkts = max(1, -(-size // ltp.packet_floats))
    k = jax.random.fold_in(key, i)
    u = jax.random.uniform(k, (n_pkts,))
    crit = np.zeros(n_pkts, bool)
    c = ltp.critical_per_tensor
    crit[:c] = True
    crit[-c:] = True
    return jnp.where(jnp.asarray(crit), 1.0, (u < frac).astype(jnp.float32))


def _as_packets(leaf, p: int):
    """Row-major (n_pkts, p) float32 view of a leaf (zero-padded tail)."""
    size = int(np.prod(leaf.shape)) if leaf.shape else 1
    n_pkts = max(1, -(-size // p))
    flat = leaf.astype(jnp.float32).reshape(-1)
    pad = n_pkts * p - size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(n_pkts, p)


def _from_packets(pkts, shape, dtype):
    size = int(np.prod(shape)) if shape else 1
    return pkts.reshape(-1)[:size].reshape(shape).astype(dtype)


def leafwise_packet_masks(grads, key, frac, ltp: LTPConfig):
    """Per-leaf packet delivery masks, broadcast to element space.

    Packets are spans of ``ltp.packet_floats`` contiguous elements in each
    leaf's row-major layout (per-leaf streams; the padding-bubble alignment
    holds within every leaf). The mask expands by broadcast against the
    (n_pkts, p) view — no jnp.repeat (whose flat indexing overflows int32
    on >2^31-element stacked leaves).

    Returns (masks pytree matching grads, packet_masks list).
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    masks, pkt_masks = [], []
    p = ltp.packet_floats
    for i, leaf in enumerate(leaves):
        m = _leaf_packet_mask(i, leaf.shape, key, frac, ltp)
        pkt_masks.append(m)
        view = _as_packets(jnp.ones_like(leaf, jnp.float32), p) * m[:, None]
        masks.append(_from_packets(view, leaf.shape, jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, masks), pkt_masks


def masked_psum_leafwise(grads, key, frac, ltp: LTPConfig, worker_axes,
                         n_workers: int):
    """The in-shard_map body of sharded LTP sync (v2, per-leaf packets).

    Must run inside a shard_map that is MANUAL over ``worker_axes`` (the
    replicated-model data axes — e.g. ('pod',) for cross-DC LTP) and auto
    over everything else. ``frac``: (n_workers,) delivered fraction.
    """
    widx = jnp.zeros((), jnp.int32)
    for a in worker_axes:
        widx = widx * compat.axis_size(a) + jax.lax.axis_index(a)
    k = jax.random.fold_in(key, widx)
    p = ltp.packet_floats
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out = []
    realized = None
    for i, leaf in enumerate(leaves):
        m = _leaf_packet_mask(i, leaf.shape, k, frac[widx], ltp)
        view = apply_delivery(_as_packets(leaf, p), m,
                              backend=ltp.sync_backend,
                              interpret=ltp.kernel_interpret)
        # per-leaf f32 psum: one all-reduce per tensor with a uniform dtype
        # (XLA:CPU CHECK-fails on one huge mixed-dtype tuple all-reduce —
        # and per-tensor reduces are what a production runtime overlaps
        # with backward anyway)
        tot = jax.lax.psum(view, worker_axes)
        if ltp.compensation == "count":
            cnt = jax.lax.psum(m, worker_axes)
            tot = tot / jnp.maximum(cnt, 1.0)[:, None]
        elif ltp.compensation == "expected":
            tot = tot / (n_workers * jnp.maximum(jnp.mean(frac), 1e-6))
        else:  # paper
            tot = tot / n_workers
        out.append(_from_packets(tot, leaf.shape, leaf.dtype))
        if realized is None:
            realized = jax.lax.psum(jnp.mean(m), worker_axes) / n_workers
    synced = jax.tree_util.tree_unflatten(treedef, out)
    return synced, realized


def masked_rs_update_leafwise(grads, params, m_states, key, frac,
                              ltp: LTPConfig, worker_axes, n_workers: int,
                              lr, momentum: float = 0.9):
    """ZeRO-style LTP sync (beyond-paper, §Perf): per-worker packet masking,
    then ``psum_scatter`` in packet space (each worker owns 1/W of the
    packet stream — a sharded PS, like the paper's multi-PS deployment),
    SGD-momentum on the local shard, and a bf16 *delta* all-gather back.

    Ring-volume napkin math vs masked psum: all-reduce(f32 grads) moves
    ~2x bytes; RS(f32) + AG(bf16 delta) moves ~1.5x -> -25% collective
    traffic, and momentum lives sharded (1/W of the f32 state per device).

    m_states: list of (n_pkts_padW / W, p) f32 LOCAL shards (one per leaf,
    sharded over the worker axes on dim 0 at the shard_map boundary).
    Returns (delta_shards [param-dtype packet buffers, worker-sharded],
    new_m_states, realized) — the caller applies deltas outside the manual
    region.
    """
    widx = jnp.zeros((), jnp.int32)
    for a in worker_axes:
        widx = widx * compat.axis_size(a) + jax.lax.axis_index(a)
    k = jax.random.fold_in(key, widx)
    p = ltp.packet_floats
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    p_leaves = jax.tree_util.tree_leaves(params)
    new_params, new_m = [], []
    realized = None
    for i, (gleaf, pleaf) in enumerate(zip(g_leaves, p_leaves)):
        m = _leaf_packet_mask(i, gleaf.shape, k, frac[widx], ltp)
        view = _as_packets(gleaf, p)
        n_pkts = view.shape[0]
        padw = (-n_pkts) % n_workers
        if padw:
            view = jnp.concatenate(
                [view, jnp.zeros((padw, p), jnp.float32)])
            m = jnp.concatenate([m, jnp.zeros((padw,), jnp.float32)])
        masked = view * m[:, None]
        shard = jax.lax.psum_scatter(
            masked, worker_axes, scatter_dimension=0, tiled=True)
        if ltp.compensation == "count":
            cnt = jax.lax.psum_scatter(
                m, worker_axes, scatter_dimension=0, tiled=True)
            shard = shard / jnp.maximum(cnt, 1.0)[:, None]
        else:
            shard = shard / n_workers
        m_new = momentum * m_states[i] + shard
        delta = (-lr * m_new).astype(pleaf.dtype)
        # the bf16 delta leaves the manual region as a worker-sharded
        # packet buffer; the all-gather back to replicated params happens
        # in GSPMD auto land (outside), where reshapes of gathered values
        # are unrestricted
        new_params.append(delta)
        new_m.append(m_new)
        if realized is None:
            realized = jax.lax.psum(jnp.mean(m), worker_axes) / n_workers
    return new_params, new_m, realized


def zero_momentum_shapes(params_shape, ltp: LTPConfig, n_workers: int):
    """Global shapes of the packet-space momentum buffers (sharded over
    the worker axes on dim 0)."""
    out = []
    for leaf in jax.tree_util.tree_leaves(params_shape):
        size = 1
        for s in leaf.shape:
            size *= s
        n_pkts = max(1, -(-size // ltp.packet_floats))
        n_pkts += (-n_pkts) % n_workers
        out.append(jax.ShapeDtypeStruct((n_pkts, ltp.packet_floats),
                                        jnp.float32))
    return out


def make_ltp_sync(params_shape, mesh, ltp: LTPConfig, grad_specs) -> LTPSync:
    """Build an LTPSync from a params shape-pytree and its sharding specs."""
    plan = pk.local_plan(
        params_shape, grad_specs, mesh,
        packet_floats=ltp.packet_floats,
        critical_per_tensor=ltp.critical_per_tensor,
    )
    dp = dp_axes(mesh)
    w = 1
    for a in dp:
        w *= mesh.shape[a]
    return LTPSync(mesh=mesh, plan=plan, ltp=ltp, grad_specs=grad_specs, n_workers=w)
