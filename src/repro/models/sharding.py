"""Sharding rules for params and activations.

Baseline scheme (DESIGN.md §4): 2D "fsdp + tensor" sharding.
  - ``data`` axis: FSDP shard of weight matrices + batch parallelism.
  - ``model`` axis: tensor parallelism (heads / d_ff / experts / vocab).
  - ``pod`` axis (multi-pod only): pure data parallelism across pods; weights
    are replicated across pods, so the only cross-pod traffic is the gradient
    all-reduce — the exact "PS over WAN/DCN" link the paper's LTP targets.

Rules are name-based: parameter pytree paths carry conventional leaf names
(``wq``, ``w_up``, ``embed``, ...).  ``spec_for(path, shape)`` returns a
PartitionSpec; dims that do not divide the mesh axis fall back to replication
(checked by the caller via ``divisible``).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def dp_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The batch-parallel axes present in this mesh ((pod, data) or (data,))."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _fits(dim: int, n: int) -> bool:
    return n > 1 and dim % n == 0


# Leaf-name -> (dim sharded over 'data', dim sharded over 'model').
# None means "never shard that side"; -1 means "last dim".
_RULES = {
    # embeddings / unembedding
    "embed": (1, 0),          # (vocab, d_model): vocab->model, d->data
    "lm_head": (0, 1),        # (d_model, vocab): vocab->model
    "pos_embed": (None, 1),   # (max_pos, d_model)
    # attention projections
    "wq": (0, 1),             # (d_model, H*hd)
    "wk": (0, 1),
    "wv": (0, 1),
    "wo": (1, 0),             # (H*hd, d_model)
    # MLA
    "w_dq": (0, None),        # (d, q_lora)
    "w_uq": (None, 1),        # (q_lora, H*qk_dim)
    "w_dkv": (0, None),       # (d, kv_lora + rope)
    "w_uk": (None, 1),        # (kv_lora, H*nope)
    "w_uv": (None, 1),        # (kv_lora, H*v_dim)
    # MLP
    "w_gate": (0, 1),         # (d, ff)
    "w_up": (0, 1),
    "w_down": (1, 0),         # (ff, d)
    # MoE (E, d, ff) / (E, ff, d): expert dim -> model when divisible,
    # handled specially in spec_for.
    "moe_gate": (0, None),    # router (d, E)
    # SSM
    "in_proj": (0, 1),        # (d, 2*d_inner) etc.
    "out_proj": (1, 0),       # (d_inner, d)
    "x_proj": (1, None),      # (d_inner, dt_rank + 2*state)
    "dt_proj": (None, 1),     # (dt_rank, d_inner)
    "conv_w": (1, None),      # (k, d_inner) tap-major
    "A_log": (1, None),       # (d_inner, state) — model on d_inner
    # CNN
    "conv": (None, None),
    "fc": (0, None),
}

_REPLICATED_SUFFIXES = (
    "scale", "bias", "offset", "D", "dt_bias", "A_log_m2", "gamma",
)


def _leaf_name(path: Any) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
    return parts[-1] if parts else ""


def spec_for(path: Any, shape: Tuple[int, ...], mesh: jax.sharding.Mesh,
             *, fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf, honoring divisibility.

    ``fsdp=False`` drops the 'data' (FSDP) axis from weight specs — used
    when weights must be replicated across the worker axes (LTP's
    per-worker gradient masking on a single-pod mesh)."""
    name = _leaf_name(path)
    nd = axis_size(mesh, "data") if fsdp else 1
    nm = axis_size(mesh, "model")
    ndim = len(shape)

    if name in _REPLICATED_SUFFIXES or ndim <= 1:
        return P()

    if not fsdp and name == "embed" and ndim == 2:
        # inside manual (LTP) regions the token-lookup gather must be
        # shard-local: shard d_model, replicate vocab rows
        return P(None, "model") if _fits(shape[1], nm) else P()

    # MoE expert stacks: (E, d_in, d_out)
    if name in ("experts_gate", "experts_up", "experts_down") and ndim == 3:
        e, di, do = shape
        spec = [None, None, None]
        if _fits(e, nm):
            spec[0] = "model"
            if _fits(di, nd):
                spec[1] = "data"
        else:  # few big experts (mixtral): tensor-parallel within experts
            ff_dim = 2 if name != "experts_down" else 1
            if _fits(shape[ff_dim], nm):
                spec[ff_dim] = "model"
            other = 1 if ff_dim == 2 else 2
            if _fits(shape[other], nd):
                spec[other] = "data"
        return P(*spec)

    rule = _RULES.get(name)
    if rule is None:
        # generic 2D matmul weight: fsdp on dim0, tensor on dim1 when divisible
        rule = (0, 1) if ndim == 2 else (None, None)
    d_dim, m_dim = rule
    spec = [None] * ndim
    if m_dim is not None and m_dim < ndim and _fits(shape[m_dim], nm):
        spec[m_dim] = "model"
    if (
        d_dim is not None
        and d_dim < ndim
        and spec[d_dim] is None
        and _fits(shape[d_dim], nd)
    ):
        spec[d_dim] = "data"
    return P(*spec)


def param_shardings(params_shape: Any, mesh: jax.sharding.Mesh) -> Any:
    """Pytree of NamedShardings matching a params (shape-)pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for(path, leaf.shape, mesh)),
        params_shape,
    )


def param_specs(params_shape: Any, mesh: jax.sharding.Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(path, leaf.shape, mesh), params_shape
    )


# ----------------------------------------------------------------------------
# Activation constraints
# ----------------------------------------------------------------------------


class ShardCtx:
    """Carries the mesh through model code; ``None`` mesh = no constraints
    (single-device smoke tests).

    ``exclude``: axis names that are MANUAL in an enclosing shard_map —
    sharding constraints inside the region may not mention them."""

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None,
                 exclude: Tuple[str, ...] = ()):
        self.mesh = mesh
        self.exclude = tuple(exclude)
        dp = dp_axes(mesh) if mesh is not None else ()
        self.dp: Tuple[str, ...] = tuple(a for a in dp if a not in self.exclude)
        self.nm = axis_size(mesh, "model") if mesh is not None else 1
        if "model" in self.exclude:
            self.nm = 1

    def constrain(self, x, *spec):
        """with_sharding_constraint, skipping axes that don't divide."""
        if self.mesh is None:
            return x
        fixed = []
        for dim, s in enumerate(spec):
            if s is None:
                fixed.append(None)
                continue
            names = (s,) if isinstance(s, str) else tuple(s)
            total = 1
            for n in names:
                total *= axis_size(self.mesh, n)
            if x.shape[dim] % total == 0 and total > 1:
                fixed.append(s)
            else:
                fixed.append(None)
        # bare-PartitionSpec constraint (resolved by the ambient set_mesh):
        # NamedSharding would reject worker-varying values inside a
        # partial-manual shard_map region (vma/auto axis-type clash)
        return jax.lax.with_sharding_constraint(x, P(*fixed))

    def batch_seq_hidden(self, x):
        """(B, S, D) -> batch over dp, hidden over model."""
        return self.constrain(x, self.dp or None, None, "model")

    def batch_only(self, x):
        spec = [self.dp or None] + [None] * (x.ndim - 1)
        return self.constrain(x, *spec)


NULL_CTX = ShardCtx(None)
