"""jit'd public wrappers around the Pallas kernels.

Handle padding to the kernels' tile constraints (lane-width payload,
block-multiple packet counts) and strip it on the way out, so callers can
use arbitrary packet geometries. ``interpret=True`` (the default here)
executes the kernel body in Python on CPU; on a real TPU pass
``interpret=False``.

Dispatch cache (DESIGN.md §9): each (interpret, donate) variant of a
wrapper is built exactly once through ``_variant``; within a variant,
``jax.jit`` keys compiled executables by shape, so repeated calls with
the same packet geometry pay zero retrace/recompile. ``donate=True``
donates the packet-stream buffer to the kernel (the output aliases the
input's memory on backends that support aliasing — TPU; a no-op in
interpret mode) — the caller's array is consumed, so only opt in when
the stream is dead after the call (e.g. a PS hot loop that immediately
overwrites it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dropfill as _df
from repro.kernels import packet_reduce as _pr
from repro.kernels import randomk as _rk


def _pad_to(x, m: int, axis: int):
    r = x.shape[axis] % m
    if r == 0:
        return x, 0
    pad = m - r
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.lru_cache(maxsize=None)
def _variant(fn_name: str, interpret: bool, donate: bool, *static):
    """Shape-keyed jit cache: one jitted callable per (wrapper,
    interpret, donate, static-args) variant; jax.jit's own cache keys
    the compiled executable by input shapes under it."""
    core = {
        "dropfill": _dropfill_core,
        "packet_reduce": _packet_reduce_core,
        "randomk": _randomk_core,
    }[fn_name]
    kw = {"compensation": static[0]} if fn_name == "packet_reduce" else {}
    fn = functools.partial(core, interpret=interpret, **kw)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def _dropfill_core(packets, mask, scale, *, interpret: bool):
    x, pad_p = _pad_to(packets.astype(jnp.float32), 128, 1)
    x, pad_n = _pad_to(x, _df.BLOCK_P, 0)
    m, _ = _pad_to(mask.astype(jnp.float32), _df.BLOCK_P, 0)
    s, _ = _pad_to(scale.astype(jnp.float32), _df.BLOCK_P, 0)
    out = _df.dropfill(x, m, s, interpret=interpret)
    out = out[: packets.shape[0], : packets.shape[1]]
    return out.astype(packets.dtype)


def ltp_dropfill(packets, mask, scale=None, *, interpret: bool = True,
                 donate: bool = False):
    """packets: (n_packets, payload) any-float; mask: (n_packets,) {0,1};
    scale: optional (n_packets,) compensation. Zero-fills lost packets."""
    if scale is None:
        scale = jnp.ones_like(mask)
    return _variant("dropfill", bool(interpret), bool(donate))(
        packets, mask, scale)


def _packet_reduce_core(packets, mask, *, compensation: str,
                        interpret: bool):
    x, _ = _pad_to(packets.astype(jnp.float32), 128, 2)
    x, _ = _pad_to(x, _pr.BLOCK_P, 1)
    m, _ = _pad_to(mask.astype(jnp.float32), _pr.BLOCK_P, 1)
    out = _pr.packet_reduce(x, m, compensation=compensation,
                            interpret=interpret)
    return out[: packets.shape[1], : packets.shape[2]]


def ltp_packet_reduce(packets, mask, *, compensation: str = "paper",
                      interpret: bool = True, donate: bool = False):
    """packets: (W, n_packets, payload); mask: (W, n_packets)."""
    return _variant("packet_reduce", bool(interpret), bool(donate),
                    compensation)(packets, mask)


def _randomk_core(x, u, k_frac, *, interpret: bool):
    orig_shape = x.shape
    flat = x.reshape(-1)
    uf = u.reshape(-1)
    n = flat.shape[0]
    cols = _rk.BLOCK_C
    rows = -(-n // cols)
    pad = rows * cols - n
    flat = jnp.pad(flat, (0, pad)).reshape(rows, cols)
    uf = jnp.pad(uf, (0, pad), constant_values=2.0).reshape(rows, cols)
    flat, _ = _pad_to(flat, _rk.BLOCK_R, 0)
    uf, _ = _pad_to(uf, _rk.BLOCK_R, 0)
    # padded uniforms = 2.0 > k  ->  padding never kept
    out = _rk.randomk(flat, uf, k_frac, interpret=interpret)
    return out.reshape(-1)[:n].reshape(orig_shape)


def randomk_sparsify(x, u, k_frac, *, interpret: bool = True):
    """Elementwise Random-k keep mask via uniforms ``u`` (same shape)."""
    return _variant("randomk", bool(interpret), False)(x, u, k_frac)
