"""Mixtral-8x22B — sparse MoE (8 experts, top-2) with SWA [arXiv:2401.04088]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    n_experts=8,
    top_k=2,
    block_pattern=("W",),   # sliding-window attention (Mistral lineage)
    window=4096,
    rope_theta=1e6,
    source="arXiv:2401.04088",
)

REDUCED = CONFIG.replace(
    name="mixtral-8x22b-reduced",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv=4,
    head_dim=32,
    d_ff=512,
    n_experts=4,
    top_k=2,
    window=64,
    vocab=512,
)
