"""Discrete-event core: event loop + lossy serialized pipes.

A ``Pipe`` models one direction of a link: store-and-forward serialization
at ``rate_bps``, a droptail queue (in packets) at its ingress, i.i.d.
non-congestion random loss, and fixed propagation delay. The incast
scenarios attach many senders to one shared bottleneck pipe — the ToR's
egress port toward the PS — which is where the paper's long-tail latency
is born.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Packet:
    flow: int
    seq: int              # packet sequence within the flow (jigsaw piece id)
    size: int             # bytes on the wire
    kind: str = "data"    # data | ack | stop | reg | end
    critical: bool = False
    meta: Any = None      # protocol payload (e.g. acked seq, send stamp)


class Sim:
    """Event loop. Callbacks run at monotonically nondecreasing times."""

    def __init__(self):
        self.now = 0.0
        self._heap: List = []
        self._ids = itertools.count()
        self.cancelled: set = set()

    def at(self, t: float, fn: Callable[[], None]) -> int:
        eid = next(self._ids)
        heapq.heappush(self._heap, (max(t, self.now), eid, fn))
        return eid

    def after(self, dt: float, fn: Callable[[], None]) -> int:
        return self.at(self.now + dt, fn)

    def cancel(self, eid: int) -> None:
        self.cancelled.add(eid)

    def run(self, until: float = float("inf"), max_events: int = 100_000_000):
        n = 0
        while self._heap and n < max_events:
            t, eid, fn = heapq.heappop(self._heap)
            if eid in self.cancelled:
                self.cancelled.discard(eid)
                continue
            if t > until:
                heapq.heappush(self._heap, (t, eid, fn))
                break
            self.now = t
            fn()
            n += 1
        return n


class Pipe:
    """One-direction link: droptail queue -> serializer -> loss -> delay."""

    def __init__(
        self,
        sim: Sim,
        rate_bps: float,
        delay: float,
        loss: float = 0.0,
        queue_pkts: int = 256,
        rng: Optional[np.random.Generator] = None,
        overhead: int = 0,
    ):
        self.sim = sim
        self.rate = rate_bps
        self.delay = delay
        self.loss = loss
        self.cap = queue_pkts
        self.rng = rng or np.random.default_rng(0)
        self.busy_until = 0.0
        self.overhead = overhead  # per-packet header bytes on the wire
        self.n_sent = 0
        self.n_dropped_queue = 0
        self.n_dropped_loss = 0
        self.bytes_delivered = 0

    def queue_len(self) -> float:
        backlog = max(0.0, self.busy_until - self.sim.now)
        return backlog * self.rate / 8.0 / 1500.0

    def send(self, pkt: Packet, deliver: Callable[[Packet], None]) -> bool:
        """Returns False if droptail-dropped at enqueue."""
        if self.queue_len() >= self.cap:
            self.n_dropped_queue += 1
            return False
        wire = pkt.size + self.overhead
        start = max(self.sim.now, self.busy_until)
        self.busy_until = start + wire * 8.0 / self.rate
        self.n_sent += 1
        if self.rng.random() < self.loss:
            self.n_dropped_loss += 1
            return True  # consumed wire time, dropped in flight
        arrive = self.busy_until + self.delay
        self.bytes_delivered += pkt.size

        def _deliver(p=pkt):
            deliver(p)

        self.sim.at(arrive, _deliver)
        return True
