"""Transport legs for the cluster runtime (DESIGN.md §8).

Two backends carry a worker's gradient from grad-ready to the PS on the
runtime's shared ``Sim`` clock:

``AnalyticPerWorkerNet``
    Fast closed-form per-flow timing for the async/SSP paths: each
    worker's gather leg is an independent transfer whose serialization
    shares the trunk with the flows active *at its start* (a bounded
    approximation of true interleaving), inflated by the protocol's
    loss model and an incast tail draw — the same ingredients as
    ``AnalyticIncastModel``, applied per flow instead of per barrier.
    LTP flows run the per-flow Early Close rule (LT threshold, pct
    target, deadline); reliable protocols wait for their last byte.

``DESTransport``
    The packet-level co-simulation: real LTP/TCP senders and receivers
    over a shared ``Topology`` (one trunk per PS shard, optional
    heterogeneous access links and cross traffic via ``GatherSpec``),
    with flows starting the instant the worker's compute finishes. Per
    iteration, bsp runs one ``ShardedGatherReceiver`` barrier gather;
    async/SSP run one single-flow ``PSGatherReceiver`` per (worker,
    shard) so every flow closes independently.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from repro.config import LTPConfig, NetConfig
from repro.core.early_close import AnalyticIncastModel
from repro.net import senders as snd
from repro.net.ltp_receiver import PSGatherReceiver, ShardedGatherReceiver
from repro.net.scenarios import (
    GatherSpec,
    _build_topology,
    _fwd_path,
    _npkts,
)
from repro.net.simcore import Packet, Pipe, Sim


class AnalyticPerWorkerNet:
    """Closed-form per-flow transport (the async/SSP fast path).

    ``send(worker, cb)`` schedules ``cb(frac, early_closed)`` at the
    flow's close time. The model: first byte lands after rtprop/2 + eps;
    100% would land after ``bytes * active / (bw/8) * loss_inflation *
    (1 + tail)``; LTP closes per the paper's double-threshold rule
    evaluated against that linear arrival ramp.
    """

    def __init__(self, sim: Sim, net: NetConfig, ltp: LTPConfig,
                 protocol: str, n_workers: int, model_bytes: float,
                 seed: int = 0, tail_prob: float = 0.15,
                 tail_scale: float = 1.5):
        self.sim = sim
        self.net = net
        self.ltp = ltp
        self.protocol = protocol
        self.w = n_workers
        self.bytes = float(model_bytes)
        self.rng = np.random.default_rng(seed + 77)
        self.tail_prob = tail_prob
        self.tail_scale = tail_scale
        # reuse the calibrated per-protocol loss-inflation law
        self._infl = AnalyticIncastModel(
            net, n_workers, protocol=protocol, seed=seed).loss_inflation()
        self.active = 0
        rt = net.rtprop_ms * 1e-3
        share = net.bandwidth_gbps * 1e9 / 8.0 / n_workers
        self.lt = ltp.lt_init_rtprop_mult * rt + self.bytes / share
        self.deadline = self.lt + ltp.deadline_c_ms * 1e-3

    def send(self, worker: int,
             cb: Callable[[float, bool], None]) -> None:
        rt = self.net.rtprop_ms * 1e-3
        bw = self.net.bandwidth_gbps * 1e9 / 8.0
        self.active += 1
        tail = (self.rng.exponential(self.tail_scale)
                if self.rng.random() < self.tail_prob else 0.0)
        t0 = rt
        t_full = rt + self.bytes * self.active / bw * self._infl * (1.0 + tail)
        if self.protocol != "ltp" or t_full <= self.lt:
            t_close, frac, early = t_full, 1.0, False
        else:
            # earliest t >= LT with pct >= threshold; deadline wins
            t_thr = t0 + self.ltp.data_pct_threshold * (t_full - t0)
            t_close = min(max(self.lt, t_thr), self.deadline)
            frac = float(np.clip((t_close - t0) / max(t_full - t0, 1e-12),
                                 0.0, 1.0))
            if t_close >= t_full:
                t_close, frac, early = t_full, 1.0, False
            else:
                early = True

        def done():
            self.active -= 1
            cb(frac, early)

        self.sim.after(t_close, done)


class _DESFlowSet:
    """Per-(worker, iteration) flow bundle on the shared topology: one
    single-flow gather receiver per PS shard; fires ``cb`` once all
    shards have closed."""

    def __init__(self, tr: "DESTransport", worker: int,
                 cb: Callable[[np.ndarray, float, bool], None]):
        self.tr = tr
        self.worker = worker
        self.cb = cb
        self.masks: List[Optional[np.ndarray]] = [None] * tr.n_ps
        self.closed = 0
        self.early = False
        for p in range(tr.n_ps):
            self._one_flow(p)

    def _one_flow(self, p: int) -> None:
        tr, w = self.tr, self.worker
        back = Pipe(tr.sim, tr.bw, tr.half_rtt, tr.net.loss_rate, 10_000,
                    tr.rng)
        if tr.protocol == "ltp":
            sender_cell: list = [None]

            def send_stop(flow):
                s = sender_cell[0]
                if s is not None:
                    back.send(Packet(s.flow, -2, 41, kind="stop"), s.on_ack)

            def on_close(recv, p=p):
                full = recv.all_full
                self._shard_done(p, recv.delivery_masks()[0], not full)

            recv = PSGatherReceiver(
                tr.sim, [w], tr.lt_per_worker[w], tr.deadline_per_worker[w],
                tr.ltp.data_pct_threshold, send_stop, on_close=on_close)
            s = snd.LTPSender(tr.sim, _fwd_path(tr.topo, tr.spec, p, w),
                              recv.on_data, tr.n, critical=tr.crit, flow=w,
                              rng=tr.rng, train_len=tr.coalesce)
            sender_cell[0] = s
            recv.attach_ack(w, lambda pkt, s=s, back=back:
                            back.send(pkt, s.on_ack))
            if tr.coalesce > 1:
                s.deliver_train = recv.on_data_train
                recv.attach_ack_train(
                    w, lambda acks, s=s, back=back:
                    back.send_train(acks, s.on_ack_train))
            s.start()
        else:
            def on_done(s, p=p):
                self._shard_done(p, np.ones(tr.n, bool), False)

            s = snd.make_sender(tr.protocol, tr.sim,
                                _fwd_path(tr.topo, tr.spec, p, w), None,
                                tr.n, flow=w, rng=tr.rng, on_done=on_done,
                                train_len=tr.coalesce)
            r = snd.TcpReceiver(tr.sim, lambda pkt, s=s, back=back:
                                back.send(pkt, s.on_ack), w)
            s.deliver = r.on_data
            if tr.coalesce > 1:
                s.deliver_train = r.on_data_train
                r.send_ack_train = (lambda acks, s=s, back=back:
                                    back.send_train(acks, s.on_ack_train))
            r.n_total = tr.n
            s.start()

    def _shard_done(self, p: int, mask: np.ndarray, early: bool) -> None:
        if self.masks[p] is not None:
            return
        self.masks[p] = mask
        self.early = self.early or early
        self.closed += 1
        if self.closed >= self.tr.n_ps:
            stacked = np.stack(self.masks)          # (n_ps, n)
            frac = float(stacked.mean())
            self.cb(stacked, frac, self.early)


class _DESBarrierGather:
    """Per-iteration bsp gather on the shared topology: one
    ``ShardedGatherReceiver`` over all W workers; senders join as their
    compute finishes (the runtime's start_delays, made event-driven)."""

    def __init__(self, tr: "DESTransport",
                 cb: Callable[[ShardedGatherReceiver], None]):
        self.tr = tr
        self.cb = cb
        self.t0 = tr.sim.now
        self._senders: Dict = {}
        self._stops: Dict = {}

        def send_stop(p, f):
            stop = self._stops.get((p, f))
            if stop is not None:
                stop()

        self.sharded = ShardedGatherReceiver(
            tr.sim, tr.n_ps, list(range(tr.w)),
            [tr.lt_shard] * tr.n_ps, [tr.deadline_shard] * tr.n_ps,
            tr.ltp.data_pct_threshold, send_stop)
        self._n_closed = 0
        for s in self.sharded.shards:
            s.on_close = self._shard_closed

    def _shard_closed(self, shard: PSGatherReceiver) -> None:
        self.tr.on_early_close(shard.ps_id, self.tr.sim.now,
                               float(shard.agg_pct), shard.all_full)
        self._n_closed += 1
        if self._n_closed >= self.tr.n_ps:
            self.cb(self.sharded)

    def add_worker(self, worker: int) -> None:
        """Start worker's shard flows now (its compute just finished)."""
        tr = self.tr
        for p in range(tr.n_ps):
            shard = self.sharded.shard(p)
            if shard.closed:
                continue   # shard already gave up on this straggler
            back = Pipe(tr.sim, tr.bw, tr.half_rtt, tr.net.loss_rate,
                        10_000, tr.rng)
            s = snd.LTPSender(tr.sim, _fwd_path(tr.topo, tr.spec, p, worker),
                              shard.on_data, tr.n, critical=tr.crit,
                              flow=worker, rng=tr.rng, train_len=tr.coalesce)
            shard.attach_ack(worker, lambda pkt, s=s, back=back:
                             back.send(pkt, s.on_ack))
            if tr.coalesce > 1:
                s.deliver_train = shard.on_data_train
                shard.attach_ack_train(
                    worker, lambda acks, s=s, back=back:
                    back.send_train(acks, s.on_ack_train))
            self._stops[(p, worker)] = (
                lambda s=s, back=back: back.send(
                    Packet(s.flow, -2, 41, kind="stop"), s.on_ack))
            self._senders[(p, worker)] = s
            s.start()


class DESTransport:
    """Packet-level transport on the runtime's shared clock. bsp uses
    ``start_gather``/``add_worker`` (one barrier gather per iteration);
    async/SSP use ``send`` (independent per-worker flow sets). LTP flows
    in this transport carry static LT thresholds from the paper's init
    formula (per-link attainable share); the epoch-adaptive LT update of
    ``scenarios._iterate_gather`` is out of scope here."""

    def __init__(self, sim: Sim, net: NetConfig, ltp: LTPConfig,
                 protocol: str, n_workers: int, model_bytes: float,
                 n_ps: int = 1, spec: Optional[GatherSpec] = None,
                 seed: int = 0, coalesce: int = 1,
                 on_early_close: Optional[Callable] = None):
        self.sim = sim
        self.net = net
        self.ltp = ltp
        self.protocol = protocol
        self.w = n_workers
        self.spec = spec or GatherSpec(n_ps=n_ps)
        self.n_ps = self.spec.n_ps
        self.coalesce = max(1, int(coalesce))
        self.rng = np.random.default_rng(seed + 101)
        self.bw = net.bandwidth_gbps * 1e9
        self.half_rtt = net.rtprop_ms * 1e-3
        self.topo, self.sources = _build_topology(
            sim, net, n_workers, self.spec, self.rng, self.coalesce)
        shard_bytes = model_bytes / self.n_ps
        self.n = _npkts(shard_bytes, protocol)
        crit = np.zeros(self.n, bool)
        ncrit = max(2, int(0.01 * self.n))
        crit[: ncrit // 2] = True
        crit[-(ncrit - ncrit // 2):] = True
        self.crit = crit
        rt = net.rtprop_ms * 1e-3
        c = ltp.deadline_c_ms * 1e-3
        self.lt_per_worker = np.empty(n_workers)
        for f in range(n_workers):
            share = self.spec.worker_share_bps(f, n_workers, net) / 8.0
            self.lt_per_worker[f] = (ltp.lt_init_rtprop_mult * rt
                                     + shard_bytes / share)
        self.deadline_per_worker = self.lt_per_worker + c
        self.lt_shard = float(self.lt_per_worker.max())
        self.deadline_shard = self.lt_shard + c
        self._on_early_close = on_early_close

    def stop(self) -> None:
        for src in self.sources:
            src.stop()

    def on_early_close(self, shard: int, t: float, delivered: float,
                       full: bool) -> None:
        if self._on_early_close is not None and not full:
            self._on_early_close(shard, t, delivered)

    # -- async/SSP: independent per-worker flow sets ------------------------
    def send(self, worker: int,
             cb: Callable[[np.ndarray, float, bool], None]) -> None:
        _DESFlowSet(self, worker, cb)

    # -- bsp: one barrier gather per iteration ------------------------------
    def start_gather(self, cb: Callable[[ShardedGatherReceiver], None],
                     ) -> _DESBarrierGather:
        return _DESBarrierGather(self, cb)

    def queue_depth_pkts(self) -> float:
        """Max trunk queue depth right now (telemetry sampler hook)."""
        depths = self.topo.queue_depths()
        return max(depths.values()) if depths else 0.0
