"""Worker / PS actors on the runtime's shared event clock (DESIGN.md §8).

A ``WorkerActor`` is the per-worker state machine: (policy gate) ->
fetch params -> compute (sampled from the compute model) -> hand the
gradient to the transport -> immediately attempt the next iteration.
Whether that attempt proceeds is the aggregation policy's call — bsp
blocks until the barrier commits, ssp blocks when the worker runs too
far ahead, async never blocks.

The ``PSActor`` is the admission side: every arriving gradient goes
through the policy, ready batches are folded into the model by the
runtime (which owns the JAX state), and too-stale arrivals are counted
out. Both actors only *schedule*; all numerical work lives in
``ClusterRuntime``.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

from repro.runtime.policies import PendingGrad

if TYPE_CHECKING:
    from repro.runtime.runtime import ClusterRuntime


class WorkerActor:
    def __init__(self, rt: "ClusterRuntime", idx: int):
        self.rt = rt
        self.idx = idx
        self.it = 0
        self.blocked = False
        self.busy = False      # a compute event for self.it is in flight
        self.params_version = 0
        self.params_snap = None
        self.finished = False

    def start(self) -> None:
        self._try_begin()

    def _try_begin(self) -> None:
        rt = self.rt
        if self.busy or self.finished:
            return   # wake paths may overlap; one compute per iteration
        if self.it >= rt.steps:
            if self.blocked:
                self.blocked = False
                rt._blocked.discard(self.idx)
                rt.tel.record("unblock", rt.sim.now, worker=self.idx,
                              iteration=self.it)
            if not self.finished:
                self.finished = True
                rt.on_worker_finished(self.idx)
            return
        if not rt.policy.may_start(self.idx, self.it):
            if not self.blocked:
                self.blocked = True
                rt._blocked.add(self.idx)
                rt.tel.record("block", rt.sim.now, worker=self.idx,
                              iteration=self.it)
            return
        if self.blocked:
            self.blocked = False
            rt._blocked.discard(self.idx)
            rt.tel.record("unblock", rt.sim.now, worker=self.idx,
                          iteration=self.it)
        rt.policy.on_start(self.idx, self.it)
        self.params_version, self.params_snap = rt.visible_params()
        dt = rt.compute.sample(self.idx, self.it)
        it = self.it
        rt.tel.record("compute_start", rt.sim.now, worker=self.idx,
                      iteration=it, dt=dt)
        self.busy = True
        rt.sim.after(dt, lambda: self._grad_ready(it))
        # starting an iteration advances this worker's clock, which may
        # release SSP peers parked on the staleness bound
        rt.wake_blocked(exclude=self.idx)

    def _grad_ready(self, it: int) -> None:
        rt = self.rt
        self.busy = False
        rt.tel.record("grad_ready", rt.sim.now, worker=self.idx, iteration=it)
        rt.on_grad_ready(self, it)
        self.it = it + 1
        self._try_begin()


class PSActor:
    """Admission + flush loop over the aggregation policy."""

    def __init__(self, rt: "ClusterRuntime"):
        self.rt = rt

    def on_arrival(self, g: PendingGrad) -> None:
        rt = self.rt
        rt.tel.record("grad_arrived", rt.sim.now, worker=g.worker,
                      iteration=g.iteration, staleness=g.staleness,
                      delivered=float(g.payload["frac"]))
        # O(1) sample: PS pending depth only. Trunk queue depths are
        # sampled on the runtime's Sim.every wall grid, NOT per arrival —
        # a topology walk per gradient would put an O(pipes) cost on the
        # hot path (DESIGN.md §9).
        rt.policy.on_arrival(g)
        rt.tel.record("queue", rt.sim.now, depth=rt.policy.pending_count())
        self.flush()

    def flush(self) -> None:
        rt = self.rt
        for g in rt.policy.drained_stale():
            rt.tel.record("stale_drop", rt.sim.now, worker=g.worker,
                          iteration=g.iteration, staleness=g.staleness)
        batch = rt.policy.ready()
        while batch:
            rt.apply_batch(batch)
            batch = rt.policy.ready()
        rt.maybe_finish()
