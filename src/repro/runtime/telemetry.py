"""Structured runtime telemetry (DESIGN.md §8).

Every actor/policy event in a ``ClusterRuntime`` run lands here as one
flat dict — an append-only stream the benchmarks and tests consume
directly, and ``summary()`` reduces into the scalar fields the sweep
rows carry.

Event schema — common fields ``kind`` (str) and ``t`` (sim seconds),
plus per-kind payload:

  compute_start   worker, iteration, dt
  grad_ready      worker, iteration            (compute leg done)
  grad_arrived    worker, iteration, staleness, delivered
  apply           step, n_grads, staleness_max, staleness_mean, loss
  early_close     worker|shard, iteration, delivered   (EC fire time = t)
  stale_drop      worker, iteration, staleness (SSP rejected the grad)
  block/unblock   worker, iteration            (SSP/BSP gating)
  queue           depth [, net_depth]          (PS pending / trunk pkts)
  masks           [worker,] iteration, digest  (DES delivery-mask hash)

Fault-layer kinds (DESIGN.md §10; absent in a zero-fault run):

  fault           fault, target                (injected FaultEvent kind)
  lifecycle       worker, state, iteration [, reason]
  flow_torn       worker, iteration   (crash fenced an in-flight grad)
  ps_lost         worker, iteration   (PS downtime swallowed a grad)
  ps_failover     ps, step, n_hist    (snapshot restored, history cut)
  checkpoint      step, n_hist        (periodic snapshot taken)
  rebalance       owner               (shard ownership re-homed)

Conservation law the chaos suite asserts: every grad_ready is applied,
stale-dropped, torn, or lost —
``n(grad_ready) == sum(apply.n_grads) + n(stale_drop) + n(flow_torn)
+ n(ps_lost)``.

Sampling discipline (DESIGN.md §9): per-event hooks record O(1)
payloads only; anything that walks topology state (trunk queue depths)
is sampled on the runtime's ``Sim.every`` wall grid, never per event.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class Telemetry:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: List[dict] = []

    def record(self, kind: str, t: float, **fields) -> None:
        if not self.enabled:
            return
        self.events.append({"kind": kind, "t": float(t), **fields})

    def of(self, kind: str) -> List[dict]:
        return [e for e in self.events if e["kind"] == kind]

    def blocked_seconds(self) -> float:
        """Total worker-seconds spent blocked on the staleness/barrier
        gate (paired block/unblock events; an unmatched block counts to
        the last event's timestamp)."""
        t_end = self.events[-1]["t"] if self.events else 0.0
        open_t: Dict[int, float] = {}
        total = 0.0
        for e in self.events:
            if e["kind"] == "block":
                open_t.setdefault(e["worker"], e["t"])
            elif e["kind"] == "unblock":
                t0 = open_t.pop(e["worker"], None)
                if t0 is not None:
                    total += e["t"] - t0
        total += sum(t_end - t0 for t0 in open_t.values())
        return total

    def summary(self) -> Dict[str, float]:
        """Scalar reduction of the stream — what a sweep row carries."""
        applies = self.of("apply")
        stale = [e["staleness_max"] for e in applies]
        stale_mean = [e["staleness_mean"] for e in applies]
        queues = self.of("queue")
        closes = self.of("early_close")
        out = {
            "n_events": len(self.events),
            "n_applies": len(applies),
            "n_early_close": len(closes),
            "n_stale_drops": len(self.of("stale_drop")),
            "blocked_s": round(self.blocked_seconds(), 6),
            "staleness_max": int(max(stale)) if stale else 0,
            "staleness_mean": round(float(np.mean(stale_mean)), 4)
            if stale_mean else 0.0,
        }
        if queues:
            depths = [e["depth"] for e in queues]
            out["queue_depth_mean"] = round(float(np.mean(depths)), 3)
            out["queue_depth_max"] = float(np.max(depths))
            net = [e["net_depth"] for e in queues if "net_depth" in e]
            if net:
                out["net_queue_max_pkts"] = round(float(np.max(net)), 2)
        if closes:
            out["early_close_mean_delivered"] = round(
                float(np.mean([e["delivered"] for e in closes])), 4)
        faults = self.of("fault")
        if faults:
            out["n_faults"] = len(faults)
            out["n_flow_torn"] = len(self.of("flow_torn"))
            out["n_ps_lost"] = len(self.of("ps_lost"))
            out["n_failovers"] = len(self.of("ps_failover"))
            out["n_checkpoints"] = len(self.of("checkpoint"))
        return out
