"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp


def dropfill_ref(packets, mask, scale):
    """Bubble-fill + compensation.

    packets: (n_packets, payload) float; mask: (n_packets,) {0,1};
    scale: (n_packets,) compensation multiplier.
    out = packets * mask * scale (lost packets zero-filled — paper §III-C).
    """
    return packets * (mask * scale)[:, None].astype(packets.dtype)


def packet_reduce_ref(packets, mask, *, compensation: str = "paper"):
    """PS-side masked multi-worker aggregation.

    packets: (W, n_packets, payload); mask: (W, n_packets) {0,1}.
      paper: sum over delivered / W     (zero bubbles count in the mean)
      count: sum over delivered / count (unbiased over deliverers)
    Returns (n_packets, payload) float32.
    """
    w = packets.shape[0]
    masked = packets.astype(jnp.float32) * mask[..., None].astype(jnp.float32)
    tot = jnp.sum(masked, axis=0)
    if compensation == "count":
        cnt = jnp.maximum(jnp.sum(mask, axis=0), 1.0)
        return tot / cnt[:, None]
    return tot / w


def randomk_ref(x, u, k_frac):
    """Random-k sparsification: keep where u < k_frac (Random-k [26])."""
    return jnp.where(u < k_frac, x, jnp.zeros_like(x))
