"""replint core: pragma parsing, rule registry, file walking.

A *rule* is a callable over a :class:`FileContext` yielding
:class:`Finding` objects. Rules register themselves with
:func:`register`; :func:`lint_file` runs the selected rules, applies
``# replint: ok(<rule>)`` suppressions, and reports pragma hygiene
(malformed pragmas, pragmas naming unknown rules, pragmas that
suppressed nothing) under the always-on pseudo-rule ``pragma``.
Unparsable files surface under the pseudo-rule ``parse``.

Pragma grammar (one directive per comment)::

    # replint: ok(rule)            suppress `rule` on this line
    # replint: ok(rule-a, rule-b)  suppress several rules
    # replint: hotpath             mark the next/this-line function hot

A pragma comment on its own line applies to the next code line, so it
can sit above the statement it excuses; a trailing pragma applies to
its own line.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

PRAGMA_RE = re.compile(r"#\s*replint:\s*(?P<body>.*?)\s*$")
OK_RE = re.compile(r"^ok\s*\(\s*(?P<rules>[^)]*)\s*\)$")

#: pseudo-rules that are always active and not user-selectable
META_RULES = ("parse", "pragma")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One linter finding, stable across output formats."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def to_json(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class Pragma:
    """One parsed ``# replint:`` comment."""

    line: int           # line the comment sits on
    target: int         # code line the pragma governs
    kind: str           # "ok" | "hotpath" | "bad"
    rules: Tuple[str, ...] = ()
    text: str = ""


class Pragmas:
    """All ``# replint:`` pragmas of one file, indexed by target line."""

    def __init__(self, items: Sequence[Pragma]) -> None:
        self.items = list(items)
        self.ok_by_line: Dict[int, Set[str]] = {}
        self.hotpath_lines: Set[int] = set()
        for p in self.items:
            if p.kind == "ok":
                self.ok_by_line.setdefault(p.target, set()).update(p.rules)
            elif p.kind == "hotpath":
                self.hotpath_lines.add(p.target)

    def suppresses(self, finding: Finding) -> Optional[str]:
        """The rule name that suppresses ``finding``, or None."""
        rules = self.ok_by_line.get(finding.line, ())
        return finding.rule if finding.rule in rules else None


def _parse_pragmas(source: str) -> Pragmas:
    """Tokenize-based pragma scan (robust to ``#`` inside strings)."""
    pragmas: List[Pragma] = []
    comments: List[Tuple[int, int, str]] = []   # (line, col, text)
    code_lines: Set[int] = set()
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return Pragmas([])
    skip = {tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE,
            tokenize.INDENT, tokenize.DEDENT, tokenize.ENDMARKER}
    for tok in toks:
        if tok.type == tokenize.COMMENT:
            comments.append((tok.start[0], tok.start[1], tok.string))
        elif tok.type not in skip:
            code_lines.add(tok.start[0])
            if tok.end[0] != tok.start[0]:
                code_lines.update(range(tok.start[0], tok.end[0] + 1))
    sorted_code = sorted(code_lines)

    def next_code_line(after: int) -> int:
        for ln in sorted_code:
            if ln > after:
                return ln
        return after  # trailing comment at EOF: govern itself

    for line, _col, text in comments:
        m = PRAGMA_RE.search(text)
        if not m:
            continue
        body = m.group("body")
        target = line if line in code_lines else next_code_line(line)
        if body == "hotpath":
            pragmas.append(Pragma(line, target, "hotpath"))
            continue
        ok = OK_RE.match(body)
        if ok:
            rules = tuple(r.strip() for r in ok.group("rules").split(",")
                          if r.strip())
            if rules:
                pragmas.append(Pragma(line, target, "ok", rules))
            else:
                pragmas.append(Pragma(line, target, "bad", (),
                                      "ok() pragma names no rule"))
            continue
        pragmas.append(Pragma(line, target, "bad", (),
                              f"unrecognized pragma {body!r} (expected "
                              f"'ok(<rule>)' or 'hotpath')"))
    return Pragmas(pragmas)


class FileContext:
    """Everything a rule needs to know about one source file."""

    def __init__(self, path: str, source: str, tree: ast.Module,
                 pragmas: Pragmas,
                 design_sections: Optional[Set[str]] = None) -> None:
        self.path = path
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        self.pragmas = pragmas
        self.design_sections = design_sections
        norm = os.path.normpath(path).replace(os.sep, "/")
        self.parts: Tuple[str, ...] = tuple(norm.split("/"))
        self.filename = self.parts[-1] if self.parts else path

    def in_package_dirs(self, dirs: Sequence[str]) -> bool:
        """True when the file lives under ``repro/<d>/`` for some d in
        ``dirs`` (matches both the real tree and test fixtures)."""
        for i, part in enumerate(self.parts[:-1]):
            if part == "repro" and i + 1 < len(self.parts) \
                    and self.parts[i + 1] in dirs:
                return True
        return False


RuleFunc = Callable[[FileContext], Iterable[Finding]]

#: rule name -> (function, one-line description); insertion-ordered
RULES: Dict[str, Tuple[RuleFunc, str]] = {}


def register(name: str, description: str) -> Callable[[RuleFunc], RuleFunc]:
    def deco(fn: RuleFunc) -> RuleFunc:
        RULES[name] = (fn, description)
        return fn
    return deco


def _ensure_rules() -> None:
    if not RULES:
        from repro.devtools.replint import rules as _rules  # noqa: F401


def rule_names() -> List[str]:
    _ensure_rules()
    return list(RULES)


# -- DESIGN.md section discovery ---------------------------------------------

_SECTION_RE = re.compile(r"§([A-Za-z0-9_]+(?:\.[0-9]+)*)")
_design_cache: Dict[str, Optional[Set[str]]] = {}


def _design_sections_for(path: str,
                         explicit: Optional[str] = None) -> Optional[Set[str]]:
    """Section tokens of the DESIGN.md governing ``path`` (nearest one
    walking up from the file), or None when there is none."""
    if explicit is not None:
        if explicit not in _design_cache:
            _design_cache[explicit] = _read_sections(explicit)
        return _design_cache[explicit]
    d = os.path.dirname(os.path.abspath(path))
    seen: List[str] = []
    while True:
        if d in _design_cache:
            sections = _design_cache[d]
            break
        seen.append(d)
        cand = os.path.join(d, "DESIGN.md")
        if os.path.isfile(cand):
            sections = _read_sections(cand)
            break
        parent = os.path.dirname(d)
        if parent == d:
            sections = None
            break
        d = parent
    for s in seen:
        _design_cache[s] = sections
    return sections


def _read_sections(design_path: str) -> Optional[Set[str]]:
    try:
        with open(design_path, encoding="utf-8") as f:
            text = f.read()
    except OSError:
        return None
    sections: Set[str] = set()
    for line in text.splitlines():
        if line.lstrip().startswith("#"):
            sections.update(_SECTION_RE.findall(line))
    return sections


# -- driving -----------------------------------------------------------------

def iter_python_files(paths: Sequence[str]) -> Iterator[str]:
    """Expand files/dirs into a sorted, deduped list of ``.py`` files."""
    out: Set[str] = set()
    for p in paths:
        if os.path.isdir(p):
            for root, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if not d.startswith(".")
                                     and d != "__pycache__")
                for fn in filenames:
                    if fn.endswith(".py"):
                        out.add(os.path.join(root, fn))
        else:
            out.add(p)
    return iter(sorted(out))


def lint_file(path: str, select: Optional[Sequence[str]] = None,
              design: Optional[str] = None) -> List[Finding]:
    """Lint one file; returns surviving findings (pragma-suppressed ones
    removed, pragma-hygiene findings added)."""
    _ensure_rules()
    selected = list(select) if select is not None else list(RULES)
    full_run = set(selected) == set(RULES)
    try:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    except OSError as e:
        return [Finding("parse", path, 1, 0, f"cannot read file: {e}")]
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("parse", path, e.lineno or 1, e.offset or 0,
                        f"syntax error: {e.msg}")]
    pragmas = _parse_pragmas(source)
    ctx = FileContext(path, source, tree, pragmas,
                      design_sections=_design_sections_for(path, design))

    raw: List[Finding] = []
    for name in selected:
        fn, _desc = RULES[name]
        raw.extend(fn(ctx))

    used: Set[Tuple[int, str]] = set()
    kept: List[Finding] = []
    for f in raw:
        rule = pragmas.suppresses(f)
        if rule is not None:
            used.add((f.line, rule))
        else:
            kept.append(f)

    # pragma hygiene: malformed, unknown-rule, and unused pragmas
    known = set(RULES) | set(META_RULES)
    for p in pragmas.items:
        if p.kind == "bad":
            kept.append(Finding("pragma", path, p.line, 0, p.text))
            continue
        if p.kind != "ok":
            continue
        for r in p.rules:
            if r not in known:
                kept.append(Finding(
                    "pragma", path, p.line, 0,
                    f"pragma names unknown rule {r!r} "
                    f"(known: {', '.join(sorted(known))})"))
            elif full_run and r in RULES and (p.target, r) not in used:
                kept.append(Finding(
                    "pragma", path, p.line, 0,
                    f"unused pragma: ok({r}) suppresses nothing on "
                    f"line {p.target}"))
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


def lint_paths(paths: Sequence[str], select: Optional[Sequence[str]] = None,
               design: Optional[str] = None) -> Tuple[List[Finding], int]:
    """Lint files/directories; returns (findings, files_scanned)."""
    _ensure_rules()
    findings: List[Finding] = []
    n = 0
    for path in iter_python_files(paths):
        n += 1
        findings.extend(lint_file(path, select=select, design=design))
    return findings, n
