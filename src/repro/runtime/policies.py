"""PS-side aggregation policies (DESIGN.md §8).

An ``AggregationPolicy`` decides, as gradients arrive at the PS over the
shared sim clock, (a) when to fold them into the model (``ready``),
(b) whether a worker may begin its next iteration (``may_start``), and
(c) how much each admitted gradient weighs (``weights`` — staleness
damping fed to ``ltp_sync.reduce_packet_stream``).

  bsp       full barrier: apply when all W gradients of the current
            iteration are in; workers lockstep. Reproduces the legacy
            ``PSTrainer`` loop to float tolerance (the runtime runs the
            same fused step on the same masks).
  async     apply-on-arrival with per-worker learning-rate damping
            1/(1 + damping * staleness); workers never block.
  ssp(k)    bounded staleness: a worker may run at most ``staleness``
            iterations ahead of the slowest; arrivals staler than k are
            rejected (counted, never folded in); pending reductions are
            admitted oldest-iteration-first (MLFabric-style aggregation
            ordering) with staleness-damped weights
            (``LTPConfig.staleness_comp``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Union

import numpy as np

from repro.core.ltp_sync import staleness_weights


@dataclasses.dataclass
class PendingGrad:
    """One gradient parked at the PS awaiting admission."""

    worker: int
    iteration: int
    t_ready: float            # sim time the gradient arrived at the PS
    staleness: int = 0        # iterations behind the freshest applied
    payload: Any = None       # runtime-owned: flat packets, masks, frac, loss


#: name -> class; ``make_policy`` dispatches through this table.
POLICIES: Dict[str, type] = {}


def register_policy(name: str):
    def deco(cls):
        POLICIES[name] = cls
        cls.name = name
        return cls
    return deco


class AggregationPolicy:
    """Interface; concrete policies override the four decision hooks."""

    name = "?"
    active: frozenset = frozenset()

    def bind(self, n_workers: int) -> None:
        self.w = n_workers
        self.active = frozenset(range(n_workers))

    # -- degraded membership (DESIGN.md §10) --------------------------------
    def on_membership(self, active) -> None:
        """The fault layer's membership hook: ``active`` is the set of
        worker slots currently alive. bsp re-barriers on the surviving
        set; async/ssp rescale staleness damping and contribution
        weights by the effective membership."""
        self.active = frozenset(active)

    def membership_scale(self) -> float:
        """W / W_eff — restores the per-contribution effective step when
        fewer than W slots feed the 1/W reduction. 1.0 at full
        membership (and on an unbound policy, which some unit tests
        drive directly)."""
        w = getattr(self, "w", 0)
        n = len(getattr(self, "active", ()))
        if not w or not n or n == w:
            return 1.0
        return w / n

    # -- worker-side gate ---------------------------------------------------
    def may_start(self, worker: int, iteration: int) -> bool:
        return True

    def on_start(self, worker: int, iteration: int) -> None:
        pass

    # -- PS-side admission --------------------------------------------------
    def on_arrival(self, g: PendingGrad) -> None:
        raise NotImplementedError

    def ready(self) -> List[PendingGrad]:
        """Drain the batch to reduce+apply NOW (possibly empty)."""
        raise NotImplementedError

    def on_applied(self, batch: List[PendingGrad]) -> None:
        pass

    def weights(self, batch: List[PendingGrad]) -> Optional[np.ndarray]:
        """Per-gradient contribution weights (None = uniform 1)."""
        return None

    def drained_stale(self) -> List[PendingGrad]:
        """Gradients rejected as too stale since the last call."""
        return []

    def drop_pending(self) -> List[PendingGrad]:
        """Discard every parked gradient (PS failover tore the state from
        under them); returns the dropped batch for telemetry accounting."""
        return []

    def rollback(self, step: int) -> None:
        """PS failover restored the model at ``step`` applied iterations;
        policies with an iteration frontier re-anchor there."""

    def pending_count(self) -> int:
        """Gradients parked at the PS right now (telemetry queue depth)."""
        return 0


@register_policy("bsp")
class BSPPolicy(AggregationPolicy):
    """Bulk-synchronous barrier — the paper's (and legacy PSTrainer's)
    semantics: one fused reduction per iteration, workers lockstep."""

    def bind(self, n_workers: int) -> None:
        super().bind(n_workers)
        self.committed = 0                      # iterations fully applied
        self._buf: Dict[int, Dict[int, PendingGrad]] = {}

    def may_start(self, worker: int, iteration: int) -> bool:
        return iteration <= self.committed

    def on_arrival(self, g: PendingGrad) -> None:
        self._buf.setdefault(g.iteration, {})[g.worker] = g

    def ready(self) -> List[PendingGrad]:
        cur = self._buf.get(self.committed, {})
        if not self.active or not self.active <= set(cur):
            return []
        del self._buf[self.committed]
        return [cur[f] for f in sorted(cur)]

    def on_applied(self, batch: List[PendingGrad]) -> None:
        self.committed += 1

    def on_membership(self, active) -> None:
        # re-barrier on the surviving set: dead slots can no longer be
        # waited on, and their parked gradients are unreachable
        super().on_membership(active)
        for d in self._buf.values():
            for wk in [wk for wk in d if wk not in self.active]:
                del d[wk]
        self._buf = {it: d for it, d in self._buf.items() if d}

    def rollback(self, step: int) -> None:
        self.committed = int(step)
        self._buf.clear()

    def drop_pending(self) -> List[PendingGrad]:
        out = [g for d in self._buf.values() for g in d.values()]
        self._buf.clear()
        return out

    def pending_count(self) -> int:
        return sum(len(d) for d in self._buf.values())


@register_policy("async")
class AsyncPolicy(AggregationPolicy):
    """Apply-on-arrival: no barrier, no blocking. Staleness costs a
    learning-rate damp of 1/(1 + damping * staleness) per gradient
    (``ltp_sync.staleness_weights``). ``damping=None`` defers to
    ``LTPConfig.staleness_comp`` — the runtime wires it at bind time —
    so the config knob governs both async and SSP unless a policy
    instance overrides it explicitly."""

    def __init__(self, damping: Optional[float] = None):
        self.damping = None if damping is None else float(damping)

    def bind(self, n_workers: int) -> None:
        super().bind(n_workers)
        self._pending: List[PendingGrad] = []

    def on_arrival(self, g: PendingGrad) -> None:
        self._pending.append(g)

    def ready(self) -> List[PendingGrad]:
        batch, self._pending = self._pending, []
        return batch

    def weights(self, batch: List[PendingGrad]) -> Optional[np.ndarray]:
        wts = None
        if self.damping:
            wts = staleness_weights([g.staleness for g in batch],
                                    self.damping)
        scale = self.membership_scale()
        if scale != 1.0:
            # the runtime's apply divides by W; W/W_eff restores the mean
            # over the surviving contributors
            if wts is None:
                wts = np.ones(len(batch))
            wts = wts * scale
        return wts

    def drop_pending(self) -> List[PendingGrad]:
        out, self._pending = self._pending, []
        return out

    def pending_count(self) -> int:
        return len(self._pending)


@register_policy("ssp")
class SSPPolicy(AggregationPolicy):
    """Bounded staleness: worker clocks may spread at most ``staleness``
    iterations; admission is oldest-first with staleness-damped weights.

    ``staleness_comp`` is the damping coefficient for admitted-but-stale
    gradients (wired from ``LTPConfig.staleness_comp`` by the runtime);
    gradients staler than the bound are rejected outright.
    """

    def __init__(self, staleness: int = 2, staleness_comp: float = 0.0):
        if staleness < 0:
            raise ValueError("staleness bound must be >= 0")
        self.k = int(staleness)
        self.staleness_comp = float(staleness_comp)

    def bind(self, n_workers: int) -> None:
        super().bind(n_workers)
        self._clock = dict.fromkeys(range(n_workers), 0)  # next iteration
        self._pending: List[PendingGrad] = []
        self._stale: List[PendingGrad] = []

    def may_start(self, worker: int, iteration: int) -> bool:
        # membership-set order is irrelevant: only min(clocks) is used
        clocks = [self._clock[wk] for wk in self.active  # replint: ok(determinism)
                  if wk in self._clock]
        if not clocks:
            clocks = [self._clock.get(worker, 0)]
        return iteration <= min(clocks) + self.k

    def on_start(self, worker: int, iteration: int) -> None:
        self._clock[worker] = iteration + 1

    def on_membership(self, active) -> None:
        # a dead slot's frozen clock must not gate the survivors; a
        # rejoiner is admitted at the surviving frontier so its stale
        # clock does not stall the bound either
        new = frozenset(active) - self.active
        super().on_membership(active)
        if new:
            cur = max((self._clock.get(wk, 0) for wk in self.active),
                      default=0)
            for wk in sorted(new):
                self._clock[wk] = max(self._clock.get(wk, 0), cur)

    def on_arrival(self, g: PendingGrad) -> None:
        if g.staleness > self.k:
            self._stale.append(g)
        else:
            self._pending.append(g)

    def ready(self) -> List[PendingGrad]:
        # MLFabric-style aggregation ordering: oldest iteration first, so
        # the reduction retires the laggard's work before fresher shards
        batch = sorted(self._pending, key=lambda g: (g.iteration, g.worker))
        self._pending = []
        return batch

    def weights(self, batch: List[PendingGrad]) -> Optional[np.ndarray]:
        wts = None
        if self.staleness_comp > 0:
            wts = staleness_weights([g.staleness for g in batch],
                                    self.staleness_comp)
        scale = self.membership_scale()
        if scale != 1.0:
            if wts is None:
                wts = np.ones(len(batch))
            wts = wts * scale
        return wts

    def drained_stale(self) -> List[PendingGrad]:
        out, self._stale = self._stale, []
        return out

    def drop_pending(self) -> List[PendingGrad]:
        out = self._pending + self._stale
        self._pending, self._stale = [], []
        return out

    def pending_count(self) -> int:
        return len(self._pending)


def make_policy(spec: Union[str, AggregationPolicy],
                **kw) -> AggregationPolicy:
    """Resolve a policy from an instance or a registered name. Extra
    kwargs go to the named policy's constructor, e.g.
    ``make_policy("ssp", staleness=3)``."""
    if isinstance(spec, AggregationPolicy):
        return spec
    try:
        cls = POLICIES[spec]
    except KeyError:
        raise ValueError(
            f"unknown aggregation policy {spec!r}; registered: "
            f"{sorted(POLICIES)}") from None
    return cls(**kw)
