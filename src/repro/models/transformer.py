"""Decoder-only transformer covering dense / MoE / SSM / hybrid / VLM
families via a per-layer mixer code ('A', 'W', 'M', 'M2', 'L').

Layer stacking: layers are grouped by *pattern period* and scanned —
each position within the period has a static mixer code, so heterogeneous
patterns (gemma3 5W:1A, zamba2 mamba+shared-attn) still lower as a single
``lax.scan`` with static trip count (exact roofline accounting, small HLO).

  params = {
    embed, lead: (layer...), stack: {p0..p{P-1}: stacked over periods},
    rem: (layer...), shared_attn?, final_norm
  }

Decode (`serve_step`) unrolls a Python loop over layers so every layer can
carry its own cache shape (ring buffers for 'W' layers, latent caches for
MLA, SSM states for mamba) — that is what makes long_500k feasible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    Params,
    apply_mlp,
    apply_norm,
    cross_entropy,
    embed_params,
    embed_tokens,
    mlp_params,
    norm_params,
    split_keys,
    unembed,
)
from repro.models.sharding import ShardCtx, NULL_CTX


# ----------------------------------------------------------------------------
# Layer plan
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    lead_codes: Tuple[str, ...]   # unstacked leading layers (deepseek dense-0)
    period_codes: Tuple[str, ...] # codes within one scanned period
    n_periods: int
    rem_codes: Tuple[str, ...]    # unstacked trailing layers
    shared_attn: bool             # zamba2: shared block at each period end

    @property
    def n_layers(self) -> int:
        return (
            len(self.lead_codes)
            + self.n_periods * len(self.period_codes)
            + len(self.rem_codes)
        )


def make_plan(cfg: ModelConfig) -> LayerPlan:
    codes = cfg.pattern_layers
    lead = cfg.first_dense_layers if cfg.n_experts > 0 else 0
    rest = codes[lead:]
    if cfg.shared_attn_every > 0:
        period = cfg.shared_attn_every
        shared = True
    else:
        period = len(cfg.block_pattern)
        shared = False
    n_full = len(rest) // period
    rem = rest[n_full * period :]
    return LayerPlan(
        lead_codes=codes[:lead],
        period_codes=rest[:period] if n_full > 0 else (),
        n_periods=n_full,
        rem_codes=rem if n_full > 0 else rest,
        shared_attn=shared,
    )


def _layer_has_mlp(cfg: ModelConfig, code: str) -> bool:
    if code in ("M", "M2"):
        return False  # mamba block is the whole layer
    return cfg.d_ff > 0 or cfg.n_experts > 0


def _layer_is_moe(cfg: ModelConfig, code: str, is_lead: bool) -> bool:
    return cfg.n_experts > 0 and not is_lead and _layer_has_mlp(cfg, code)


def window_for(cfg: ModelConfig, code: str) -> int:
    return cfg.window if code == "W" else 0


# ----------------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------------


def _mixer_params(key, cfg: ModelConfig, code: str, dtype) -> Params:
    if code in ("A", "W"):
        return attn.attn_params(key, cfg, dtype)
    if code == "L":
        return mla_mod.mla_params(key, cfg, dtype)
    if code == "M":
        return ssm.mamba1_params(key, cfg, dtype)
    if code == "M2":
        return ssm.mamba2_params(key, cfg, dtype)
    raise ValueError(f"unknown mixer code {code!r}")


def _layer_params(key, cfg: ModelConfig, code: str, *, is_lead: bool, dtype) -> Params:
    k_mix, k_mlp = jax.random.split(key)
    p: Params = {
        "norm1": norm_params(cfg, cfg.d_model),
        "mixer": _mixer_params(k_mix, cfg, code, dtype),
    }
    if _layer_has_mlp(cfg, code):
        p["norm2"] = norm_params(cfg, cfg.d_model)
        if _layer_is_moe(cfg, code, is_lead):
            p["moe"] = moe_mod.moe_params(k_mlp, cfg, dtype)
        else:
            p["mlp"] = mlp_params(k_mlp, cfg, cfg.d_model, cfg.d_ff, dtype)
    return p


def _shared_block_params(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": norm_params(cfg, cfg.d_model),
        "attn": attn.attn_params(k1, cfg, dtype),
        "norm2": norm_params(cfg, cfg.d_model),
        "mlp": mlp_params(k2, cfg, cfg.d_model, cfg.d_ff, dtype),
    }


def init(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    plan = make_plan(cfg)
    k_embed, k_lead, k_stack, k_rem, k_shared = split_keys(key, 5)
    params: Params = {"embed": embed_params(k_embed, cfg, dtype)}

    params["lead"] = tuple(
        _layer_params(k, cfg, c, is_lead=True, dtype=dtype)
        for k, c in zip(split_keys(k_lead, max(1, len(plan.lead_codes))), plan.lead_codes)
    )
    if plan.n_periods > 0:
        stack: Dict[str, Any] = {}
        pkeys = split_keys(k_stack, len(plan.period_codes))
        for j, code in enumerate(plan.period_codes):
            per = [
                _layer_params(k, cfg, code, is_lead=False, dtype=dtype)
                for k in split_keys(pkeys[j], plan.n_periods)
            ]
            stack[f"p{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        params["stack"] = stack
    params["rem"] = tuple(
        _layer_params(k, cfg, c, is_lead=False, dtype=dtype)
        for k, c in zip(split_keys(k_rem, max(1, len(plan.rem_codes))), plan.rem_codes)
    )
    if plan.shared_attn:
        params["shared_attn"] = _shared_block_params(k_shared, cfg, dtype)
    params["final_norm"] = norm_params(cfg, cfg.d_model)
    return params


# ----------------------------------------------------------------------------
# Forward (train / prefill)
# ----------------------------------------------------------------------------


def _apply_mixer(cfg, code, p, x, positions, *, ctx, collect_cache=False):
    """Returns (out, cache_or_None)."""
    w = window_for(cfg, code)
    if code in ("A", "W"):
        q, k, v = attn._project_qkv(cfg, p, x)
        q, k = attn._apply_pos(cfg, q, k, positions)
        out = attn.multi_head_attention(q, k, v, causal=True, window=w, ctx=ctx)
        b, s = x.shape[:2]
        out = out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]
        cache = None
        if collect_cache:
            if w > 0 and s > w:
                cache = {"k": k[:, s - w :], "v": v[:, s - w :]}
            else:
                cache = {"k": k, "v": v}
        return out, cache
    if code == "L":
        out = mla_mod.mla_attention(cfg, p, x, positions, ctx=ctx)
        cache = None
        if collect_cache:
            ckv, krope = mla_mod._latents(cfg, p, x, positions)
            cache = {"ckv": ckv, "krope": krope[:, :, 0, :]}
        return out, cache
    if code == "M":
        out = ssm.mamba1_forward(cfg, p, x, ctx=ctx)
        # decode state from prefill: recompute path not needed for dry-run;
        # examples use decode-from-scratch or train only.
        return out, None
    if code == "M2":
        out = ssm.mamba2_forward(cfg, p, x, ctx=ctx)
        return out, None
    raise ValueError(code)


def _apply_layer(cfg, code, p, x, positions, *, is_lead, ctx, collect_cache=False):
    h = apply_norm(cfg, p["norm1"], x)
    mix, cache = _apply_mixer(
        cfg, code, p["mixer"], h, positions, ctx=ctx, collect_cache=collect_cache
    )
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in p:
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
    elif "moe" in p:
        y, aux = moe_mod.apply_moe(cfg, p["moe"], apply_norm(cfg, p["norm2"], x), ctx=ctx)
        x = x + y
    x = ctx.batch_seq_hidden(x)
    return x, aux, cache


def _apply_shared_block(cfg, p, x, positions, *, ctx, collect_cache=False):
    h = apply_norm(cfg, p["norm1"], x)
    out = attn.self_attention(cfg, p["attn"], h, positions, window=0, ctx=ctx)
    cache = None
    if collect_cache:
        q, k, v = attn._project_qkv(cfg, p["attn"], h)
        _, k = attn._apply_pos(cfg, q, k, positions)
        cache = {"k": k, "v": v}
    x = x + out
    x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
    return x, cache


def _positions_for(cfg: ModelConfig, inputs: Dict[str, Any], s: int, b: int):
    if cfg.pos_type == "mrope":
        if "positions3" in inputs:
            return inputs["positions3"]
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        return jnp.broadcast_to(pos, (3, b, s))
    return jnp.broadcast_to(jnp.arange(s), (b, s))


def embed_inputs(cfg: ModelConfig, params: Params, inputs: Dict[str, Any], ctx):
    """Token (+ modality-stub) embedding. Returns (x, positions)."""
    tok = inputs["tokens"]
    x = embed_tokens(params["embed"], tok).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "vlm" and "patch_embeds" in inputs:
        x = jnp.concatenate(
            [inputs["patch_embeds"].astype(x.dtype), x], axis=1
        )
    b, s = x.shape[:2]
    positions = _positions_for(cfg, inputs, s, b)
    x = ctx.batch_seq_hidden(x)
    return x, positions


def forward(
    cfg: ModelConfig,
    params: Params,
    inputs: Dict[str, Any],
    *,
    ctx: ShardCtx = NULL_CTX,
    collect_cache: bool = False,
    remat: bool = True,
    last_only: bool = False,
):
    """Full-sequence forward.

    Returns (logits, aux_loss, caches) — caches is a dict with 'lead'/'stack'/
    'rem'/'shared' entries when collect_cache else None.
    """
    plan = make_plan(cfg)
    x, positions = embed_inputs(cfg, params, inputs, ctx)
    # tie the aux-loss carry's provenance to x so its varying-manual-axes
    # type matches the scan body's output inside shard_map regions
    aux = jnp.float32(0) * x[0, 0, 0].astype(jnp.float32)
    caches: Dict[str, Any] = {"lead": [], "rem": [], "stack": None, "shared": None}

    for p, code in zip(params["lead"], plan.lead_codes):
        x, a, c = _apply_layer(
            cfg, code, p, x, positions, is_lead=True, ctx=ctx, collect_cache=collect_cache
        )
        aux += a
        caches["lead"].append(c)

    if plan.n_periods > 0:
        shared_p = params.get("shared_attn")

        def body(carry, stack_slice):
            x, aux = carry
            period_caches = {}
            for j, code in enumerate(plan.period_codes):
                x, a, c = _apply_layer(
                    cfg, code, stack_slice[f"p{j}"], x, positions,
                    is_lead=False, ctx=ctx, collect_cache=collect_cache,
                )
                aux += a
                if collect_cache:
                    period_caches[f"p{j}"] = c
            if plan.shared_attn:
                x, sc = _apply_shared_block(
                    cfg, shared_p, x, positions, ctx=ctx, collect_cache=collect_cache
                )
                if collect_cache:
                    period_caches["shared"] = sc
            out = period_caches if collect_cache else None
            return (x, aux), out

        if remat:
            body = jax.checkpoint(body)
        (x, aux), stack_caches = jax.lax.scan(body, (x, aux), params["stack"])
        caches["stack"] = stack_caches
    for p, code in zip(params["rem"], plan.rem_codes):
        x, a, c = _apply_layer(
            cfg, code, p, x, positions, is_lead=False, ctx=ctx, collect_cache=collect_cache
        )
        aux += a
        caches["rem"].append(c)

    x = apply_norm(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:, :]
    logits = unembed(params["embed"], x, ctx)
    logits = ctx.constrain(logits, ctx.dp or None, None, "model")
    return logits, aux, (caches if collect_cache else None)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            *, ctx: ShardCtx = NULL_CTX, remat: bool = True):
    logits, aux, _ = forward(cfg, params, batch, ctx=ctx, remat=remat)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab)
    if cfg.n_experts > 0:
        loss = loss + 0.01 * aux
    return loss


# ----------------------------------------------------------------------------
# Decode (serve_step)
# ----------------------------------------------------------------------------


def _mixer_cache_spec(cfg: ModelConfig, code: str, batch: int, max_seq: int, dtype):
    w = window_for(cfg, code)
    if code in ("A", "W"):
        s = min(w, max_seq) if w > 0 else max_seq
        shp = (batch, s, cfg.n_kv, cfg.hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if code == "L":
        return {
            "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora), dtype),
            "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype),
        }
    if code == "M":
        return ssm.mamba1_state_init(cfg, batch, dtype)
    if code == "M2":
        return ssm.mamba2_state_init(cfg, batch, dtype)
    raise ValueError(code)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Cache pytree for decode: one entry per layer (+ shared-attn slots)."""
    plan = make_plan(cfg)
    layers: List[Any] = []
    for code in plan.lead_codes:
        layers.append(_mixer_cache_spec(cfg, code, batch, max_seq, dtype))
    for _ in range(plan.n_periods):
        for code in plan.period_codes:
            layers.append(_mixer_cache_spec(cfg, code, batch, max_seq, dtype))
    for code in plan.rem_codes:
        layers.append(_mixer_cache_spec(cfg, code, batch, max_seq, dtype))
    cache: Dict[str, Any] = {"layers": tuple(layers)}
    if plan.shared_attn:
        shp = (batch, max_seq, cfg.n_kv, cfg.hd)
        cache["shared"] = tuple(
            {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
            for _ in range(plan.n_periods)
        )
    return cache


def _layer_param_at(params: Params, plan: LayerPlan, idx: int) -> Tuple[Params, str, bool]:
    """Layer params + code for flat layer index (decode path)."""
    nl = len(plan.lead_codes)
    if idx < nl:
        return params["lead"][idx], plan.lead_codes[idx], False
    idx -= nl
    per = len(plan.period_codes)
    if idx < plan.n_periods * per:
        i, j = divmod(idx, per)
        p = jax.tree.map(lambda x: x[i], params["stack"][f"p{j}"])
        is_period_end = j == per - 1
        return p, plan.period_codes[j], is_period_end
    idx -= plan.n_periods * per
    return params["rem"][idx], plan.rem_codes[idx], False


def _decode_mixer(cfg, code, p, x1, cache, pos):
    w = window_for(cfg, code)
    if code in ("A", "W"):
        out, nk, nv = attn.self_attention_decode(
            cfg, p, x1, cache["k"], cache["v"], pos,
            window=w if (w > 0 and cache["k"].shape[1] == w) else 0,
        )
        return out, {"k": nk, "v": nv}
    if code == "L":
        out, nckv, nkrope = mla_mod.mla_decode(
            cfg, p, x1, cache["ckv"], cache["krope"], pos
        )
        return out, {"ckv": nckv, "krope": nkrope}
    if code == "M":
        return ssm.mamba1_decode(cfg, p, x1, cache)
    if code == "M2":
        return ssm.mamba2_decode(cfg, p, x1, cache)
    raise ValueError(code)


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Dict[str, Any],
    token,
    pos,
    *,
    ctx: ShardCtx = NULL_CTX,
):
    """One decode step. token: (B,) int32; pos: scalar int32 position.

    Returns (logits (B, vocab_padded), new_cache).
    """
    plan = make_plan(cfg)
    x = embed_tokens(params["embed"], token[:, None]).astype(jnp.dtype(cfg.dtype))
    x = ctx.batch_only(x)
    new_layers = []
    new_shared = list(cache.get("shared", ()))
    per = len(plan.period_codes)
    n_lead = len(plan.lead_codes)
    for idx in range(plan.n_layers):
        p, code, period_end = _layer_param_at(params, plan, idx)
        h = apply_norm(cfg, p["norm1"], x)
        mix, nc = _decode_mixer(cfg, code, p["mixer"], h, cache["layers"][idx], pos)
        new_layers.append(nc)
        x = x + mix
        if "mlp" in p:
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        elif "moe" in p:
            y, _ = moe_mod.apply_moe(cfg, p["moe"], apply_norm(cfg, p["norm2"], x), ctx=ctx)
            x = x + y
        if plan.shared_attn and period_end and idx >= n_lead:
            app_i = (idx - n_lead) // per
            if app_i < len(new_shared):
                sp = params["shared_attn"]
                h = apply_norm(cfg, sp["norm1"], x)
                out, nk, nv = attn.self_attention_decode(
                    cfg, sp["attn"], h,
                    new_shared[app_i]["k"], new_shared[app_i]["v"], pos, window=0,
                )
                new_shared[app_i] = {"k": nk, "v": nv}
                x = x + out
                x = x + apply_mlp(cfg, sp["mlp"], apply_norm(cfg, sp["norm2"], x))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x, ctx)[:, 0]
    new_cache = {"layers": tuple(new_layers)}
    if plan.shared_attn:
        new_cache["shared"] = tuple(new_shared)
    return logits, new_cache
