"""Closed-loop loss-budget controller (DESIGN.md §14).

The paper's Early Close rule "adjusts the loss-tolerant threshold based
on network conditions"; ``BudgetController`` closes that loop at the
runtime level. On a fixed observation grid (``Sim.every``) it reads
three signals from the run itself:

* fabric distress — new netfault / blackhole / flow-dead telemetry
  since the last tick (the network fault plane's event stream);
* per-round Early-Close behavior — new ``early_close`` records: the
  delivered fraction AND the close latency. Latency is the primary
  degradation signal: a straggling rack makes rounds close *late* while
  the delivered fraction actually climbs (a longer round lands more
  bytes), so "delivered looks fine" must never be read as health on its
  own. The controller learns its own healthy-latency baseline (EWMA
  over calm ticks) and flags distress when recent closes run
  ``late_mult`` over it;
* training-loss trend — the tail of the runtime history (accuracy
  guardrail).

and moves each PS shard's effective Early-Close pct threshold
(``DESTransport.set_pct_threshold``) by ``step`` per tick inside the
``[floor, ceiling]`` guardrail band:

  loss rising      -> narrow (raise the threshold toward the ceiling:
                      accuracy wins over speed, even under distress);
  fabric distress  -> widen (lower the threshold toward the floor: keep
                      rounds closing instead of chasing bytes a flapping
                      fabric will not deliver). Distress is *sustained*,
                      not edge-triggered: new fault telemetry counts,
                      and so does any window of Early-Close rounds that
                      delivered less than the baseline ceiling OR closed
                      ``late_mult`` over the learned healthy latency —
                      so the budget stays wide for as long as the fabric
                      under-delivers or drags, not just for the tick the
                      fault fired on;
  round stalled    -> hold (no Early Close for longer than a full close
                      window: the round is gated by criticals or a
                      blackholed path, which no pct threshold can buy
                      back — but silence is not health, so the budget
                      does not narrow back mid-outage);
  healthy for
  ``patience`` ticks -> narrow back toward the configured baseline.

The ceiling defaults to the configured ``data_pct_threshold`` (the
controller never demands more than the config did); the floor is the
accuracy guardrail. Every actuation is recorded as a ``budget``
telemetry event, so runs are auditable and the chaos tests can pin the
controller's trajectory. A runtime constructed without a controller
never touches any of this (zero-fault parity).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

#: telemetry kinds that signal fabric distress on the observation grid
_DISTRESS_KINDS = ("netfault", "blackhole", "flow_dead")


class BudgetController:
    """One instance per runtime; ``bind`` wires it, ``tick`` observes
    and actuates. Pure deterministic arithmetic over the telemetry
    stream — no RNG, no wall clock (replayable by construction)."""

    def __init__(self, *, floor: float = 0.55,
                 ceiling: Optional[float] = None, step: float = 0.05,
                 interval_s: float = 0.05, patience: int = 3,
                 loss_window: int = 6, late_mult: float = 1.3):
        if not 0.0 < floor <= 1.0:
            raise ValueError(f"floor must be in (0, 1], got {floor}")
        if step <= 0:
            raise ValueError(f"step must be > 0, got {step}")
        if late_mult <= 1.0:
            raise ValueError(f"late_mult must be > 1, got {late_mult}")
        self.floor = float(floor)
        self.ceiling = ceiling            # None -> configured threshold
        self.step = float(step)
        self.interval_s = float(interval_s)
        self.patience = int(patience)
        self.loss_window = int(loss_window)
        self.late_mult = float(late_mult)
        self.rt = None
        self.pct: List[float] = []
        self._ceil: List[float] = []
        self._healthy = 0
        self._seen = {k: 0 for k in _DISTRESS_KINDS}
        self._n_closes = 0
        self._lat_ewma: Optional[float] = None
        self.n_widen = 0
        self.n_narrow = 0

    def bind(self, rt) -> None:
        """Attach to a runtime (its DES transport is the actuator)."""
        if rt.net_des is None:
            raise ValueError(
                "BudgetController needs transport='des' — the analytic "
                "transport has no per-shard Early-Close receivers to "
                "actuate")
        self.rt = rt
        self.pct = list(rt.net_des.pct_eff)
        self._ceil = ([float(self.ceiling)] * len(self.pct)
                      if self.ceiling is not None else list(self.pct))
        # a round is "stalled" once no Early Close has landed for longer
        # than a full close window (LT + deadline) plus a few observation
        # ticks of slack — generous enough that a healthy cadence (close
        # gaps ~ compute + LT, with ticks coarser than rounds) can never
        # read as a stall
        self._stall_after = (rt.net_des.lt_shard
                             + rt.net_des.deadline_shard
                             + 3.0 * self.interval_s)
        self._t_last_close = rt.sim.now

    # -- observation ---------------------------------------------------------
    def _distressed(self) -> bool:
        tel = self.rt.tel
        hit = False
        for kind in _DISTRESS_KINDS:
            n = tel._count(kind)
            if n > self._seen[kind]:
                hit = True
            self._seen[kind] = n
        return hit

    def _loss_rising(self) -> bool:
        hist = self.rt.history
        w = self.loss_window
        if len(hist) < w:
            return False
        tail = [float(r["loss"]) for r in hist[-w:]]
        half = w // 2
        return float(np.mean(tail[half:])) > float(np.mean(tail[:half]))

    def _observe(self):
        """Consume Early-Close records landed since the last tick and
        fold them into (mean delivered, mean close latency) — or
        ``(None, None)`` when no round closed. Also advances the stall
        clock."""
        closes = self.rt.tel.of("early_close")
        new = closes[self._n_closes:]
        self._n_closes = len(closes)
        if not new:
            return None, None
        self._t_last_close = float(new[-1]["t"])
        d = float(np.mean([e["delivered"] for e in new]))
        lats = [float(e["lat"]) for e in new if e.get("lat")]
        lat = float(np.mean(lats)) if lats else None
        return d, lat

    def _delivered_low(self, delivered: Optional[float]) -> bool:
        """Recent Early-Close rounds delivering under the *baseline*
        ceiling mean the fabric is carrying less than the config asked
        for — stragglers or a browned-out link are pinning the aggregate
        pct below the configured threshold. Comparing against the
        ceiling (not the already-widened ``self.pct``) is what holds the
        budget wide for the whole degraded window: a widened threshold
        closes rounds at exactly its own pct, which would read as
        "healthy" under a self-referential test and narrow the budget
        back mid-fault (hysteresis, DESIGN.md §14)."""
        return (delivered is not None
                and delivered < min(self._ceil) - 1e-9)

    def _late(self, lat: Optional[float]) -> bool:
        """Recent closes ran ``late_mult`` over the learned healthy
        latency. This is the signal that survives the delivered-fraction
        paradox: a straggling rack makes rounds run *longer*, which
        lands *more* bytes per round — delivered climbs while the round
        cadence degrades. Latency only ever moves the wrong way under
        degradation, so it is the primary distress predicate. No
        baseline yet (or async closes without latency) -> no opinion."""
        return (lat is not None and self._lat_ewma is not None
                and lat > self.late_mult * self._lat_ewma)

    def _stalled(self) -> bool:
        """No Early Close for longer than a full close window: the open
        round is gated by something the pct threshold cannot buy back
        (missing criticals, a blackholed rack in RTO backoff). Neither
        healthy nor actuatable — the controller holds its position
        instead of narrowing back mid-outage."""
        return self.rt.sim.now - self._t_last_close > self._stall_after

    # -- control law ---------------------------------------------------------
    def tick(self) -> None:
        delivered, lat = self._observe()
        distress = (self._distressed() or self._delivered_low(delivered)
                    or self._late(lat))
        if self._loss_rising():
            self._move(+self.step)        # accuracy guardrail wins
            self._healthy = 0
        elif distress:
            self._move(-self.step)
            self._healthy = 0
        elif self._stalled():
            self._healthy = 0             # hold: not healthy, not closable
        else:
            self._healthy += 1
            # the healthy-latency baseline learns only from calm ticks,
            # so a long brownout can never drag it up toward "late is
            # the new normal"
            if lat is not None:
                self._lat_ewma = (lat if self._lat_ewma is None
                                  else 0.8 * self._lat_ewma + 0.2 * lat)
            if self._healthy >= self.patience:
                self._move(+self.step)

    def _move(self, delta: float) -> None:
        rt = self.rt
        moved = False
        for p in range(len(self.pct)):
            new = float(np.clip(self.pct[p] + delta, self.floor,
                                self._ceil[p]))
            if new != self.pct[p]:
                self.pct[p] = new
                rt.net_des.set_pct_threshold(p, new)
                rt.tel.record("budget", rt.sim.now, shard=p, pct=new,
                              direction="widen" if delta < 0 else "narrow")
                moved = True
        if moved:
            if delta < 0:
                self.n_widen += 1
            else:
                self.n_narrow += 1
