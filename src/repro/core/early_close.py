"""Early Close (paper §III-B): the double time-threshold controller.

Host-side control loop (numpy): per-link LT thresholds, the global
deadline, and the per-iteration close decision. Transport timing comes
from a pluggable gather model — either the fast analytic incast model
below (training loops) or samples from the packet-level DES in
``repro.net`` (protocol benchmarks).

Definitions (paper):
  ECT            = RTprop + ModelSize/BtlBw
  LT_init        = 1.5 * RTprop + ModelSize/BtlBw      (first batch of epoch)
  LT update      = shortest observed 100%-delivery time this epoch, per link
  deadline       = max(LT thresholds) + C   (C = 30 ms DCN / 100 ms WAN)
  close rule     : t < LT       -> wait for all data
                   LT <= t < DL -> close when received pct >= threshold
                   t >= DL      -> close unconditionally

Beyond-paper extensions (DESIGN.md §3.3, §5):
  * phase-aware threshold — the received-pct threshold ramps with training
    progress (``LTPConfig.phase_final_pct_threshold``): early iterations
    tolerate more loss, late iterations less.
  * ``MultiPSEarlyClose`` — one independent controller per PS shard; an
    iteration's close time is the slowest shard's close.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.config import LTPConfig, NetConfig


@dataclasses.dataclass
class GatherSample:
    """One iteration's transport outcome for W workers."""

    completion_times: np.ndarray   # (W,) time for 100% of this worker's data
    first_arrival: np.ndarray      # (W,) time of first payload byte


class AnalyticIncastModel:
    """Fast closed-form stand-in for the DES (calibrated against it —
    compare against ``benchmarks/fig3_incast_fct.py`` output; DESIGN.md §1).

    Captures the two phenomena the paper measures:
      * incast long tail (Fig 3): most flows finish near the fair-share
        time; a few "starved" flows are inflated by a heavy-tail factor.
      * non-congestion loss (Fig 4): loss-recovery inflation for
        order-preserving TCP (cwnd collapse), mild inflation for
        BBR/LTP-style BDP control.
    """

    def __init__(self, net: NetConfig, n_workers: int, *, protocol: str = "ltp",
                 tail_prob: float = 0.15, tail_scale: float = 1.5, seed: int = 0):
        self.net = net
        self.w = n_workers
        self.protocol = protocol
        self.tail_prob = tail_prob
        self.tail_scale = tail_scale
        self.rng = np.random.default_rng(seed)

    def loss_inflation(self) -> float:
        """Goodput divisor under random loss p (per-protocol, per Fig 4)."""
        p = self.net.loss_rate
        bdp_pkts = (
            self.net.bandwidth_gbps * 1e9 / 8 * self.net.rtprop_ms * 1e-3 / 1500.0
        )
        if self.protocol in ("ltp", "bbr"):
            # BDP probing: goodput ~ (1-p) with small probe overhead
            return 1.0 / max(1e-6, (1.0 - p) ** 2)
        # Reno/Cubic-like: throughput ~ MSS/(RTT*sqrt(2p/3)) capped at fair share
        if p <= 0:
            return 1.0
        loss_limited = 1.0 / (self.net.rtprop_ms * 1e-3) * np.sqrt(1.5 / p)
        fair_share = self.net.bandwidth_gbps * 1e9 / 8 / 1500.0 / self.w
        return max(1.0, fair_share / max(loss_limited, 1e-9))

    def sample(self, model_bytes: float) -> GatherSample:
        bw = self.net.bandwidth_gbps * 1e9 / 8  # B/s shared bottleneck
        rt = self.net.rtprop_ms * 1e-3
        base = model_bytes * self.w / bw + rt  # serialized incast drain time
        infl = self.loss_inflation()
        tails = np.where(
            self.rng.random(self.w) < self.tail_prob,
            self.rng.exponential(self.tail_scale, self.w),
            0.0,
        )
        # order-preserving protocols additionally stall on per-loss RTOs
        if self.protocol in ("reno", "cubic") and self.net.loss_rate > 0:
            n_pkts = model_bytes / 1500.0
            rto_stalls = self.rng.binomial(
                int(max(1, n_pkts * self.net.loss_rate * 0.05)), 0.5, self.w
            ) * (4 * rt)
        else:
            rto_stalls = np.zeros(self.w)
        completion = base * infl * (1.0 + tails) + rto_stalls
        return GatherSample(
            completion_times=completion,
            first_arrival=np.full(self.w, rt),
        )


def phase_pct_threshold(ltp: LTPConfig, progress: float) -> float:
    """Effective Early-Close received-pct threshold at training progress
    in [0, 1]. Linear ramp from ``data_pct_threshold`` toward
    ``phase_final_pct_threshold`` (identity when the latter is None)."""
    base = ltp.data_pct_threshold
    final = ltp.phase_final_pct_threshold
    if final is None:
        return base
    p = min(max(float(progress), 0.0), 1.0)
    return base + (final - base) * p


class EarlyCloseController:
    """Maintains LT thresholds + deadline; decides close time & delivered
    fractions each iteration (gathering direction only, §III-B-2)."""

    def __init__(self, ltp: LTPConfig, net: NetConfig, n_workers: int,
                 model_bytes: float):
        self.ltp = ltp
        self.net = net
        self.w = n_workers
        self.model_bytes = float(model_bytes)
        rt = net.rtprop_ms * 1e-3
        btlbw = net.bandwidth_gbps * 1e9 / 8
        per_worker_share = btlbw / n_workers
        init = ltp.lt_init_rtprop_mult * rt + self.model_bytes / per_worker_share
        self.lt = np.full(n_workers, init)          # per-link LT threshold
        self.best_full = np.full(n_workers, np.inf)  # best 100% time this epoch
        self.iter_in_epoch = 0
        self.progress = 0.0   # training progress in [0,1] (phase-aware ramp)

    def set_progress(self, progress: float) -> None:
        """Feed training progress for the phase-aware threshold ramp."""
        self.progress = float(progress)

    @property
    def pct_threshold(self) -> float:
        return phase_pct_threshold(self.ltp, self.progress)

    @property
    def deadline(self) -> float:
        return float(self.lt.max() + self.ltp.deadline_c_ms * 1e-3)

    def new_epoch(self) -> None:
        """LT <- shortest 100%-delivery time observed last epoch (paper)."""
        upd = np.isfinite(self.best_full)
        self.lt[upd] = self.best_full[upd]
        self.best_full[:] = np.inf
        self.iter_in_epoch = 0

    def step(self, sample: GatherSample) -> Tuple[float, np.ndarray]:
        """Returns (close_time a.k.a. gather BST, delivered_frac (W,)).

        Worker w's packets arrive ~uniformly over
        [first_arrival_w, completion_w] (out-of-order transmission has no
        head-of-line ordering), so pct(t) is linear in t.
        """
        t_full = sample.completion_times
        t0 = sample.first_arrival
        lt = float(self.lt.max())
        dl = self.deadline

        def pct(t: float) -> np.ndarray:
            return np.clip((t - t0) / np.maximum(t_full - t0, 1e-12), 0.0, 1.0)

        if float(t_full.max()) <= lt:
            close = float(t_full.max())      # all data before LT: no loss
        else:
            # earliest t in [lt, dl] with mean received pct >= threshold;
            # pct is piecewise-linear & monotone -> bisect
            target = self.pct_threshold
            if pct(dl).mean() < target:
                close = dl                    # deadline wins
            elif pct(lt).mean() >= target:
                close = lt
            else:
                lo, hi = lt, dl
                for _ in range(40):
                    mid = 0.5 * (lo + hi)
                    if pct(mid).mean() >= target:
                        hi = mid
                    else:
                        lo = mid
                close = hi
        frac = pct(close)
        # record best 100% times for the epoch update
        done = t_full <= close
        self.best_full[done] = np.minimum(self.best_full[done], t_full[done])
        self.iter_in_epoch += 1
        return close, frac


class MultiPSEarlyClose:
    """Per-shard Early Close for multi-PS deployments (DESIGN.md §5).

    One independent ``EarlyCloseController`` per PS shard, each over
    ``model_bytes / n_ps``; the iteration's gather BST is the slowest
    shard's close, and a worker's delivered fraction is the mean over its
    shard flows. The single-controller interface is preserved so the
    trainer treats n_ps=1 and n_ps>1 uniformly.
    """

    def __init__(self, ltp: LTPConfig, net: NetConfig, n_workers: int,
                 model_bytes: float, n_ps: int = 1):
        if n_ps < 1:
            raise ValueError("n_ps must be >= 1")
        self.n_ps = n_ps
        self.controllers = [
            EarlyCloseController(ltp, net, n_workers, model_bytes / n_ps)
            for _ in range(n_ps)
        ]

    @property
    def deadline(self) -> float:
        return max(c.deadline for c in self.controllers)

    def set_progress(self, progress: float) -> None:
        for c in self.controllers:
            c.set_progress(progress)

    def new_epoch(self) -> None:
        for c in self.controllers:
            c.new_epoch()

    def step(self, samples: Sequence[GatherSample]) -> Tuple[float, np.ndarray]:
        """``samples``: one GatherSample per shard. Returns
        (close = max over shards, delivered frac = mean over shards)."""
        if len(samples) != self.n_ps:
            raise ValueError(
                f"expected {self.n_ps} shard samples, got {len(samples)}")
        closes, fracs = [], []
        for c, s in zip(self.controllers, samples):
            close, frac = c.step(s)
            closes.append(close)
            fracs.append(frac)
        return float(max(closes)), np.mean(fracs, axis=0)


def broadcast_time(net: NetConfig, model_bytes: float, n_ps: int = 1) -> float:
    """Reliable one-to-many broadcast (no Early Close, §III-B-2). With
    n_ps shards each PS broadcasts its 1/n_ps of the model over its own
    trunk, in parallel."""
    bw = net.bandwidth_gbps * 1e9 / 8
    rt = net.rtprop_ms * 1e-3
    # PS egress serializes the model once per worker on the shared trunk
    return rt + model_bytes / n_ps / bw
