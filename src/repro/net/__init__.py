"""Packet-level discrete-event transport simulator.

Reproduces the paper's protocol-level experiments at packet granularity:
Fig 3 (incast FCT long tail), Fig 4 (TCP under non-congestion loss),
Fig 12/14 (training throughput / BST), Fig 15 (fairness) — plus the
composable topology engine behind the multi-PS / straggler / cross-traffic
scenarios (DESIGN.md §5). Run any scenario by name via ``run_scenario``.
"""
from repro.net.simcore import (  # noqa: F401
    CrossTrafficSource,
    Packet,
    Pipe,
    Route,
    Sim,
    Topology,
)
from repro.net.scenarios import (  # noqa: F401
    PROTOCOLS,
    SCENARIOS,
    GatherSpec,
    cross_traffic,
    fairness_share,
    incast_gather,
    list_scenarios,
    multi_ps_gather,
    p2p_transfer,
    run_scenario,
    straggler_gather,
    train_iterations,
)
