"""State-space mixers: Mamba-1 (selective scan) and Mamba-2 (chunked SSD).

Mamba-1 (falcon-mamba): per-channel diagonal A (d_inner, state); the
recurrence runs as a ``lax.scan`` over time with a (B, d_inner, state)
carry — tiny state, static trip count.

Mamba-2 (zamba2): scalar decay per head -> the SSD block-matmul form.
Sequence is chunked; within-chunk terms are MXU-friendly matmuls, the
chunk-to-chunk state is a scan carry. This is the TPU-native adaptation:
quadratic-within-chunk work maps onto the MXU, state passing is O(S/Lc).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import Params, dense_init, rms_norm, split_keys
from repro.models.sharding import ShardCtx, NULL_CTX


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def _causal_conv(x, conv_w, conv_b):
    """x: (B, S, C); conv_w: (k, C) tap-major; causal depthwise conv."""
    k = conv_w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = x if shift == 0 else jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * conv_w[i]
    return out + conv_b


def _conv_step(buf, x1, conv_w, conv_b):
    """One-token causal conv. buf: (B, k-1, C) previous inputs; x1: (B, C).
    Returns (y1, new_buf)."""
    k = conv_w.shape[0]
    window = jnp.concatenate([buf, x1[:, None, :]], axis=1)  # (B, k, C)
    y1 = jnp.einsum("bkc,kc->bc", window, conv_w) + conv_b
    return y1, window[:, 1:]


# ============================================================================
# Mamba-1
# ============================================================================


def mamba1_params(key, cfg: ModelConfig, dtype) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    r = _dt_rank(cfg)
    ks = split_keys(key, 5)
    A = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, r + 2 * n, dtype),
        "dt_proj": dense_init(ks[3], r, di, dtype, scale=r**-0.5),
        "dt_bias": jnp.full((di,), math.log(math.e**0.01 - 1.0), jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _mamba1_inputs(cfg: ModelConfig, p: Params, u):
    """Shared projection path. u: (B, S, d). Returns x, z, dt, Bc, Cc."""
    n, r = cfg.ssm_state, _dt_rank(cfg)
    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    x = jax.nn.silu(_causal_conv(x, p["conv_w"], p["conv_b"]))
    xdbl = x @ p["x_proj"]
    dt = jax.nn.softplus(
        (xdbl[..., :r] @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )
    Bc = xdbl[..., r : r + n].astype(jnp.float32)
    Cc = xdbl[..., r + n :].astype(jnp.float32)
    return x, z, dt, Bc, Cc


def mamba1_forward(cfg: ModelConfig, p: Params, u, *, ctx: ShardCtx = NULL_CTX):
    """Full-sequence selective scan. u: (B, S, d) -> (B, S, d)."""
    b, s, _ = u.shape
    x, z, dt, Bc, Cc = _mamba1_inputs(cfg, p, u)
    A = -jnp.exp(p["A_log"])  # (di, n)
    xf = x.astype(jnp.float32)

    def step(h, ins):
        xt, dtt, bt, ct = ins  # (B,di), (B,di), (B,n), (B,n)
        da = jnp.exp(dtt[..., None] * A)  # (B,di,n)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    # tie h0's provenance to the input so its varying-manual-axes type
    # matches the scan body output inside shard_map regions
    h0 = jnp.zeros((b, cfg.d_inner, cfg.ssm_state), jnp.float32) \
        + 0.0 * xf[0, 0, 0]
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bc, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * p["D"]
    y = (y.astype(u.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba1_decode(cfg: ModelConfig, p: Params, u1, state):
    """One-token update. u1: (B, 1, d); state = {"h": (B,di,n),
    "conv": (B, k-1, di)}. Returns (out, new_state)."""
    n, r = cfg.ssm_state, _dt_rank(cfg)
    xz = u1[:, 0] @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    xc, conv_buf = _conv_step(state["conv"], x, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    xdbl = xc @ p["x_proj"]
    dt = jax.nn.softplus(
        (xdbl[..., :r] @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )
    Bc = xdbl[..., r : r + n].astype(jnp.float32)
    Cc = xdbl[..., r + n :].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt[..., None] * A)
    h = da * state["h"] + (dt * xc.astype(jnp.float32))[..., None] * Bc[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cc) + xc.astype(jnp.float32) * p["D"]
    y = y.astype(u1.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"h": h, "conv": conv_buf}


def mamba1_state_init(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
    }


# ============================================================================
# Mamba-2 (SSD)
# ============================================================================


def mamba2_params(key, cfg: ModelConfig, dtype) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    ks = split_keys(key, 3)
    conv_ch = di + 2 * n  # conv over (x, B, C)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log_m2": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gamma": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _mamba2_split(cfg: ModelConfig, proj):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di : 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n :]
    return z, xBC, dt


def mamba2_forward(cfg: ModelConfig, p: Params, u, *, chunk: int = 128,
                   ctx: ShardCtx = NULL_CTX):
    """Chunked SSD. u: (B, S, d) -> (B, S, d)."""
    b, s, _ = u.shape
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = di // nh  # head dim
    lc = chunk
    while s % lc != 0:
        lc //= 2
    nchunks = s // lc

    proj = u @ p["in_proj"]
    z, xBC, dt = _mamba2_split(cfg, proj)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    x = xBC[..., :di].reshape(b, s, nh, hp)
    Bc = xBC[..., di : di + n].astype(jnp.float32)
    Cc = xBC[..., di + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log_m2"])  # (nh,)
    la = dt * A  # log decay per step (B,S,nh), <= 0

    xr = x.reshape(b, nchunks, lc, nh, hp).astype(jnp.float32)
    br = Bc.reshape(b, nchunks, lc, n)
    cr = Cc.reshape(b, nchunks, lc, n)
    lar = la.reshape(b, nchunks, lc, nh)
    dtr = dt.reshape(b, nchunks, lc, nh)

    def chunk_step(hstate, ins):
        xc, bc, cc, lac, dtc = ins  # (B,lc,nh,hp),(B,lc,n),(B,lc,n),(B,lc,nh),(B,lc,nh)
        cs = jnp.cumsum(lac, axis=1)  # (B,lc,nh)
        # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j (incl. own-step decay)
        L = jnp.exp(
            jnp.where(
                (jnp.arange(lc)[:, None] >= jnp.arange(lc)[None, :])[None, :, :, None],
                cs[:, :, None, :] - cs[:, None, :, :],
                -jnp.inf,
            )
        )  # (B,lc,lc,nh)
        sb = jnp.einsum("bin,bjn->bij", cc, bc)  # (B,lc,lc) shared across heads
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", sb, L, xc * dtc[..., None])
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cs)  # decay from chunk start to step i
        y_inter = jnp.einsum(
            "bin,bhpn,bih->bihp", cc, hstate, decay_in
        )
        # new state: h' = exp(sum la) h + sum_j exp(cs_end - cs_j) dt_j x_j B_j^T
        tot = cs[:, -1:, :]  # (B,1,nh)
        w = jnp.exp(tot - cs)  # (B,lc,nh) decay from step j to chunk end
        h_new = jnp.exp(tot[:, 0, :])[:, :, None, None] * hstate + jnp.einsum(
            "bjhp,bjn,bjh->bhpn", xc * dtc[..., None], bc, w
        )
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, nh, hp, n), jnp.float32) + 0.0 * xr[0, 0, 0, 0, 0]
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xr, br, cr, lar, dtr))
    _, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, hp)
    y = y + xr.reshape(b, s, nh, hp) * p["D"][:, None]
    y = y.reshape(b, s, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gamma"] - 1.0, cfg.norm_eps)
    return y @ p["out_proj"]


def mamba2_decode(cfg: ModelConfig, p: Params, u1, state):
    """One-token SSD update. state = {"h": (B,nh,hp,n), "conv": (B,k-1,conv_ch)}."""
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = di // nh
    proj = u1[:, 0] @ p["in_proj"]
    z, xBC, dt = _mamba2_split(cfg, proj)
    xBC, conv_buf = _conv_step(state["conv"], xBC, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    x = xBC[..., :di].reshape(-1, nh, hp).astype(jnp.float32)
    Bc = xBC[..., di : di + n].astype(jnp.float32)
    Cc = xBC[..., di + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    a = jnp.exp(dt * -jnp.exp(p["A_log_m2"]))  # (B,nh)
    h = a[..., None, None] * state["h"] + jnp.einsum(
        "bhp,bn,bh->bhpn", x, Bc, dt
    )
    y = jnp.einsum("bhpn,bn->bhp", h, Cc) + x * p["D"][:, None]
    y = y.reshape(-1, di).astype(u1.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gamma"] - 1.0, cfg.norm_eps)
    return (y @ p["out_proj"])[:, None, :], {"h": h, "conv": conv_buf}


def mamba2_state_init(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "h": jnp.zeros((batch, nh, di // nh, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
    }
