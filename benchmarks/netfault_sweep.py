"""Network-fault sweep: training cost of fabric chaos and what the
closed-loop loss-budget controller buys back (DESIGN.md §14).

The headline scenario is a 16-worker packet-level DES run under link
flaps + one aggregation-switch crash + one rack partition, measured
three ways on the SAME drawn schedule and seeds: fault-free twin,
faulted with the budget controller, faulted without it. Metrics:

* ``netfault_recovery_s``   — time from the first injected fault until
                              commits resume at pre-fault cadence
                              (controller on; absolute ceiling in
                              ``check_regression``);
* ``netfault_goodput_ratio``— faulted steps/sim-second over the clean
                              twin's (controller on; 1.0 = chaos cost
                              nothing);
* ``netfault_final_loss_ratio`` — faulted final loss / clean final loss
                              (controller on; ceiling-gated at 1.10 —
                              fabric chaos that silently costs more
                              than 10% of final loss is a regression);
* the same three with the ``_off`` suffix for the controller-off twin,
  so the controller's contribution stays measured, not asserted.

Every cell is seeded end to end; records are machine-independent and
bitwise reproducible.

  PYTHONPATH=src python -m benchmarks.netfault_sweep --quick
  PYTHONPATH=src python -m benchmarks.run --only netfault_sweep
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.config import LTPConfig, NetConfig, RuntimeConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.net.topology import rack_spine
from repro.optim import make_optimizer
from repro.runtime import (
    BudgetController,
    ClusterRuntime,
    LinkFaultEvent,
    LinkFaultSchedule,
)

from benchmarks.common import emit
from benchmarks.sweep_scenarios import write_bench

NET = NetConfig(10, 1, 0.001, 4096)
W = 16
RACKS = 4

#: the des16 fabric-chaos scenario: a flap storm on one uplink (the
#: plane reroutes it over the spare spine path), an aggregation-switch
#: crash, one rack partition (survived via blackhole detection), then a
#: mild brownout of one rack uplink — the controller's showcase: the
#: rack's flows straggle in lockstep behind in-network aggregation,
#: holding the aggregate delivered pct near the configured 0.8
#: threshold, so controller-off rounds intermittently wait out the pct
#: rule into the deadline window while the controller widens below the
#: straggler plateau and keeps rounds closing at healthy-flow latency
#: (DESIGN.md §14).
DES16_NETFAULTS = LinkFaultSchedule([
    LinkFaultEvent(0.04, "link_flap", "rack2/up", period_s=0.02,
                   duty=0.5, duration_s=0.08),
    LinkFaultEvent(0.09, "switch_crash", "rack1", recover_s=0.05),
    LinkFaultEvent(0.13, "partition", "rack3", recover_s=0.08),
    # the brownout starts only after the partitioned rack's senders have
    # worked back out of RTO backoff (~0.36 s), so the two recovery
    # phases stay separable in the apply cadence
    # 10 Gbps uplink -> 50 Mbps: deep enough that the rack's lockstep
    # delivered fraction crawls (holding the aggregate pct under 0.8
    # into the deadline window on unlucky rounds), shallow enough that
    # its critical packets — sent first via CQ — still land promptly,
    # so the pct rule (not critical completeness) is what gates closes
    LinkFaultEvent(0.45, "link_degrade", "rack2/up", rate_factor=5e-3,
                   recover_s=0.30),
])


def _recovery_s(rt) -> float:
    """Sim-seconds from the first injected fault until commits are
    *done* stalling: the end of the last inter-apply gap that exceeded
    1.5x the pre-fault median cadence. Scanning for the last slow gap
    (not the first recovered one) is deliberate — a run that limps
    through a brownout at half cadence has not recovered just because
    one early gap happened to look normal."""
    applies = [e["t"] for e in rt.tel.of("apply")]
    nf = rt.tel.of("netfault")
    if not nf or len(applies) < 3:
        return 0.0
    t0 = nf[0]["t"]
    pre = [t for t in applies if t <= t0]
    post = [t for t in applies if t > t0]
    if len(pre) >= 3:
        cadence = float(np.median(np.diff(pre)))
    else:
        cadence = float(np.median(np.diff(applies)))
    recovered = t0
    prev = pre[-1] if pre else t0
    for t in post:
        if t - prev > 1.5 * cadence:
            recovered = t
        prev = t
    return round(max(recovered - t0, 0.0), 4)


def _cell(api, tc, steps, *, net_faults=None, budget=False, seed=11):
    rt = ClusterRuntime(
        api, make_optimizer(tc), tc, LTPConfig(), NET,
        n_workers=W, protocol="ltp", policy="bsp", compute_time=0.01,
        seed=seed, transport="des",
        topology=rack_spine(RACKS, W // RACKS, n_ps=2),
        net_faults=net_faults,
        budget=BudgetController(interval_s=0.02) if budget else None,
        runtime_cfg=RuntimeConfig(staleness_comp=0.5))
    t0 = time.time()
    rt.run(batches(SyntheticCIFAR(seed=3), tc.batch, steps))
    wall = time.time() - t0
    s = rt.tel.summary()
    return rt, {
        "scenario": "netfault_des16", "policy": "bsp", "transport": "des",
        "budget": bool(budget),
        "n_netfaults": s.get("n_netfaults", 0),
        "n_flow_dead": s.get("n_flow_dead", 0),
        "n_reroutes": s.get("n_reroutes", 0),
        "n_blackholes": s.get("n_blackholes", 0),
        "n_budget_moves": s.get("n_budget_moves", 0),
        "recovery_s": _recovery_s(rt),
        "simtime_s": round(rt.sim_time, 4),
        "goodput_steps_per_s": round(len(rt.history) / rt.sim_time, 3),
        "final_loss": round(float(rt.history[-1]["loss"]), 6),
        "n_steps_done": len(rt.history),
        "wall_s": round(wall, 2),
    }


def run(quick: bool = True):
    steps = 40 if quick else 56
    cfg = get_config("papernet").replace(d_model=8, n_layers=3)
    api = build(cfg)
    tc = TrainConfig(batch=4 * W, lr=0.05, steps=steps)
    rows = []
    metrics = {}
    t_start = time.time()

    _, clean = _cell(api, tc, steps)
    clean["scenario"] = "netfault_des16_free"
    rows.append(clean)

    rt_on, on = _cell(api, tc, steps, net_faults=DES16_NETFAULTS,
                      budget=True)
    rows.append(on)
    _, off = _cell(api, tc, steps, net_faults=DES16_NETFAULTS,
                   budget=False)
    off["scenario"] = "netfault_des16_nobudget"
    rows.append(off)

    for row, suffix in ((on, ""), (off, "_off")):
        assert row["n_steps_done"] == steps, \
            f"faulted des16 run ({suffix or 'budget'}) did not complete"
        metrics[f"netfault_recovery_s{suffix}"] = row["recovery_s"]
        metrics[f"netfault_goodput_ratio{suffix}"] = round(
            row["goodput_steps_per_s"] / clean["goodput_steps_per_s"], 4)
        metrics[f"netfault_final_loss_ratio{suffix}"] = round(
            row["final_loss"] / clean["final_loss"], 4)
    metrics["netfault_n_reroutes"] = on["n_reroutes"]
    metrics["netfault_n_budget_moves"] = on["n_budget_moves"]
    metrics["netfault_sweep_wall_s"] = round(time.time() - t_start, 3)
    write_bench(metrics, quick, "BENCH_netfaults.json")
    emit(rows, "netfault_sweep")
    print(f"des16 fabric chaos: final-loss ratio "
          f"{metrics['netfault_final_loss_ratio']:.4f} (ceiling 1.10), "
          f"recovery {metrics['netfault_recovery_s']:.3f}s, "
          f"goodput x{metrics['netfault_goodput_ratio']:.3f} "
          f"[controller off: ratio "
          f"{metrics['netfault_final_loss_ratio_off']:.4f}, recovery "
          f"{metrics['netfault_recovery_s_off']:.3f}s]")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized grid (default: full)")
    args = ap.parse_args(argv)
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
