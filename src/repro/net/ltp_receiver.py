"""LTP receiver(s): per-packet out-of-order ACK, Early Close, bubble
accounting (paper §III-B/C).

``LTPFlowReceiver`` handles one flow. ``PSGatherReceiver`` coordinates the
incast gather at the PS: per-link LT thresholds, one shared deadline, and
the close rule over the aggregate received percentage + critical-packet
completeness. On close it broadcasts "stop" to all senders and records,
per flow, exactly which packets must be bubble-filled.

``ShardedGatherReceiver`` (DESIGN.md §5) is the multi-PS composition: one
independent ``PSGatherReceiver`` per model shard, each with its own LT
threshold, deadline timer, and close decision. A worker appears once per
shard; aggregate statistics reduce over shards (BST = slowest shard's
close; a worker's delivered fraction = mean over its shard flows).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Set

import numpy as np

from repro.net.genfence import GEN_KEY, gen_of, has_gen
from repro.net.simcore import Packet, Sim, TrainItems


class LTPFlowReceiver:
    """Tracks one sender's flow; emits per-packet ACKs.

    With a train-aware ``send_ack_train`` attached, coalesced data trains
    (``on_data_train``) are acknowledged as one ACK train — K ACK packets,
    one heap event (DESIGN.md §7).
    """

    def __init__(self, sim: Sim, send_ack: Callable[[Packet], None], flow: int):
        self.sim = sim
        self.send_ack = send_ack
        # transport wiring, attached once per pooled life from outside —
        # reset() deliberately leaves it alone
        self.send_ack_train: Optional[Callable[[List[Packet]], None]] = None  # replint: ok(pool-reset)
        self.flow = flow
        self.received: Set[int] = set()
        self.reset()

    def reset(self) -> None:
        """Cold-start flow state in place (flow pooling, DESIGN.md §9)."""
        self.n: Optional[int] = None
        self.critical: Optional[np.ndarray] = None
        self.received.clear()
        self.t_start: Optional[float] = None
        self.t_full: Optional[float] = None
        self.closed = False

    @property
    def pct(self) -> float:
        if not self.n:
            return 0.0
        return len(self.received) / self.n

    @property
    def criticals_done(self) -> bool:
        if self.n is None:
            return False
        if self.critical is None:
            return True
        need = np.flatnonzero(self.critical)
        return all(int(s) in self.received for s in need)

    # replint: hotpath
    def _ack_for(self, pkt: Packet, t: float) -> Packet:
        """Per-packet bookkeeping (reg metadata / received set / t_start /
        t_full at the packet's true arrival ``t``) -> the ACK to send.
        Shared by the per-packet and coalesced-train paths so they cannot
        drift."""
        if pkt.kind == "reg":
            self.n = pkt.meta["n"]
            self.critical = pkt.meta.get("critical")
            if self.t_start is None:
                self.t_start = t
            # echo the sender's flow generation so a pooled sender can
            # tell this reg-ack from one aimed at a previous life
            ack = Packet(self.flow, -1, 41, kind="ack",
                         meta={GEN_KEY: gen_of(pkt.meta)}
                         if has_gen(pkt.meta) else {})
        else:
            self.received.add(pkt.seq)
            ack = Packet(self.flow, pkt.seq, 41, kind="ack",
                         meta={"echo": pkt.meta,
                               "order": pkt.meta.get("order", -1)})
        if self.n is not None and len(self.received) >= self.n \
                and self.t_full is None:
            self.t_full = t
        return ack

    # replint: hotpath
    def on_data(self, pkt: Packet, notify: Callable[[], None]):
        if self.closed:
            return
        self.send_ack(self._ack_for(pkt, self.sim.now))
        notify()

    def on_data_train(self, items: TrainItems, notify: Callable[[], None]):
        """Coalesced delivery: one call per train, per-packet arrival times
        from the pipe; ACKs return as a single train."""
        if self.closed:
            return
        acks: List[Packet] = [self._ack_for(pkt, t) for pkt, t in items]
        if acks:
            if self.send_ack_train is not None:
                self.send_ack_train(acks)
            else:
                for a in acks:
                    self.send_ack(a)
        notify()

    def delivered_mask(self) -> np.ndarray:
        """(n,) bool — per-packet delivery state (True = received)."""
        if self.n is None:
            return np.zeros(0, bool)
        mask = np.zeros(self.n, bool)
        # iteration order is irrelevant: each element sets one mask cell
        for s in self.received:  # replint: ok(determinism)
            if 0 <= s < self.n:
                mask[s] = True
        return mask

    def bubbles(self) -> np.ndarray:
        """(n,) bool — packets that must be zero-filled at close."""
        if self.n is None:
            return np.zeros(0, bool)
        return ~self.delivered_mask()


def _noop() -> None:
    pass


class PSGatherReceiver:
    """The PS side of one gather iteration over W flows (paper Fig 7).

    close rule: before LT -> wait for 100%; in [LT, deadline) -> close when
    aggregate pct >= threshold and all criticals are in; at deadline ->
    close unconditionally (criticals are retransmitted via CQ and in
    practice always land before the deadline; if not, the close is late —
    counted in stats).
    """

    def __init__(self, sim: Sim, flows: List[int], lt_threshold: float,
                 deadline: float, pct_threshold: float,
                 send_stop: Callable[[int], None],
                 on_close: Optional[Callable[["PSGatherReceiver"], None]] = None,
                 ps_id: int = 0):
        self.sim = sim
        self.ps_id = ps_id
        self.lt = lt_threshold
        self.deadline = deadline
        self.pct_threshold = pct_threshold
        self.send_stop = send_stop
        self.on_close = on_close
        # per-flow receiver map: reset() re-initializes every value in
        # place (the map itself is the pooled wiring, keyed by flow id)
        self.flows: Dict[int, LTPFlowReceiver] = {}  # replint: ok(pool-reset)
        self.gen = 0
        #: pooled-transport hook, called as ``on_stale(flow, gen)`` when
        #: data from an older flow generation arrives: the transport
        #: re-stops the orphaned sender if it is still living that
        #: generation (its original stop was lost in flight) — without
        #: this a recycled gather would silently drop the straggler's
        #: retransmissions and the orphan would pump forever.
        self.on_stale: Optional[Callable[[int, int], None]] = None  # replint: ok(pool-reset)
        self._check_eids: List[int] = []
        #: flows abandoned mid-gather (node death, DESIGN.md §10): their
        #: receivers are closed, they are excluded from the close rule,
        #: and their delivery masks report zeros — a dead node's partial
        #: gradient must never reach the reduction.
        self._dead: Set[int] = set()
        # observability counters (DESIGN.md §12) — cumulative across the
        # pooled gather's lives: initialized here, NOT cleared by reset()
        self.n_stale_fenced = 0   # replint: ok(pool-reset)
        self.n_stop_resends = 0   # replint: ok(pool-reset)
        for f in flows:
            self.flows[f] = LTPFlowReceiver(sim, lambda p: None, f)
        self.reset()

    def reset(self, gen: Optional[int] = None) -> None:
        """Re-arm this gather for a fresh iteration (flow pooling): cold
        flow state, new t0, fresh LT/deadline check timers (stale ones
        are cancelled), and a bumped generation so deliveries from the
        previous iteration are dropped instead of polluting the masks."""
        if gen is not None:
            self.gen = gen
        for fr in self.flows.values():
            fr.reset()
        self._dead.clear()
        self.t0 = self.sim.now
        self.closed = False
        self.close_time: Optional[float] = None
        for eid in self._check_eids:
            self.sim.cancel(eid)
        self._check_eids = [self.sim.at(self.t0 + self.lt, self._check),
                            self.sim.at(self.t0 + self.deadline, self._check)]

    def abandon_flow(self, flow: int) -> None:
        """Drop ``flow`` from this gather mid-round (its node died or
        never joined): the per-flow receiver closes, the flow no longer
        gates the close rule, and its mask reports zeros. Re-evaluates
        the close rule — the death of the last straggler may complete
        the barrier."""
        if flow not in self.flows or flow in self._dead:
            return
        self._dead.add(flow)
        self.flows[flow].closed = True
        if not self.closed:
            self._check()

    def deactivate(self, gen: Optional[int] = None) -> None:
        """Hard-stop the whole gather (PS death): close every flow,
        cancel the LT/deadline timers, and optionally bump the
        generation so in-flight data is fenced out as stale. The pooled
        receiver revives through ``reset``."""
        self.closed = True
        for fr in self.flows.values():
            fr.closed = True
        for eid in self._check_eids:
            self.sim.cancel(eid)
        self._check_eids = []
        if gen is not None:
            self.gen = gen

    def _stale(self, pkt: Packet) -> bool:
        g = gen_of(pkt.meta)
        return g is not None and g != self.gen

    def attach_ack(self, flow: int, send_ack: Callable[[Packet], None]):
        self.flows[flow].send_ack = send_ack

    def attach_ack_train(self, flow: int,
                         send_ack_train: Callable[[List[Packet]], None]):
        self.flows[flow].send_ack_train = send_ack_train

    def on_data(self, pkt: Packet):
        fr = self.flows.get(pkt.flow)
        if fr is None:
            return
        if self._stale(pkt):
            self.n_stale_fenced += 1
            if self.on_stale is not None:
                self.on_stale(pkt.flow, gen_of(pkt.meta))
            return
        if self.closed:
            # data after close means the flow's "stop" was lost in flight:
            # re-send it (once per arriving packet, so the retry rate is
            # bounded by the sender's own transmission rate)
            self.n_stop_resends += 1
            self.send_stop(pkt.flow)
            return
        fr.on_data(pkt, self._check)

    def on_data_train(self, items: TrainItems):
        """Coalesced delivery: all packets in a train share one event time,
        so the close rule is evaluated once after the whole train (identical
        to per-packet evaluation at equal ``sim.now``)."""
        stale = [(p.flow, gen_of(p.meta)) for p, _ in items
                 if self._stale(p)]
        if stale:
            self.n_stale_fenced += len(stale)
            if self.on_stale is not None:
                for flow, g in dict.fromkeys(stale):
                    self.on_stale(flow, g)
            items = [(p, t) for p, t in items if not self._stale(p)]
        if not items:
            return
        if self.closed:
            # sorted so stop-resend order never depends on set hashing
            # (same-seed replays must schedule identical event sequences)
            for flow in sorted({p.flow for p, _ in items}):
                if flow in self.flows:
                    self.n_stop_resends += 1
                    self.send_stop(flow)
            return
        by_flow: Dict[int, TrainItems] = {}
        for pkt, t in items:
            by_flow.setdefault(pkt.flow, []).append((pkt, t))
        for flow, fitems in by_flow.items():
            fr = self.flows.get(flow)
            if fr is not None:
                fr.on_data_train(fitems, _noop)
        self._check()

    def _live(self):
        """Flow receivers still gating the close rule (not abandoned)."""
        if not self._dead:
            return self.flows.values()
        return [fr for f, fr in self.flows.items() if f not in self._dead]

    @property
    def agg_pct(self) -> float:
        ps = [f.pct for f in self._live()]
        return float(np.mean(ps)) if ps else 0.0

    @property
    def all_full(self) -> bool:
        return all(f.n is not None and len(f.received) >= f.n
                   for f in self._live())

    @property
    def criticals_done(self) -> bool:
        return all(f.criticals_done for f in self._live())

    def _check(self):
        if self.closed:
            return
        t = self.sim.now - self.t0
        if self.all_full:
            self._close()
            return
        if t >= self.deadline:
            if self.criticals_done:
                self._close()
            # else: criticals still owed; CQ retransmissions land shortly —
            # the close fires on the arrival that completes them.
            return
        if t >= self.lt and self.agg_pct >= self.pct_threshold and self.criticals_done:
            self._close()

    def _close(self):
        self.closed = True
        self.close_time = self.sim.now
        for f in self.flows:
            self.send_stop(f)
        for fr in self.flows.values():
            fr.closed = True
        if self.on_close:
            self.on_close(self)

    # --- results -------------------------------------------------------------
    def delivered_fracs(self) -> np.ndarray:
        return np.array([0.0 if f in self._dead else fr.pct
                         for f, fr in self.flows.items()])

    def delivery_masks(self) -> np.ndarray:
        """(W, n) bool — per-(worker, packet) delivery state at close.

        This is the mask the PS-side aggregation consumes: True packets
        carry gradient payload, False packets are bubble-filled (the exact
        input shape of ``kernels.packet_reduce``, DESIGN.md §7). An
        abandoned flow's row is all-False: whatever a dead node managed
        to land before it died is provably dropped."""
        ms = [np.zeros_like(fr.delivered_mask()) if f in self._dead
              else fr.delivered_mask()
              for f, fr in self.flows.items()]
        n = max((len(m) for m in ms), default=0)
        if n == 0:
            return np.zeros((len(ms), 0), bool)
        return np.stack([np.pad(m, (0, n - len(m))) for m in ms])

    def full_times(self) -> np.ndarray:
        return np.array([
            (fr.t_full - self.t0)
            if fr.t_full is not None and f not in self._dead else np.inf
            for f, fr in self.flows.items()
        ])

    def bst_gather(self) -> float:
        return (self.close_time or self.sim.now) - self.t0


class ShardedGatherReceiver:
    """Multi-PS gather state: one ``PSGatherReceiver`` per model shard.

    Each shard closes independently (its own LT threshold + deadline);
    the *iteration* is done when the slowest shard closes. Statistics
    reduce over shards so the result shapes match the single-PS case:
    per-worker delivered fraction is the mean over that worker's shard
    flows, and full time is the max (the worker is only "fully
    delivered" once every shard has its packets).
    """

    def __init__(self, sim: Sim, n_ps: int, workers: List[int],
                 lt_thresholds: List[float], deadlines: List[float],
                 pct_threshold: float,
                 send_stop: Callable[[int, int], None]):
        """``send_stop(ps, worker)`` stops worker's flow toward shard ps."""
        self.sim = sim
        self.n_ps = n_ps
        self.workers = list(workers)
        self.shards: List[PSGatherReceiver] = [
            PSGatherReceiver(
                sim, list(workers), lt_thresholds[p], deadlines[p],
                pct_threshold,
                send_stop=lambda w, p=p: send_stop(p, w),
                ps_id=p,
            )
            for p in range(n_ps)
        ]

    def shard(self, ps: int) -> PSGatherReceiver:
        return self.shards[ps]

    def reset(self, gen: Optional[int] = None) -> None:
        """Re-arm every shard for a fresh iteration (flow pooling)."""
        for s in self.shards:
            s.reset(gen)

    def abandon_worker(self, worker: int) -> None:
        """Drop ``worker`` from every shard's close rule (node death)."""
        for s in self.shards:
            s.abandon_flow(worker)

    def deactivate(self, gen: Optional[int] = None) -> None:
        """Hard-stop every shard (PS death); see
        ``PSGatherReceiver.deactivate``."""
        for s in self.shards:
            s.deactivate(gen)

    @property
    def all_closed(self) -> bool:
        return all(s.closed for s in self.shards)

    @property
    def criticals_done(self) -> bool:
        return all(s.criticals_done for s in self.shards)

    # --- reductions over shards ----------------------------------------------
    def bst_gather(self) -> float:
        return max(s.bst_gather() for s in self.shards)

    def delivered_fracs(self) -> np.ndarray:
        """(W,) mean delivered fraction per worker across shards."""
        return np.mean([s.delivered_fracs() for s in self.shards], axis=0)

    def full_times(self) -> np.ndarray:
        """(W,) time at which the worker's *last* shard hit 100%."""
        return np.max([s.full_times() for s in self.shards], axis=0)

    def per_shard_full_times(self) -> np.ndarray:
        """(n_ps, W) raw 100%-times — feeds per-PS LT adaptation."""
        return np.stack([s.full_times() for s in self.shards])

    def delivery_masks(self) -> np.ndarray:
        """(n_ps, W, n) bool per-(shard, worker, packet) delivery state."""
        return np.stack([s.delivery_masks() for s in self.shards])

    def payload_packets_received(self) -> int:
        return sum(len(f.received) for s in self.shards
                   for f in s.flows.values())
