"""Deterministic fault injection for the cluster runtime (DESIGN.md §10).

A ``FaultSchedule`` is a seeded, immutable list of node-level events —
worker crash / join / leave and PS failure — placed on the runtime's
shared ``Sim`` clock before the run starts. Determinism is the whole
point: the same schedule against the same runtime seed replays the same
co-simulation event-for-event, so chaos runs are pinnable in tests.

Event semantics (enforced by ``ClusterRuntime.on_fault``):

  worker_crash   immediate death: in-flight compute is cancelled and
                 in-flight flows are torn down through the generation
                 fencing protocol (the receiver generation bumps, so any
                 packet the dead node still has in flight is provably
                 dropped as stale).
  worker_leave   graceful drain: the worker finishes the iteration it is
                 computing, its gradient is allowed to deliver, then the
                 slot retires. No teardown.
  worker_join    a previously departed slot re-enters: it fetches the
                 current params (one broadcast delay), optionally pays a
                 compute warm-up penalty, and resumes. Joining an alive
                 slot is a no-op — the cluster's slot universe is fixed
                 at ``n_workers`` (the jit-compiled batch shapes), so
                 elasticity is membership over slots, not slot creation.
  ps_fail        the parameter server dies for ``recover_s`` sim-seconds.
                 Pending and in-flight gradients are lost; on failover
                 the PS restores the last ``repro.checkpoint`` snapshot
                 and, with ``n_ps > 1``, the dead shard's transport
                 ownership rebalances onto the surviving PSes
                 (``ShardLedger``).
  ps_recover     the failed PS process returns; shard ownership
                 rebalances back to the home assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Iterator, List, Optional, Tuple

import numpy as np

FAULT_KINDS = (
    "worker_crash",
    "worker_join",
    "worker_leave",
    "ps_fail",
    "ps_recover",
)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault on the sim clock."""

    t: float
    kind: str
    target: int = 0          # worker slot or PS index
    recover_s: float = 0.0   # ps_fail only: downtime before failover

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")
        if self.t < 0:
            raise ValueError(f"fault time must be >= 0, got {self.t}")

    def label(self) -> str:
        """Human-readable marker text for trace exports (DESIGN.md §12),
        e.g. ``"crash worker3 @12.50s"``. The unit derives from the kind
        prefix — a kind that names neither a worker nor a PS (the
        network fault plane's link/switch events render through the same
        trace path) carries its target verbatim instead of being
        mislabelled ``worker{target}``."""
        if self.kind.startswith("ps_"):
            unit = "ps"
        elif self.kind.startswith("worker_"):
            unit = "worker"
        else:
            unit = ""
        s = f"{self.kind} {unit}{self.target} @{self.t:.2f}s"
        if self.recover_s:
            s += f" (+{self.recover_s:.2f}s recovery)"
        return s


class FaultSchedule:
    """Ordered, deterministic fault timeline.

    Construct from an explicit event list, or draw one with
    ``FaultSchedule.random`` (seeded Poisson churn that never drops the
    active set below ``min_active``). ``arm`` registers every event on
    the shared clock; dispatch happens through the runtime's
    ``on_fault`` so the schedule itself stays pure data.
    """

    def __init__(self, events: Iterable[FaultEvent] = ()):
        evs = list(events)
        for ev in evs:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"expected FaultEvent, got {type(ev)!r}")
        # stable sort: ties keep insertion order, so schedules replay
        # identically regardless of how they were assembled
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(evs, key=lambda e: e.t))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self.events)

    def __repr__(self) -> str:
        return f"FaultSchedule({list(self.events)!r})"

    def arm(self, sim, dispatch: Callable[[FaultEvent], None]) -> None:
        """Schedule every event: ``dispatch(ev)`` fires at ``ev.t``."""
        for ev in self.events:
            sim.at(ev.t, lambda ev=ev: dispatch(ev))

    @classmethod
    def random(cls, n_workers: int, t_end: float, *, seed: int = 0,
               crash_rate: float = 0.0,
               rejoin_after_s: Optional[float] = None,
               leave_rate: float = 0.0,
               ps_fail_at: Iterable[float] = (),
               ps_recovery_s: float = 0.05,
               min_active: int = 1) -> "FaultSchedule":
        """Seeded random churn over ``[0, t_end]``.

        Worker crashes/leaves are Poisson per worker-second; a crashed
        worker rejoins ``rejoin_after_s`` later (never, if None). Events
        that would drop the active set below ``min_active`` are thinned
        out, so a drawn schedule can never wedge the cluster.
        """
        if min_active < 1:
            raise ValueError("min_active must be >= 1")
        rng = np.random.default_rng(seed)
        raw: List[FaultEvent] = []
        for w in range(n_workers):
            for rate, kind in ((crash_rate, "worker_crash"),
                               (leave_rate, "worker_leave")):
                if rate <= 0:
                    continue
                t = float(rng.exponential(1.0 / rate))
                while t < t_end:
                    raw.append(FaultEvent(t, kind, target=w))
                    if kind == "worker_crash" and rejoin_after_s is not None:
                        raw.append(FaultEvent(t + rejoin_after_s,
                                              "worker_join", target=w))
                    t += float(rng.exponential(1.0 / rate))
        for t in ps_fail_at:
            raw.append(FaultEvent(float(t), "ps_fail", target=0,
                                  recover_s=ps_recovery_s))
        raw.sort(key=lambda e: e.t)
        # replay the membership timeline, dropping departures that would
        # violate min_active and joins/leaves that no longer make sense
        active = set(range(n_workers))
        kept: List[FaultEvent] = []
        for ev in raw:
            if ev.kind in ("worker_crash", "worker_leave"):
                if ev.target not in active or len(active) <= min_active:
                    continue
                active.discard(ev.target)
            elif ev.kind == "worker_join":
                if ev.target in active:
                    continue
                active.add(ev.target)
            kept.append(ev)
        return cls(kept)


def schedule_from_config(cfg, n_workers: int, t_end: float) -> "FaultSchedule":
    """Draw the schedule a ``repro.config.FaultConfig`` describes, once
    the run horizon ``t_end`` is known."""
    return FaultSchedule.random(
        n_workers, t_end, seed=cfg.seed, crash_rate=cfg.crash_rate,
        rejoin_after_s=cfg.rejoin_after_s, leave_rate=cfg.leave_rate,
        ps_fail_at=cfg.ps_fail_at, ps_recovery_s=cfg.ps_recovery_s,
        min_active=cfg.min_active)


class ShardLedger:
    """Shard → owning-PS map for transport-level failover rebalancing.

    The runtime's JAX state is one tree; PS shards exist at the
    transport layer (one trunk per shard). When a PS fails, the shards
    it owns re-home round-robin onto the surviving PSes so gather/
    broadcast traffic keeps flowing; ``recover`` restores the home
    assignment. ``moves`` lists ``(shard, old_owner, new_owner)`` for
    telemetry.
    """

    def __init__(self, n_ps: int):
        if n_ps < 1:
            raise ValueError("n_ps must be >= 1")
        self.n_ps = n_ps
        self.owner: List[int] = list(range(n_ps))
        self.alive: set = set(range(n_ps))

    @property
    def n_alive(self) -> int:
        return len(self.alive)

    def fail(self, ps: int) -> List[Tuple[int, int, int]]:
        """Mark ``ps`` dead; re-home its shards onto survivors."""
        if ps not in self.alive:
            return []
        self.alive.discard(ps)
        if not self.alive:
            # last PS down: ownership is moot until failover restores it
            return []
        survivors = sorted(self.alive)
        moves: List[Tuple[int, int, int]] = []
        for shard in range(self.n_ps):
            if self.owner[shard] == ps:
                new = survivors[shard % len(survivors)]
                moves.append((shard, ps, new))
                self.owner[shard] = new
        return moves

    def recover(self, ps: int) -> List[Tuple[int, int, int]]:
        """Bring ``ps`` back; its home shards return to it."""
        if ps in self.alive:
            return []
        self.alive.add(ps)
        moves: List[Tuple[int, int, int]] = []
        # home assignment is the identity map (shard i lives on PS i)
        for shard in range(self.n_ps):
            if shard == ps and self.owner[shard] != ps:
                moves.append((shard, self.owner[shard], ps))
                self.owner[shard] = ps
        return moves
