"""Discrete-event core: event loop, lossy serialized pipes, and the
composable topology layer (DESIGN.md §5).

A ``Pipe`` models one direction of a link: store-and-forward serialization
at ``rate_bps``, a droptail queue (in packets) at its ingress, i.i.d.
non-congestion random loss, and fixed propagation delay. The incast
scenarios attach many senders to one shared bottleneck pipe — the ToR's
egress port toward the PS — which is where the paper's long-tail latency
is born.

Beyond the single shared bottleneck, three composable pieces build
arbitrary gather topologies:

  ``Route``              chains pipes hop-by-hop (worker NIC -> ToR ->
                         PS port); a drop at any hop kills the packet.
  ``Topology``           named-pipe registry with per-group aggregate
                         stats — one *pipe group* per PS shard.
  ``CrossTrafficSource`` open-loop on/off background load injected at a
                         pipe's ingress, stealing serialization slots
                         from the senders under test.

Packet trains (DESIGN.md §7): beyond the per-packet ``Pipe.send``, a
sender may emit a whole *train* of packets through ``Pipe.send_train`` —
one heap event for the entire train, with queue-admission and loss
decisions drawn as a single vectorized numpy pass over the same RNG
stream the per-packet path would consume, and per-packet arrival times
handed to the receiver in one callback. This is what makes paper-scale
sweeps (64 workers x 4 PS) feasible in quick mode.

Event engine (DESIGN.md §9): ``Sim`` defaults to a calendar queue — a
near-future bucket wheel plus a far-future heap, batch-popping
same-timestamp events FIFO by schedule id — with the reference binary
heap selectable via ``Sim(engine="heap")``. Both engines execute the
same schedule in the same order, bitwise.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
import warnings
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: A delivered train: per-packet ``(packet, arrival_time)`` in arrival order.
TrainItems = List[Tuple["Packet", float]]


class PerfCounters:
    """Process-wide simulator throughput counters (read by benchmarks).

    ``events`` counts heap events processed; ``packets`` counts packet
    deliveries scheduled (train members count individually) — the ratio
    is the effective coalescing factor.
    """

    __slots__ = ("events", "packets")

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self.events = 0
        self.packets = 0

    def snapshot(self) -> dict:
        """Plain-dict view for the metrics registry (DESIGN.md §12)."""
        return {"events": self.events, "packets": self.packets}


PERF = PerfCounters()


@dataclasses.dataclass(slots=True)      # slots: the sim allocates millions
class Packet:
    flow: int
    seq: int              # packet sequence within the flow (jigsaw piece id)
    size: int             # bytes on the wire
    kind: str = "data"    # data | ack | stop | reg | end
    critical: bool = False
    meta: Any = None      # protocol payload (e.g. acked seq, send stamp)


#: engine selected by ``Sim()`` when none is given explicitly. "calendar"
#: is the fast bucketed engine (DESIGN.md §9); "heap" is the reference
#: binary-heap engine, kept so determinism tests can A/B the two.
DEFAULT_ENGINE = "calendar"


class Sim:
    """Event loop. Callbacks run at monotonically nondecreasing times.

    Two interchangeable engines produce the *same execution order* —
    events always fire in ``(time, schedule-id)`` order, so same-seed
    runs are bitwise-identical across engines (pinned by
    tests/test_calendar_queue.py):

    * ``"calendar"`` (default) — a calendar queue: a near-future wheel
      of ``_NB`` time buckets (each a tiny heap) plus a far-future heap
      for events beyond the wheel horizon. All events sharing the head
      timestamp are batch-popped and executed FIFO by schedule id; the
      bucket width recalibrates to the observed mean event spacing every
      ``_CAL_EVERY`` pops. Bucket placement uses ONE monotone map
      ``t -> int((t - origin) / width)`` per wheel epoch, so float
      rounding can shift a boundary event between adjacent buckets but
      can never invert time order.
    * ``"heap"`` — the single binary heap.

    ``truncated`` flips to True when a ``run`` stops on ``max_events``
    with work still pending — a co-simulation cut off mid-scenario must
    not masquerade as a converged run (callers check the flag; a
    ``RuntimeWarning`` fires too, so silent truncation is impossible).
    """

    _NB = 1024           # near-future wheel buckets
    _CAL_EVERY = 512     # pops between bucket-width recalibrations
    _ADV_EVERY = 8192    # empty-bucket advances that force a recalibration

    def __init__(self, engine: Optional[str] = None) -> None:
        engine = DEFAULT_ENGINE if engine is None else engine
        if engine not in ("calendar", "heap"):
            raise ValueError(f"unknown Sim engine {engine!r}; "
                             f"expected 'calendar' or 'heap'")
        self.engine = engine
        self.now = 0.0
        self._heap: List = []     # heap-engine queue / calendar far heap
        self._ids = itertools.count()
        self.cancelled: set = set()
        self.n_events = 0
        self.truncated = False
        if engine == "calendar":
            self._wheel: Optional[List[List]] = \
                [[] for _ in range(self._NB)]
            self._near = 0        # events currently in the wheel
            self._org = 0.0       # wheel origin (time of absolute slot 0)
            self._k = 0           # buckets consumed since the last rebuild
            self._width = 1e-6    # bucket width; recalibrated while running
            self._inv = 1e6       # 1 / width (slot = int((t-org) * inv))
            self._cal_n = 0       # pops since the last calibration
            self._cal_t = 0.0     # sim time at the last calibration
            self._adv_n = 0       # empty advances since the last calibration
            self._active: Optional[List] = None  # bucket being executed
        else:
            self._wheel = None

    # -- scheduling ---------------------------------------------------------
    # replint: hotpath
    def at(self, t: float, fn: Callable[[], None]) -> int:
        eid = next(self._ids)
        if t < self.now:
            t = self.now
        wheel = self._wheel
        if wheel is None:
            heapq.heappush(self._heap, (t, eid, fn))
            return eid
        # inlined _place (this is THE scheduling hot path)
        a = int((t - self._org) * self._inv) - self._k
        if a < 0:
            a = 0
        if a >= self._NB:
            heapq.heappush(self._heap, (t, eid, fn))
            return eid
        i = self._k % self._NB + a
        if i >= self._NB:
            i -= self._NB
        b = wheel[i]
        if b is self._active:
            bisect.insort(b, (t, eid, fn))
        else:
            b.append((t, eid, fn))
        self._near += 1
        return eid

    def after(self, dt: float, fn: Callable[[], None]) -> int:
        return self.at(self.now + dt, fn)

    def cancel(self, eid: int) -> None:
        self.cancelled.add(eid)

    def pending(self) -> int:
        """Events still queued (any engine)."""
        near = self._near if self._wheel is not None else 0
        return near + len(self._heap)

    # -- calendar internals -------------------------------------------------
    def _place(self, t: float, eid: int, fn: Callable[[], None],
               clamp: bool = False) -> None:
        # relative slot via the epoch's single monotone map: float
        # rounding at a bucket boundary cannot reorder two events
        a = int((t - self._org) * self._inv) - self._k
        if a < 0:
            a = 0          # belongs before the window: run ASAP, in order
        if a >= self._NB:
            if not clamp:  # beyond the horizon: park in the far heap
                heapq.heappush(self._heap, (t, eid, fn))
                return
            a = self._NB - 1   # far-drain boundary rounding: last bucket
        i = self._k % self._NB + a
        if i >= self._NB:
            i -= self._NB
        b = self._wheel[i]
        if b is self._active:
            # insertion into the bucket being executed: insort keeps it
            # ordered, and the new event can only land in the unexecuted
            # suffix (it compares greater than everything already run)
            bisect.insort(b, (t, eid, fn))
        else:
            b.append((t, eid, fn))   # future bucket: sorted on activation
        self._near += 1

    def _drain_far(self) -> None:
        """Move far-heap events that now fall inside the wheel horizon."""
        far = self._heap
        end = self._org + (self._k + self._NB) * self._width
        while far and far[0][0] < end:
            t, eid, fn = heapq.heappop(far)
            self._place(t, eid, fn, clamp=True)

    def _rebuild(self, width: float) -> None:
        """Re-anchor the wheel at ``now`` with a new bucket width."""
        moved = [e for b in self._wheel for e in b]
        for b in self._wheel:
            b.clear()
        self._near = 0
        self._width = width
        self._inv = 1.0 / width
        self._org = self.now
        self._k = 0
        for t, eid, fn in moved:
            self._place(t, eid, fn)
        self._drain_far()

    def _recalibrate(self) -> None:
        span = self.now - self._cal_t
        if span > 0.0 and self._cal_n > 0:
            # ~8 events per bucket: wide enough that the horizon clears
            # the pending set (no far-heap churn) and the loop is not
            # dominated by empty-bucket advances, narrow enough that
            # per-bucket sorts stay small
            width = 8.0 * span / self._cal_n
            width = min(max(width, 1e-9), 0.1)
            if not (0.25 * self._width <= width <= 4.0 * self._width):
                self._rebuild(width)
        self._cal_n = 0
        self._adv_n = 0
        self._cal_t = self.now

    def every(self, dt: float, fn: Callable[[], None],
              until: float = float("inf"),
              start: Optional[float] = None) -> Callable[[], None]:
        """Periodic actor hook: run ``fn`` every ``dt`` seconds of sim
        time starting at ``now + dt`` (telemetry samplers, watchdogs),
        or at absolute time ``start`` if given — e.g. ``start=now`` runs
        the first tick immediately as a sim event (the runtime's
        checkpoint grid anchors its t=0 snapshot this way).
        Returns a zero-argument canceller."""
        eid: Optional[int] = None
        stopped = False

        def tick() -> None:
            nonlocal eid
            if stopped or self.now > until:
                return
            fn()
            eid = self.after(dt, tick)

        eid = self.after(dt, tick) if start is None else self.at(start, tick)

        def cancel_hook() -> None:
            nonlocal stopped
            stopped = True
            if eid is not None:
                self.cancel(eid)

        return cancel_hook

    def run(self, until: float = float("inf"),
            max_events: int = 100_000_000) -> int:
        if self._wheel is None:
            n = self._run_heap(until, max_events)
        else:
            n = self._run_calendar(until, max_events)
        if n >= max_events and self.pending():
            self.truncated = True
            warnings.warn(
                f"Sim.run stopped on max_events={max_events} with "
                f"{self.pending()} events pending at t={self.now:.6f}s — "
                f"results are truncated, not converged",
                RuntimeWarning, stacklevel=2)
        self.n_events += n
        PERF.events += n
        return n

    def _run_heap(self, until: float, max_events: int) -> int:
        n = 0
        while self._heap and n < max_events:
            t, eid, fn = heapq.heappop(self._heap)
            if eid in self.cancelled:
                self.cancelled.discard(eid)
                continue
            if t > until:
                heapq.heappush(self._heap, (t, eid, fn))
                break
            self.now = t
            fn()
            n += 1
        return n

    def _run_calendar(self, until: float, max_events: int) -> int:
        n = 0
        wheel, nb = self._wheel, self._NB
        far = self._heap
        cancelled = self.cancelled
        while n < max_events:
            if not self._near:
                # discard cancelled ghosts at the far frontier first —
                # the heap engine drops a cancelled head even when it
                # lies beyond ``until``, and pending() must agree
                while far and far[0][1] in cancelled:
                    cancelled.discard(heapq.heappop(far)[1])
                if not far or far[0][0] > until:
                    break
                # the wheel is empty: jump its window to the far frontier
                self._org = far[0][0]
                self._k = 0
                self._drain_far()
                continue
            bucket = wheel[self._k % nb]
            if not bucket:
                self._k += 1
                if far and far[0][0] < \
                        self._org + (self._k + nb) * self._width:
                    self._drain_far()
                self._adv_n += 1
                if self._adv_n >= self._ADV_EVERY:
                    # sparse wheel: the width is far too small for the
                    # current event spacing — widen before scanning on
                    self._recalibrate()
                continue
            # batch-pop: sort the whole bucket once (same-timestamp runs
            # come out FIFO by schedule id) and execute it in place;
            # events landing in this bucket mid-execution insort into the
            # unexecuted suffix
            bucket.sort()
            self._active = bucket
            pos = 0
            stop = False
            while pos < len(bucket):
                t, eid, fn = bucket[pos]
                if eid in cancelled:    # drop ghosts even beyond until
                    cancelled.discard(eid)
                    pos += 1
                    continue
                if t > until or n >= max_events:
                    stop = True   # bucket head is the global pending min
                    break
                pos += 1
                self.now = t
                fn()
                n += 1
            self._active = None
            self._near -= pos
            self._cal_n += pos
            if stop:
                del bucket[:pos]   # keep the sorted unexecuted suffix
                break
            bucket.clear()
            if self._cal_n >= self._CAL_EVERY:
                self._recalibrate()
        return n


class Pipe:
    """One-direction link: droptail queue -> serializer -> loss -> delay."""

    def __init__(
        self,
        sim: Sim,
        rate_bps: float,
        delay: float,
        loss: float = 0.0,
        queue_pkts: int = 256,
        rng: Optional[np.random.Generator] = None,
        overhead: int = 0,
    ) -> None:
        self.sim = sim
        self.rate = rate_bps
        self.delay = delay
        self.loss = loss
        self.cap = queue_pkts
        self.rng = rng or np.random.default_rng(0)
        self.busy_until = 0.0
        self.overhead = overhead  # per-packet header bytes on the wire
        self.n_sent = 0
        self.n_dropped_queue = 0
        self.n_dropped_loss = 0
        self.bytes_delivered = 0
        # network fault plane (DESIGN.md §14). ``faultable`` stays False
        # until a LinkFaultSchedule arms this pipe — the default send
        # paths then never branch on any of this state, so an unarmed
        # run is bitwise-identical to a build without the fault plane.
        self.faultable = False
        self.up = True
        self.link_gen = 0        # bumps on every link_down: in-flight fence
        self.backup: Optional["Pipe"] = None   # spine-redundant reroute
        self.n_dropped_down = 0  # packets blackholed by a dead link
        self.n_rerouted = 0      # packets diverted onto the backup pipe
        self._base_rate = rate_bps
        self._base_loss = loss

    # -- fault plane (DESIGN.md §14) ----------------------------------------
    def set_up(self, up: bool) -> None:
        """Admin link state. Downing the link bumps ``link_gen`` so every
        delivery already scheduled on the wire is fenced out at arrival —
        no silent delivery from a dead link (the §9 generation pattern
        applied to the physical layer). The serializer backlog burns with
        the link."""
        self.faultable = True
        if self.up == up:
            return
        self.up = up
        if not up:
            self.link_gen += 1
            self.busy_until = 0.0

    def set_degraded(self, rate_factor: float = 1.0,
                     extra_loss: float = 0.0) -> None:
        """Degrade the link in place: cut the line rate by
        ``rate_factor`` and/or surge the random-loss probability."""
        self.faultable = True
        self.rate = self._base_rate * max(rate_factor, 1e-9)
        self.loss = min(1.0, self._base_loss + max(extra_loss, 0.0))

    def clear_degraded(self) -> None:
        self.rate = self._base_rate
        self.loss = self._base_loss

    def _deliver_fenced(self, deliver: Callable[[Packet], None],
                        pkt: Packet, gen: int) -> None:
        """Delivery on a faultable pipe: drop if the link went down after
        this packet entered the wire (``link_gen`` moved)."""
        if gen == self.link_gen:
            deliver(pkt)
        else:
            self.n_dropped_down += 1
            self.bytes_delivered -= pkt.size

    def _deliver_train_fenced(self, deliver_train: Callable[["TrainItems"], None],
                              items: "TrainItems", gen: int) -> None:
        if gen == self.link_gen:
            deliver_train(items)
        else:
            self.n_dropped_down += len(items)
            self.bytes_delivered -= sum(p.size for p, _ in items)

    def queue_len(self) -> float:
        backlog = max(0.0, self.busy_until - self.sim.now)
        return backlog * self.rate / 8.0 / 1500.0

    def recycle(self) -> None:
        """Drop residual serializer backlog (pooled per-flow back
        channels between iterations; cumulative counters are kept)."""
        self.busy_until = 0.0

    # replint: hotpath
    def send(self, pkt: Packet, deliver: Callable[[Packet], None]) -> bool:
        """Returns False if droptail-dropped at enqueue."""
        if self.faultable and not self.up:
            bk = self.backup
            if bk is not None and bk.up:
                self.n_rerouted += 1
                return bk.send(pkt, deliver)
            self.n_dropped_down += 1
            return True   # blackholed in flight (the sender can't tell)
        if self.queue_len() >= self.cap:
            self.n_dropped_queue += 1
            return False
        wire = pkt.size + self.overhead
        start = max(self.sim.now, self.busy_until)
        self.busy_until = start + wire * 8.0 / self.rate
        self.n_sent += 1
        if self.rng.random() < self.loss:
            self.n_dropped_loss += 1
            return True  # consumed wire time, dropped in flight
        arrive = self.busy_until + self.delay
        self.bytes_delivered += pkt.size
        PERF.packets += 1
        if self.faultable:
            # armed pipe: deliveries fence on link_gen so a cut kills
            # everything still on the wire (DESIGN.md §14)
            self.sim.at(arrive,
                        partial(self._deliver_fenced, deliver, pkt,
                                self.link_gen))
            return True
        # partial() beats a def-closure here: this is the per-packet hot
        # path and partial allocates no code/cell objects
        self.sim.at(arrive, partial(deliver, pkt))
        return True

    def send_train(self, pkts: Sequence[Packet],
                   deliver_train: Callable[[TrainItems], None],
                   t_ready: Optional[Sequence[float]] = None) -> int:
        """Send a train of packets as ONE heap event (DESIGN.md §7).

        Admission, serialization, and loss for the whole train are decided
        in a single vectorized pass that consumes the pipe's RNG stream in
        the same order the per-packet path would (queue drops never draw;
        admitted packets draw in send order), so a same-seed burst through
        ``send_train`` reproduces ``send`` exactly: same drops, same
        arrival times, same bytes. ``deliver_train`` fires once, at the
        last survivor's arrival, with per-packet ``(pkt, arrival_time)``
        pairs in arrival order.

        ``t_ready`` optionally gives per-packet *logical* enqueue times —
        used by multi-hop ``Route`` relays (each packet's previous-hop
        arrival) and staggered cross-traffic bursts. The relay event fires
        at the train's last arrival, so logical times may precede the
        event time: admission and serialization are computed retroactively
        at those times (exact when no other flow touched the pipe in
        between; a bounded approximation under interleaving). That path
        walks the train in order — still one event. Returns the number of
        packets admitted past the droptail queue.
        """
        if not pkts:
            return 0
        if self.faultable and not self.up:
            bk = self.backup
            if bk is not None and bk.up:
                self.n_rerouted += len(pkts)
                return bk.send_train(pkts, deliver_train, t_ready)
            self.n_dropped_down += len(pkts)
            return 0
        now = self.sim.now
        if t_ready is None:
            # same-instant burst: time does not advance within the event, so
            # the backlog only grows while admitting and freezes on a drop —
            # the first droptail drop ends the admitted prefix. Serialization
            # is a running sum in plain floats (cheaper than numpy's fixed
            # per-call cost at typical train lengths of 8..64); only the
            # loss draws vectorize — one RNG call, consuming the stream in
            # the exact order the per-packet path would.
            busy = self.busy_until
            qcap = self.cap * 1500.0 * 8.0 / self.rate    # cap in seconds
            inv_rate = 8.0 / self.rate
            admitted = []
            ends = []
            for p in pkts:
                if busy - now >= qcap or qcap <= 0:
                    break
                busy = (busy if busy > now else now) + \
                    (p.size + self.overhead) * inv_rate
                admitted.append(p)
                ends.append(busy)
            self.n_dropped_queue += len(pkts) - len(admitted)
            if not admitted:
                return 0
            self.busy_until = busy
            n_acc = len(admitted)
            self.n_sent += n_acc
            keep = self.rng.random(n_acc) >= self.loss
            self.n_dropped_loss += n_acc - int(keep.sum())
            items = [(p, e + self.delay)
                     for p, e, k in zip(admitted, ends, keep) if k]
            if not items:
                return n_acc
        else:
            items = []
            busy = self.busy_until
            n_acc = 0
            for pkt, tr in zip(pkts, t_ready):
                tr = float(tr)
                if max(0.0, busy - tr) * self.rate / 8.0 / 1500.0 >= self.cap:
                    self.n_dropped_queue += 1
                    continue
                busy = max(tr, busy) + (pkt.size + self.overhead) * 8.0 / self.rate
                self.n_sent += 1
                n_acc += 1
                if self.rng.random() < self.loss:
                    self.n_dropped_loss += 1
                    continue
                items.append((pkt, busy + self.delay))
            self.busy_until = busy
            if not items:
                return n_acc
        self.bytes_delivered += sum(p.size for p, _ in items)
        PERF.packets += len(items)
        if self.faultable:
            self.sim.at(items[-1][1],
                        partial(self._deliver_train_fenced, deliver_train,
                                items, self.link_gen))
        else:
            self.sim.at(items[-1][1], partial(deliver_train, items))
        return n_acc


class Route:
    """A chain of pipes traversed in order (store-and-forward per hop).

    Senders only require an object with ``send(pkt, deliver)``, so a
    ``Route`` substitutes for a ``Pipe`` anywhere: the packet re-enqueues
    at each hop's droptail queue, pays each hop's serialization + delay,
    and dies silently if any hop drops it. A one-pipe route behaves
    identically to using the pipe directly.
    """

    def __init__(self, pipes: Sequence[Pipe]) -> None:
        if not pipes:
            raise ValueError("Route needs at least one pipe")
        self.pipes = list(pipes)

    def send(self, pkt: Packet, deliver: Callable[[Packet], None]) -> bool:
        return self._hop(0, pkt, deliver)

    def _hop(self, i: int, pkt: Packet, deliver: Callable[[Packet], None]) -> bool:
        if i == len(self.pipes) - 1:
            return self.pipes[i].send(pkt, deliver)
        return self.pipes[i].send(
            pkt, lambda p, i=i: self._hop(i + 1, p, deliver)
        )

    def send_train(self, pkts: Sequence[Packet],
                   deliver_train: Callable[[TrainItems], None],
                   t_ready: Optional[Sequence[float]] = None) -> int:
        """Train relay over the hop chain: each hop's survivors re-enter
        the next hop as one train, carrying their per-packet hop-arrival
        times as that hop's enqueue times — still one event per hop."""
        return self._hop_train(0, list(pkts), deliver_train, t_ready)

    def _hop_train(self, i: int, pkts: List[Packet],
                   deliver_train: Callable[[TrainItems], None],
                   t_ready: Optional[Sequence[float]]) -> int:
        if i == len(self.pipes) - 1:
            return self.pipes[i].send_train(pkts, deliver_train, t_ready)

        def relay(items: TrainItems, i: int = i) -> None:
            self._hop_train(i + 1, [p for p, _ in items], deliver_train,
                            [t for _, t in items])

        return self.pipes[i].send_train(pkts, relay, t_ready)

    # aggregate counters over hops (drop-anywhere semantics)
    @property
    def n_dropped_queue(self) -> int:
        return sum(p.n_dropped_queue for p in self.pipes)

    @property
    def n_dropped_loss(self) -> int:
        return sum(p.n_dropped_loss for p in self.pipes)

    @property
    def n_dropped_down(self) -> int:
        return sum(p.n_dropped_down for p in self.pipes)

    @property
    def up(self) -> bool:
        """True when every hop is admin-up OR can reroute around its cut
        (fault plane, DESIGN.md §14) — the path can still carry traffic."""
        return all(p.up or (p.backup is not None and p.backup.up)
                   for p in self.pipes)


class Topology:
    """Named-pipe registry grouping links into *pipe groups* (one per PS
    shard in the multi-PS scenarios). Purely bookkeeping: construction
    helpers + aggregate statistics; the event loop stays in ``Sim``.
    """

    def __init__(self, sim: Sim) -> None:
        self.sim = sim
        self.pipes: Dict[str, Pipe] = {}
        self.groups: Dict[str, List[str]] = {}

    def add_pipe(self, name: str, pipe: Pipe, group: Optional[str] = None) -> Pipe:
        if name in self.pipes:
            raise ValueError(f"duplicate pipe name {name!r}")
        self.pipes[name] = pipe
        if group is not None:
            self.groups.setdefault(group, []).append(name)
        return pipe

    def route(self, *names: str) -> Route:
        return Route([self.pipes[n] for n in names])

    def group_pipes(self, group: str) -> List[Pipe]:
        return [self.pipes[n] for n in self.groups.get(group, [])]

    def queue_depths(self, group: Optional[str] = None) -> Dict[str, float]:
        """Per-pipe instantaneous queue depth in packets (actor hook:
        telemetry samplers attach via ``Sim.every`` and snapshot this)."""
        names = (self.groups.get(group, []) if group is not None
                 else list(self.pipes))
        return {n: self.pipes[n].queue_len() for n in names}

    def stats(self) -> Dict[str, Dict[str, float]]:
        """Per-group totals: sent/dropped/delivered-bytes."""
        out: Dict[str, Dict[str, float]] = {}
        for group, names in self.groups.items():
            ps = [self.pipes[n] for n in names]
            out[group] = {
                "n_sent": sum(p.n_sent for p in ps),
                "n_dropped_queue": sum(p.n_dropped_queue for p in ps),
                "n_dropped_loss": sum(p.n_dropped_loss for p in ps),
                "bytes_delivered": sum(p.bytes_delivered for p in ps),
            }
        return out


class CrossTrafficSource:
    """Open-loop background traffic on one pipe (bursty on/off).

    During ON periods, MTU-sized packets are injected at ``load`` × the
    pipe's line rate (so ``load`` is the long-run offered fraction of
    capacity while ON). ON/OFF durations are exponential with the given
    means, modelling other tenants' flows crossing the ToR — the traffic
    competes for the same serializer and droptail queue as the gather
    flows but is never ACKed or retransmitted.
    """

    FLOW_ID = -1  # cross-traffic packets carry flow == -1

    def __init__(self, sim: Sim, pipe: Pipe, load: float,
                 rng: Optional[np.random.Generator] = None,
                 pkt_bytes: int = 1500,
                 on_mean: float = 10e-3, off_mean: float = 10e-3,
                 duty: Optional[float] = None,
                 train_len: int = 1) -> None:
        self.sim = sim
        self.pipe = pipe
        self.load = float(load)
        self.rng = rng or np.random.default_rng(0)
        self.pkt_bytes = pkt_bytes
        self.train_len = max(1, int(train_len))
        self.on_mean = on_mean
        if duty is not None:
            # explicit duty cycle: derive the OFF mean from it
            self.duty = float(duty)
            self.off_mean = on_mean * (1.0 - self.duty) / max(self.duty, 1e-9)
        else:
            self.off_mean = off_mean
            self.duty = on_mean / (on_mean + off_mean)
        self.n_injected = 0
        self.n_delivered = 0
        self._seq = 0
        self._stopped = False
        self._running = False
        self._gen = 0          # burst-chain generation (restart safety)

    @property
    def offered_bps(self) -> float:
        """Long-run average offered load in bits/s."""
        return self.load * self.duty * self.pipe.rate

    def start(self) -> None:
        """Begin injecting. Idempotent: a second ``start`` on a running
        source is a no-op (no doubled burst chains); ``start`` after
        ``stop`` resumes from a fresh burst."""
        if self._running:
            return
        self._stopped = False
        self._running = True
        self._gen += 1         # orphan any pending chain from a prior life
        self._burst(self._gen)

    def stop(self) -> None:
        """Cease injecting (idempotent). Pending burst events become
        no-ops; already-enqueued packets still drain through the pipe."""
        self._stopped = True
        self._running = False

    def _burst(self, gen: Optional[int] = None) -> None:
        gen = self._gen if gen is None else gen
        if self._stopped or gen != self._gen or self.load <= 0:
            return
        on = self.rng.exponential(self.on_mean)
        gap = self.pkt_bytes * 8.0 / (self.load * self.pipe.rate)
        n = max(1, int(on / gap))
        if self.train_len > 1:
            # chunked trains: one event injects up to train_len packets with
            # staggered enqueue times, pre-claiming at most train_len * gap
            # of future wire time (a bounded approximation of the per-packet
            # interleaving; DESIGN.md §7)
            for start in range(0, n, self.train_len):
                k = min(self.train_len, n - start)
                self.sim.after(
                    start * gap,
                    lambda k=k, gap=gap: self._inject_train(k, gap, gen))
        else:
            for i in range(n):
                self.sim.after(i * gap, lambda: self._inject(gen))
        off = self.rng.exponential(self.off_mean)
        self.sim.after(on + off, lambda: self._burst(gen))

    def _inject(self, gen: Optional[int] = None) -> None:
        if self._stopped or (gen is not None and gen != self._gen):
            return
        self._seq += 1
        self.n_injected += 1
        pkt = Packet(self.FLOW_ID, self._seq, self.pkt_bytes, kind="data",
                     meta={"cross": True})
        self.pipe.send(pkt, self._sink)

    def _inject_train(self, k: int, gap: float,
                      gen: Optional[int] = None) -> None:
        if self._stopped or (gen is not None and gen != self._gen):
            return
        now = self.sim.now
        pkts = []
        for _ in range(k):
            self._seq += 1
            self.n_injected += 1
            pkts.append(Packet(self.FLOW_ID, self._seq, self.pkt_bytes,
                               kind="data", meta={"cross": True}))
        self.pipe.send_train(pkts, self._sink_train,
                             t_ready=[now + i * gap for i in range(k)])

    def _sink(self, pkt: Packet) -> None:
        self.n_delivered += 1

    def _sink_train(self, items: TrainItems) -> None:
        self.n_delivered += len(items)
