"""Paper Fig 13: time-to-accuracy — does LTP's partial gradient loss cost
final accuracy or convergence time? Full training loop (PSTrainer) with
transport-modelled wall-clock; reports sim-time to reach the accuracy
target plus final accuracy per protocol per loss rate."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.config import LTPConfig, NetConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCIFAR, batches
from repro.models import build
from repro.models.cnn import accuracy
from repro.optim import make_optimizer
from repro.train import PSTrainer

from benchmarks.common import emit


def run(quick: bool = True):
    cfg = get_config("papernet").replace(d_model=8 if quick else 16,
                                         n_layers=3 if quick else 6)
    api = build(cfg)
    steps = 40 if quick else 150
    tc = TrainConfig(batch=128, lr=0.05, steps=steps)
    data = SyntheticCIFAR(seed=5)
    test = {k: jnp.asarray(v) for k, v in data.test_set(1024).items()}
    eval_every = 10
    target = 0.2 if quick else 0.45
    rows = []
    losses = [0.0, 0.01] if quick else [0.0, 0.001, 0.01]
    for loss in losses:
        net = NetConfig(10, 1, loss, 4096)
        for proto in ["ltp", "bbr", "cubic"]:
            tr = PSTrainer(api, make_optimizer(tc), tc, LTPConfig(), net,
                           n_workers=8, protocol=proto, compute_time=0.05,
                           seed=0)
            hist = tr.run(batches(data, tc.batch, steps), epoch_steps=20,
                          eval_fn=lambda p: accuracy(cfg, p, test),
                          eval_every=eval_every)
            evals = [(h["sim_time"], h["eval"]) for h in hist if "eval" in h]
            tta = next((t for t, a in evals if a >= target), None)
            rows.append({
                "loss": loss, "protocol": proto,
                "final_acc": round(evals[-1][1], 4) if evals else None,
                # fixed key + explicit target column so sweep aggregation
                # and regression tooling can parse rows uniformly
                "tta_s": round(tta, 1) if tta else "not_reached",
                "target": target,
                "final_loss": round(hist[-1]["loss"], 4),
                "delivered": round(float(np.mean([h["delivered"] for h in hist])), 3),
                "total_sim_time_s": round(tr.sim_time, 1),
            })
    return emit(rows, "fig13_tta")


if __name__ == "__main__":
    run(quick=False)
