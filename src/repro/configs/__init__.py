"""Registry of assigned architectures (+ the paper's own model).

Each submodule exposes ``CONFIG`` (the exact assigned full-size config) and
``REDUCED`` (a same-family smoke variant: <=2 layers of each kind,
d_model<=512, <=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

ARCH_IDS: List[str] = [
    "yi_34b",
    "mixtral_8x22b",
    "smollm_360m",
    "falcon_mamba_7b",
    "qwen2_vl_72b",
    "gemma3_1b",
    "qwen3_14b",
    "whisper_small",
    "zamba2_7b",
    "deepseek_v2_236b",
    "papernet",
]

_ALIASES = {
    "yi-34b": "yi_34b",
    "mixtral-8x22b": "mixtral_8x22b",
    "smollm-360m": "smollm_360m",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "gemma3-1b": "gemma3_1b",
    "qwen3-14b": "qwen3_14b",
    "whisper-small": "whisper_small",
    "zamba2-7b": "zamba2_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}


def _norm(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
