"""Shared building blocks: inits, norms, MLPs, rotary embeddings."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Params = Dict[str, jnp.ndarray]


# ----------------------------------------------------------------------------
# Init helpers
# ----------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, offset, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + offset.astype(jnp.float32)).astype(dt)


def norm_params(cfg: ModelConfig, d: int) -> Params:
    if cfg.norm_type == "rms":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "offset": jnp.zeros((d,), jnp.float32)}


def apply_norm(cfg: ModelConfig, p: Params, x):
    if cfg.norm_type == "rms":
        return rms_norm(x, p["scale"], cfg.norm_eps)
    return layer_norm(x, p["scale"], p["offset"], cfg.norm_eps)


# ----------------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------------


def mlp_params(key, cfg: ModelConfig, d: int, ff: int, dtype) -> Params:
    ks = split_keys(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, ff, dtype),
            "w_up": dense_init(ks[1], d, ff, dtype),
            "w_down": dense_init(ks[2], ff, d, dtype),
        }
    return {
        "w_up": dense_init(ks[0], d, ff, dtype),
        "w_down": dense_init(ks[1], ff, d, dtype),
    }


def apply_mlp(cfg: ModelConfig, p: Params, x):
    if cfg.mlp_type == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"])
        return (g * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ----------------------------------------------------------------------------
# Rotary embeddings (plain + M-RoPE)
# ----------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Tuple[int, ...]):
    """Qwen2-VL multimodal RoPE.

    positions3: (3, ..., S) — temporal / height / width position ids.
    sections: per-axis sizes of the half-dim split (sum == hd//2).
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    # section id per frequency slot
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=hd // 2
    )
    # pick positions per slot: (..., S, hd/2)
    pos = positions3.astype(jnp.float32)  # (3, ..., S)
    pos_slot = jnp.take(pos, sec_id, axis=0)  # (hd/2, ..., S) after take on axis0?
    # jnp.take over axis 0 keeps taken axis first -> move it last
    pos_slot = jnp.moveaxis(pos_slot, 0, -1)  # (..., S, hd/2)
    ang = pos_slot * inv
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------------


def embed_params(key, cfg: ModelConfig, dtype) -> Params:
    ks = split_keys(key, 2)
    p = {"embed": dense_init(ks[0], cfg.vocab_padded, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_padded, dtype)
    return p


def embed_tokens(p: Params, tokens):
    return jnp.take(p["embed"], tokens, axis=0)


def unembed(p: Params, x, ctx=None):
    if "lm_head" in p:
        return x @ p["lm_head"]
    e = p["embed"]
    if ctx is not None:
        # tied embeddings: the lookup wants vocab-replicated rows, the
        # unembed matmul wants vocab-sharded columns — reshard the (small)
        # table here instead of partial-summing the (huge) logits
        e = ctx.constrain(e, "model", None)
    return x @ e.T


def cross_entropy(logits, labels, vocab: int):
    """Mean next-token CE in float32; labels < 0 are masked out.

    ``vocab`` is the true (unpadded) vocab — padded logit columns are masked.
    """
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab:
        neg = jnp.full((logits.shape[-1] - vocab,), -1e30, logits.dtype)
        logits = logits.at[..., vocab:].set(neg) if False else jnp.concatenate(
            [logits[..., :vocab], jnp.broadcast_to(neg, logits.shape[:-1] + neg.shape)],
            axis=-1,
        )
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    lab = jnp.clip(labels, 0)
    # gather-free pick: iota-compare + masked sum. Works with a
    # vocab-sharded logits tensor (a take_along_axis over the sharded dim
    # would force the SPMD partitioner into cross-shard index handling,
    # which XLA:CPU cannot lower inside manual shard_map regions).
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(
        jnp.where(iota == lab[..., None], logits, 0.0), axis=-1
    )
    nll = lse - picked
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
