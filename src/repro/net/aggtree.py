"""In-network aggregation at ToR switches (DESIGN.md §11).

``AggSwitch`` is the packet-level model of a programmable ToR doing
partial gradient reduction (MLFabric, PAPERS.md): copies of the same
(shard, seq) gradient fragment arriving from a rack's workers are
combined into ONE upstream wire packet — the reduced partial sum is the
size of a single fragment, so the oversubscribed uplink and the spine
trunk each carry ~1/rack_size of the flat gather's bytes. The numeric
reduce itself stays at the PS (``kernels.packet_reduce`` over delivery
masks, DESIGN.md §7; ``kernels.packet_reduce.tree_reduce`` pins that the
hierarchical reduction equals the flat one to float tolerance) — the
switch changes where bytes travel, never what the reduction computes.

Scheduling is order-aware per MLFabric: a seq whose rack membership
completes flushes immediately *together with every lower pending seq*
(reductions leave the switch in stream order; a finished high seq never
queues behind a straggling low one), and a hold timer bounds how long a
partial entry waits for stragglers before it is flushed as-is.

Loss accounting rides the §9 generation fence unchanged: member packets
keep their original ``meta`` (flow generation ``g`` included), so a
merged packet dropped on the uplink/trunk simply never expands — every
member's seq stays un-ACKed, its sender retransmits, and the PS delivery
masks show exactly which (worker, packet) cells arrived. Stale-round
traffic is fenced at the receivers exactly as on flat paths.

Transparency: senders need only an object with ``send``/``send_train``
(``AggIngress`` below), receivers see ordinary per-flow packets — the
runtime's pooled flow graphs (DESIGN.md §9) and all three aggregation
policies ride the tree without modification.

Pass-through rules: control packets (``reg``), critical packets (paper
§III-E: 100% delivery, retransmission latency matters), and flows from
outside the rack bypass aggregation and are forwarded solo in the same
upstream train — never delayed by the hold timer.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.net.simcore import Packet, Sim, TrainItems

#: flow id carried by merged envelope packets on the wire (never seen by
#: receivers — envelopes are expanded back into member packets on
#: delivery at the trunk end).
AGG_FLOW = -7

#: wire bookkeeping bytes per extra member folded into an envelope (a
#: worker-bitmap entry; the payload itself does not grow — that is the
#: entire bandwidth win).
MEMBER_OVERHEAD_BYTES = 2


class AggIngress:
    """Sender-facing path into a ToR switch. Duck-types ``Pipe``/
    ``Route`` (senders only require ``send``/``send_train``), learns the
    flow's delivery callbacks from the send calls themselves, and hands
    packets to the switch — so existing wiring code needs no new hook.

    One ingress per flow *life*: pooled transports build one per
    (worker, shard) sender and reuse it across iterations; expansion at
    the tree root delivers through the ingress' recorded callbacks, so
    two concurrent flow sets of the same worker can never cross wires.
    ``access`` optionally interposes that worker's heterogeneous access
    pipe in front of the switch.
    """

    __slots__ = ("sw", "flow", "access", "deliver", "deliver_train")

    def __init__(self, sw: "AggSwitch", flow: int,
                 access: Optional[object] = None):
        self.sw = sw
        self.flow = flow
        self.access = access
        self.deliver: Optional[Callable[[Packet], None]] = None
        self.deliver_train: Optional[Callable[[TrainItems], None]] = None

    def send(self, pkt: Packet, deliver: Callable[[Packet], None]) -> bool:
        self.deliver = deliver
        if self.access is not None:
            return self.access.send(pkt, self._arrive_one)
        self.sw.intake([(pkt, self.sw.sim.now)], self)
        return True

    def send_train(self, pkts: Sequence[Packet],
                   deliver_train: Callable[[TrainItems], None],
                   t_ready: Optional[Sequence[float]] = None) -> int:
        self.deliver_train = deliver_train
        if self.access is not None:
            return self.access.send_train(pkts, self._arrive_train, t_ready)
        now = self.sw.sim.now
        self.sw.intake([(p, now) for p in pkts], self)
        return len(pkts)

    def _arrive_one(self, pkt: Packet) -> None:
        self.sw.intake([(pkt, self.sw.sim.now)], self)

    def _arrive_train(self, items: TrainItems) -> None:
        self.sw.intake(items, self)

    def dispatch(self, items: TrainItems) -> None:
        """Deliver expanded member packets to this flow's receiver."""
        if self.deliver_train is not None:
            self.deliver_train(items)
        elif self.deliver is not None:
            for pkt, _ in items:
                self.deliver(pkt)


class AggSwitch:
    """One (shard, rack) aggregation point at the ToR.

    ``upstream`` is the path toward the PS (uplink + trunk ``Route``, or
    the trunk alone when the shard is homed in this rack). ``members``
    are the rack's worker/flow ids; ``live`` shrinks on node death
    (transport fault hooks) so a crashed straggler degrades membership
    flushes to hold-timer flushes instead of stalling them forever.
    """

    def __init__(self, sim: Sim, upstream, members: Sequence[int],
                 hold_s: float):
        self.sim = sim
        self.upstream = upstream
        self.members = frozenset(int(m) for m in members)
        self.live = set(self.members)
        self.hold = float(hold_s)
        # seq -> [t_open, {flow: (pkt, ingress)}]
        self._open: Dict[int, list] = {}
        self._timer: Optional[int] = None
        # counters (read by benchmarks/tests; conservation law checks)
        self.n_in = 0          # member data packets taken for aggregation
        self.n_solo = 0        # packets bypassing aggregation (reg/critical)
        self.n_merged = 0      # member packets folded into envelopes
        self.n_envelopes = 0   # merged envelopes emitted upstream
        self.n_timeout_flushes = 0
        self.n_membership_flushes = 0  # entries flushed by a member going dead
        # fault plane (DESIGN.md §14): a crashed switch drops everything
        # it holds and blackholes intake until recovery
        self.crashed = False
        self.n_dropped_crash = 0

    # -- fault plane (DESIGN.md §14) ----------------------------------------
    def crash(self) -> None:
        """The programmable switch dies: pending partial reductions are
        lost (their members' seqs stay un-ACKed, so the senders
        retransmit after recovery), the hold timer stops, and intake
        blackholes until ``recover``."""
        if self.crashed:
            return
        self.crashed = True
        for _s, e in sorted(self._open.items()):
            self.n_dropped_crash += len(e[1])
        self._open.clear()
        if self._timer is not None:
            self.sim.cancel(self._timer)
            self._timer = None

    def recover(self) -> None:
        self.crashed = False

    # -- membership (fault hooks, DESIGN.md §10) ----------------------------
    def set_live(self, flow: int, alive: bool) -> None:
        if flow not in self.members:
            return
        if alive:
            self.live.add(flow)
            return
        self.live.discard(flow)
        # entries may have just become membership-complete
        full = [s for s, e in self._open.items() if self.live <= e[1].keys()]
        if full:
            self.n_membership_flushes += len(full)
            self._emit(self._collect(max(full)))

    # -- datapath -----------------------------------------------------------
    def intake(self, items: TrainItems, ing: AggIngress) -> None:
        """Packets arriving from one rack member (one event)."""
        if self.crashed:
            self.n_dropped_crash += len(items)
            return
        out: List[Packet] = []
        flush_upto = -1
        for pkt, _t in items:
            if (pkt.kind != "data" or pkt.critical
                    or pkt.flow not in self.members):
                self.n_solo += 1
                out.append(self._envelope([(pkt, ing)]))
                continue
            self.n_in += 1
            e = self._open.get(pkt.seq)
            if e is None:
                self._open[pkt.seq] = e = [self.sim.now, {}]
            elif pkt.flow in e[1]:
                # retransmit while the seq is still pending: forward the
                # older copy solo, keep the newest in the entry
                self.n_solo += 1
                out.append(self._envelope([e[1][pkt.flow]]))
            e[1][pkt.flow] = (pkt, ing)
            if self.live <= e[1].keys():
                flush_upto = max(flush_upto, pkt.seq)
        if flush_upto >= 0:
            out.extend(self._collect(flush_upto))
        self._emit(out)
        self._arm()

    def _envelope(self, copies: List[Tuple[Packet, AggIngress]]) -> Packet:
        """Wrap member copies as one wire packet. A single copy rides at
        its own size; k copies ride at max(size) + a bitmap entry per
        extra member — the partial sum is one payload wide."""
        size = max(p.size for p, _ in copies) \
            + MEMBER_OVERHEAD_BYTES * (len(copies) - 1)
        if len(copies) > 1:
            self.n_merged += len(copies)
            self.n_envelopes += 1
        return Packet(AGG_FLOW, copies[0][0].seq, size, kind="data",
                      meta={"agg": copies})

    def _collect(self, upto: int) -> List[Packet]:
        """Order-aware flush: every pending seq <= ``upto``, ascending —
        reductions leave the switch in stream order (MLFabric)."""
        seqs = sorted(s for s in self._open if s <= upto)
        out = []
        for s in seqs:
            _, copies = self._open.pop(s)
            out.append(self._envelope(list(copies.values())))
        return out

    def _emit(self, envelopes: List[Packet]) -> None:
        if envelopes:
            self.upstream.send_train(envelopes, self._expand)

    # -- hold timer ---------------------------------------------------------
    def _arm(self) -> None:
        if self._timer is not None or not self._open:
            return
        t0 = min(e[0] for e in self._open.values())
        self._timer = self.sim.at(t0 + self.hold, self._sweep)

    def _sweep(self) -> None:
        self._timer = None
        if not self._open:
            return
        cutoff = self.sim.now - self.hold + 1e-12
        ripe = [s for s, e in self._open.items() if e[0] <= cutoff]
        if ripe:
            self.n_timeout_flushes += len(ripe)
            # order-aware even on timeout: ripe seqs drag every lower
            # pending seq out with them
            self._emit(self._collect(max(ripe)))
        self._arm()

    # -- tree root: expansion back into per-flow packets --------------------
    def _expand(self, items: TrainItems) -> None:
        """A train of envelopes survived the uplink+trunk: unwrap every
        member copy and deliver it through its own ingress' callbacks.
        Flows sharing one receiver train callback (the bsp barrier's
        sharded receiver) are dispatched as one train, so the close rule
        evaluates once per wire train, exactly like a flat trunk."""
        # id()-keyed grouping is safe here: keys only bucket callbacks
        # within this one event, the dict iterates in insertion order
        # (member order on the wire), and no id ever leaves the process.
        groups: Dict[tuple, Tuple[AggIngress, TrainItems]] = {}
        for env, t in items:
            for pkt, ing in env.meta["agg"]:
                cb = ing.deliver_train
                if cb is not None:
                    key = (id(getattr(cb, "__self__", cb)),     # replint: ok(determinism)
                           id(getattr(cb, "__func__", cb)))     # replint: ok(determinism)
                else:
                    key = ("pp", id(ing))                       # replint: ok(determinism)
                g = groups.get(key)
                if g is None:
                    groups[key] = (ing, [(pkt, t)])
                else:
                    g[1].append((pkt, t))
        for ing, fitems in groups.values():
            ing.dispatch(fitems)

    def stats(self) -> Dict[str, float]:
        return {
            "n_in": self.n_in,
            "n_solo": self.n_solo,
            "n_merged": self.n_merged,
            "n_envelopes": self.n_envelopes,
            "n_timeout_flushes": self.n_timeout_flushes,
            "n_membership_flushes": self.n_membership_flushes,
            "n_dropped_crash": self.n_dropped_crash,
            "pending": len(self._open),
        }
