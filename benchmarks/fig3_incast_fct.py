"""Paper Fig 3: long-tail FCT distribution under 8-to-1 incast (DES),
and Fig 14: batch-synchronization-time distribution normalized to LTP."""
from __future__ import annotations

import numpy as np

from repro.config import NetConfig
from repro.net.scenarios import incast_gather

from benchmarks.common import emit


def run(quick: bool = True):
    rows = []
    iters = 8 if quick else 20
    size = 2e6 if quick else 4.9e6
    losses = [0.0, 0.001] if quick else [0.0, 0.0001, 0.001, 0.005, 0.01]
    for loss in losses:
        net = NetConfig(10, 1, loss, 4096)
        ltp_bst = None
        for proto in ["ltp", "bbr", "cubic", "reno"]:
            rs = incast_gather(proto, net, 8, size, iters=iters, seed=11)
            fct = np.concatenate([r.fcts for r in rs])
            bst = np.array([r.bst_gather for r in rs])
            delivered = float(np.mean([r.delivered.mean() for r in rs]))
            if proto == "ltp":
                ltp_bst = bst.mean()
            rows.append({
                "loss": loss, "protocol": proto,
                "fct_p50_ms": round(float(np.percentile(fct, 50)) * 1e3, 2),
                "fct_p95_ms": round(float(np.percentile(fct, 95)) * 1e3, 2),
                "fct_p99_ms": round(float(np.percentile(fct, 99)) * 1e3, 2),
                "bst_mean_ms": round(float(bst.mean()) * 1e3, 2),
                "bst_p95_ms": round(float(np.percentile(bst, 95)) * 1e3, 2),
                "bst_norm_to_ltp": round(float(bst.mean() / ltp_bst), 3),
                "delivered": round(delivered, 3),
            })
    return emit(rows, "fig3_14_incast_fct_bst")


if __name__ == "__main__":
    run(quick=False)
