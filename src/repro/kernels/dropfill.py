"""Pallas TPU kernel: bubble-fill + compensation over packet tiles.

The PS-side hot loop of LTP-sync applies, per packet, `out = g * mask * scale`
over the flattened gradient stream laid out as (n_packets, payload). The
payload is lane-aligned (the paper's *padding bubble* generalized from
4-byte float alignment to the TPU's 128-float lane width — DESIGN.md §2),
so a whole packet maps to whole vector lanes and a lost packet zeroes
aligned spans. Memory-bound: tiles stream HBM -> VMEM once.

Block shape: (BLOCK_P, payload) with payload padded to a 128 multiple by
``ops.ltp_dropfill``; BLOCK_P=256 keeps the working set ~256*384*4B = 384KB
in VMEM (well under the ~16MB/core budget, leaving room for double
buffering).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

BLOCK_P = 256


def _dropfill_kernel(pkt_ref, gate_ref, out_ref):
    """pkt: (BLOCK_P, payload); gate: (BLOCK_P, 1) = mask*scale."""
    out_ref[...] = pkt_ref[...] * gate_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dropfill(packets, mask, scale, *, interpret: bool = True):
    """packets: (n_packets, payload) f32; mask/scale: (n_packets,) f32.

    Requires payload % 128 == 0 and n_packets % BLOCK_P == 0 (the ops.py
    wrapper pads); returns packets * mask * scale.
    """
    n, p = packets.shape
    assert p % 128 == 0, f"payload {p} not lane-aligned"
    assert n % BLOCK_P == 0, f"n_packets {n} not a multiple of {BLOCK_P}"
    gate = (mask * scale)[:, None].astype(packets.dtype)
    grid = (n // BLOCK_P,)
    return pl.pallas_call(
        _dropfill_kernel,
        out_shape=jax.ShapeDtypeStruct((n, p), packets.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_P, p), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_P, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_P, p), lambda i: (i, 0)),
        interpret=interpret,
    )(packets, gate)
