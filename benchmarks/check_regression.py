"""Perf-regression gate over the BENCH_*.json records (CI perf-smoke).

Compares freshly generated records against the committed baselines:

* ``*_wall_s``        — FAIL when current > ``--max-ratio`` x baseline
                        (default 2.0: the CI budget for runner jitter);
* ``*_events_per_sec`` / ``*_gbps`` / ``*_speedup``
                      — FAIL when current < baseline / ``--max-ratio``
                        (throughput floors: the committed acceptance
                        metrics must not silently collapse);
* metric present in the baseline but missing from the current record
                      — FAIL (a benchmark quietly dropped).

New metrics in the current record are allowed (they become baseline on
the next commit of the JSONs).

Wall-clocks are machine-dependent: the 2x budget is what absorbs the
authoring-machine-vs-CI-runner gap, and a host mismatch between the two
records is printed as a warning so a tripped gate is easy to triage.
The in-run *relative* metrics (``grid64_coalesce_speedup``, the
events/sec floors) are machine-independent and carry the real signal.

  python -m benchmarks.check_regression \
      --baseline-dir /tmp/bench-baseline --current-dir . \
      BENCH_netsim.json BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

DEFAULT_FILES = ("BENCH_netsim.json", "BENCH_kernels.json",
                 "BENCH_runtime.json")

#: metric-name suffix -> direction ("up" = bigger is better)
RULES: Tuple[Tuple[str, str], ...] = (
    ("_wall_s", "down"),
    ("_events_per_sec", "up"),
    ("_gbps", "up"),
    ("_speedup", "up"),
)


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _metrics(doc: dict) -> Dict[str, float]:
    return {k: v for k, v in doc.get("metrics", {}).items()
            if isinstance(v, (int, float))}


def compare(current: Dict[str, float], baseline: Dict[str, float],
            max_ratio: float) -> List[str]:
    """Returns a list of human-readable failure lines (empty = pass)."""
    failures = []
    for key, base in sorted(baseline.items()):
        direction = next((d for suf, d in RULES if key.endswith(suf)), None)
        if direction is None or base == 0:
            continue
        if key not in current:
            failures.append(f"{key}: missing from current record "
                            f"(baseline {base})")
            continue
        cur = current[key]
        ratio = cur / base
        ok = ratio <= max_ratio if direction == "down" else \
            ratio >= 1.0 / max_ratio
        mark = "ok" if ok else "REGRESSION"
        print(f"  {key:45s} base={base:<12g} cur={cur:<12g} "
              f"x{ratio:.2f} [{mark}]")
        if not ok:
            failures.append(
                f"{key}: {cur:g} vs baseline {base:g} "
                f"(x{ratio:.2f}, budget x{max_ratio:g} {direction})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=None,
                    help=f"record names (default: {', '.join(DEFAULT_FILES)})")
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed baseline JSONs")
    ap.add_argument("--current-dir", default=".",
                    help="directory holding the fresh JSONs (default: .)")
    ap.add_argument("--max-ratio", type=float, default=2.0)
    args = ap.parse_args(argv)
    files = args.files or list(DEFAULT_FILES)
    all_failures = []
    for name in files:
        base_path = os.path.join(args.baseline_dir, name)
        cur_path = os.path.join(args.current_dir, name)
        if not os.path.exists(base_path):
            print(f"{name}: no baseline at {base_path} — skipping "
                  f"(commit one to arm the gate)")
            continue
        if not os.path.exists(cur_path):
            all_failures.append(f"{name}: current record missing at "
                                f"{cur_path}")
            continue
        base_doc, cur_doc = _load(base_path), _load(cur_path)
        if base_doc.get("host") != cur_doc.get("host"):
            print(f"{name}: WARNING host mismatch "
                  f"(baseline {base_doc.get('host')} vs "
                  f"current {cur_doc.get('host')}) — wall-clock ratios "
                  f"compare different machines")
        print(f"{name}:")
        all_failures += compare(_metrics(cur_doc), _metrics(base_doc),
                                args.max_ratio)
    if all_failures:
        print("\nperf regression gate FAILED:", file=sys.stderr)
        for f in all_failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nperf regression gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
